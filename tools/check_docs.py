"""Docs consistency check (CI gate; see .github/workflows/ci.yml).

Two invariants keep the paper-to-code map (docs/kernels.md) from rotting:

  1. every module under src/repro/ has a module docstring — the map's
     per-file "what is this" always has a source-side anchor;
  2. every .py/.md file referenced from docs/*.md or README.md exists —
     a renamed or deleted file breaks CI, not the reader.

Path references are taken from inline code spans and link targets; a
reference may be repo-root-relative (src/repro/kernels/wkv4.py,
docs/serving.md), src/repro-relative (kernels/wkv4.py — the README module
map's convention), or a bare docs page name (serving.md).

Run: python tools/check_docs.py   (exits non-zero on any violation)
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# path-looking tokens ending in .py or .md (inside backticks or link urls)
_REF = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|md)\b")


def missing_docstrings() -> list[str]:
    out = []
    for py in sorted((ROOT / "src" / "repro").rglob("*.py")):
        tree = ast.parse(py.read_text(), filename=str(py))
        if ast.get_docstring(tree) is None:
            out.append(str(py.relative_to(ROOT)))
    return out


def _resolves(ref: str) -> bool:
    candidates = [ROOT / ref, ROOT / "src" / "repro" / ref,
                  ROOT / "docs" / ref]
    return any(c.is_file() for c in candidates)


def broken_references() -> list[str]:
    docs = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    out = []
    for doc in docs:
        for ref in sorted(set(_REF.findall(doc.read_text()))):
            if not _resolves(ref):
                out.append(f"{doc.relative_to(ROOT)} -> {ref}")
    return out


def main() -> int:
    nodoc = missing_docstrings()
    broken = broken_references()
    for path in nodoc:
        print(f"missing module docstring: {path}")
    for ref in broken:
        print(f"broken file reference: {ref}")
    if nodoc or broken:
        print(f"\ncheck_docs: FAIL ({len(nodoc)} missing docstrings, "
              f"{len(broken)} broken references)")
        return 1
    print("check_docs: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
