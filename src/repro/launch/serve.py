"""Serving launcher — thin CLI over the continuous-batching engine.

Default mode drives `repro.serving.ServingEngine`: N concurrent requests
share one slotted state pool, chunked prefill interleaves with fused
batched decode, and the run ends with a telemetry snapshot (tokens/s,
TTFT, latency) from `runtime.monitor.ServingCounters`.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv4-169m --smoke \
        --tokens 64 --batch 4 [--quantized] [--prefill-chunk 16] \
        [--fused[=block|model]] [--fused-prefill] [--devices N | --mesh] \
        [--prefix-cache [--prefix-cache-slots N]] \
        [--speculative K [--draft-depth D]] \
        [--max-queue N [--overload backpressure|shed]] \
        [--prefill-budget T] [--deadline S] \
        [--snapshot-dir DIR [--snapshot-every N] [--resume] \
         [--supervise [--max-restarts K]]] [--sentinel-every N]

Every flag combination resolves to ONE `repro.serving.plan.ExecutionPlan`
(path selection + one-pass param prep + program cache + mesh placement);
the engine just drives it.  `--fused block` decodes through the per-block
fused Pallas kernel (one launch per layer); `--fused model` through the
whole-model megakernel (ONE launch per decode step, grid over layers —
see docs/kernels.md).  `--fused-prefill` absorbs prompt chunks through
the fused chunked-prefill path (chunk-shaped matmuls + the on-chip WKV
sequence kernel, packed Δ-PoT weights decoded in-kernel) instead of the
per-op scan — same bits, measured faster in benchmarks/bench_prefill.py.

`--devices N` serves data-parallel over N local devices (`--mesh` over
all of them): the slot pool and per-tick batch shard across a 1-D
("data",) mesh, weights replicate, tokens stay bit-identical to the
1-device engine (docs/serving.md §multi-device serving).  On a CPU host
spawn virtual devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --arch rwkv4-169m \
        --smoke --batch 8 --devices 8

`--legacy` keeps the seed behavior — one jitted decode_step in a
single-batch host loop — and is also the reference baseline for
benchmarks/bench_serving.py.  `--hw-numerics` (rwkv4 only: LUT exp, PWL
sigmoid, LUT division) implies the legacy loop, since the hw-numerics
wrapper bypasses the registry Model contract the engine builds on.

See docs/serving.md for the engine API.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant.policy import QuantPolicy, fake_quantize_tree
from repro.models.registry import get_model


def greedy_decode(model, params, state, first_token, n_tokens: int,
                  start_pos: int = 0, *, sample_temp: float = 0.0,
                  rng=None):
    """Autoregressive loop around decode_step (host loop — the seed's
    single-request serving mode, kept as the engine's reference baseline)."""
    B = first_token.shape[0]
    tok = first_token
    out = [tok]
    pos = start_pos
    step_fn = jax.jit(model.decode_step)   # traced once, reused every token
    for i in range(n_tokens):
        logits, state = step_fn(params, state, tok, jnp.int32(pos))
        last = logits[:, -1]
        if sample_temp > 0 and rng is not None:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, last / sample_temp)[:, None]
        else:
            tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        pos += 1
        out.append(tok)
    return jnp.concatenate(out, axis=1), state


def sequential_decode(model, params, prompt: list[int], n_new: int):
    """Batch-1 greedy decode of one request: feed the prompt token-by-token
    through a jitted decode_step, then argmax-chain `n_new` tokens.  This is
    the engine's bit-identity oracle (docs/serving.md) — the example and the
    scheduler tests both compare against it.

    BOTH phases compile with defined rounding semantics
    (`kernels.common.exact_jit`), in lockstep with the engine: the engine
    pins `xla_allow_excess_precision=False` on every token-producing
    program (prefill, decode, and the speculative verifier), and the
    oracle must round the same way or near-tie argmaxes drift."""
    from repro.kernels.common import exact_jit
    step = exact_jit(model.decode_step)
    prompt_step = exact_jit(model.decode_step)
    state = model.init_decode_state(1, 0)
    logits = None
    for t in prompt:
        logits, state = prompt_step(params, state,
                                    jnp.array([[t]], jnp.int32),
                                    jnp.int32(0))
    out = []
    for _ in range(n_new):
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        logits, state = step(params, state,
                             jnp.array([[tok]], jnp.int32), jnp.int32(0))
    return out


def serve_legacy(arch: str, *, smoke: bool = True, batch: int = 4,
                 n_tokens: int = 32, quantized: bool = False, seed: int = 0,
                 hw_numerics: bool = False):
    """Seed serving mode: one fused batch, single host loop."""
    model = get_model(arch, smoke=smoke)
    cfg = model.cfg
    rng = jax.random.PRNGKey(seed)
    params = model.init_params(rng)
    if quantized:
        t0 = time.time()
        params = fake_quantize_tree(params, QuantPolicy())
        print(f"quantized (Δ-PoT W9/A9 policy) in {time.time()-t0:.1f}s")
    state = model.init_decode_state(batch, n_tokens + 8)
    first = jax.random.randint(rng, (batch, 1), 0, cfg.vocab)

    # rwkv4 supports the full paper numerics (LUT exp / PWL sigmoid / LUT div)
    if hw_numerics and cfg.rwkv_version == 4:
        from repro.models import rwkv4 as R4

        class HwModel:
            cfg = model.cfg

            def decode_step(self, p, s, t, pos):
                return R4.decode_step(model.cast_params(p), s, t, pos,
                                      cfg, hw=True)
        m = HwModel()
    else:
        m = model

    t0 = time.time()
    toks, state = greedy_decode(m, params, state, first, n_tokens)
    dt = time.time() - t0
    tps = batch * n_tokens / max(dt, 1e-9)
    print(f"{arch}: decoded {n_tokens} tokens x {batch} seqs in "
          f"{dt:.2f}s ({tps:,.0f} tok/s)")
    return toks


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          n_tokens: int = 32, quantized: bool = False, seed: int = 0,
          prefill_chunk: int = 16, prompt_len: int = 8,
          temperature: float = 0.0, fused: bool | str | None = False,
          fused_prefill: bool = False, devices: int | None = None,
          prefix_cache: bool = False, cache_slots: int = 64,
          cache_host_slots: int = 256, speculative: int | None = None,
          draft_depth: int | None = None, max_queue: int = 0,
          overload: str = "backpressure", prefill_budget: int = 0,
          deadline_s: float | None = None, snapshot_dir: str | None = None,
          snapshot_every: int = 8, sentinel_every: int = 0,
          resume: bool = False):
    """Continuous-batching serving: `batch` concurrent requests through the
    slotted engine; prints the telemetry snapshot and returns the handles.
    `devices` (0 = all visible) serves data-parallel over a ("data",)
    serving mesh — pool and batch sharded, weights replicated.
    `prefix_cache` enables the recurrent-state prefix cache; the demo
    workload then gives every request a shared system-prompt prefix so the
    hit path is actually exercised (docs/serving.md §prefix cache).
    `max_queue`/`overload`/`prefill_budget`/`deadline_s` configure the
    SLO layer (docs/serving.md §"SLOs and overload"); the defaults keep
    the historical unbounded/unlimited behavior.

    Crash safety (docs/operations.md): `snapshot_dir` makes the engine
    write a tick-boundary snapshot every `snapshot_every` ticks;
    `resume=True` restores the newest committed snapshot from that
    directory (falling back to a fresh start when none exists — e.g. a
    crash before the first snapshot boundary) and drives the restored
    work to completion, streams continuing bit-identically.
    `sentinel_every` turns on the NaN/Inf lane sentinels."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import (AdmissionPolicy, Overloaded,
                               PrefixCacheConfig, ServingEngine,
                               ServingSLO, SnapshotConfig)

    if resume and snapshot_dir:
        try:
            engine = ServingEngine.restore(snapshot_dir)
        except FileNotFoundError:
            print(f"no committed snapshot under {snapshot_dir!r} — "
                  "starting fresh")
        else:
            handles = list(engine._handles.values())
            print(f"resumed {len(handles)} request(s) from "
                  f"{snapshot_dir!r} at tick "
                  f"{engine.scheduler._tick_no}")
            snap = engine.run()
            if engine.snapshot_manager is not None:
                engine.snapshot_manager.wait()
            done = sum(len(h.resumed) + len(h.tokens) for h in handles)
            print(f"{arch}: resumed run drained — {done} total tokens "
                  f"across {len(handles)} stream(s) "
                  f"(resumed + continued, bit-identical)")
            for k, v in snap.items():
                print(f"  {k}: {v:.3f}" if isinstance(v, float)
                      else f"  {k}: {v}")
            return handles

    mesh = None
    if devices is not None:
        mesh = make_serving_mesh(devices)
        print(f"serving mesh: {dict(mesh.shape)} over "
              f"{mesh.devices.size} x {mesh.devices.flat[0].device_kind}")
    cache_cfg = PrefixCacheConfig(device_slots=cache_slots,
                                  host_slots=cache_host_slots) \
        if prefix_cache else None
    slo = ServingSLO(prefill_budget=prefill_budget,
                     default_deadline_s=deadline_s,
                     admission=AdmissionPolicy(max_queue=max_queue,
                                               overload=overload))
    engine = ServingEngine(arch, smoke=smoke, max_batch=batch,
                           prefill_chunk=prefill_chunk,
                           quantized=quantized,
                           fused_decode=fused or False,
                           fused_prefill=fused_prefill, seed=seed,
                           speculative=speculative, draft_depth=draft_depth,
                           mesh=mesh, prefix_cache=cache_cfg, slo=slo,
                           snapshot=None if snapshot_dir is None else
                           SnapshotConfig(directory=snapshot_dir,
                                          every=snapshot_every),
                           sentinel_every=sentinel_every)
    cfg = engine.model.cfg
    rng = np.random.default_rng(seed)
    # with the cache on, share one "system prompt" across all requests so
    # every submission after the first resumes from a cached state; a
    # warm-up request runs to completion first, since boundary states only
    # publish when their request finishes
    shared = []
    if prefix_cache:
        shared = rng.integers(0, cfg.vocab,
                              size=max(prefill_chunk, prompt_len)).tolist()
        engine.submit(shared + [int(rng.integers(0, cfg.vocab))],
                      max_new_tokens=1)
        engine.run()
    # admission is tick-driven, so every submit lands on the queue first;
    # with --max-queue below the demo's request count the engine answers
    # with typed backpressure — report it instead of letting it unwind
    handles, rejected = [], 0
    for _ in range(batch):
        prompt = shared + \
            rng.integers(0, cfg.vocab, size=prompt_len).tolist()
        try:
            handles.append(
                engine.submit(prompt, max_new_tokens=n_tokens,
                              temperature=temperature,
                              seed=int(rng.integers(1 << 31))))
        except Overloaded as exc:
            rejected += 1
            print(f"backpressured: {exc}")
    snap = engine.run()
    if engine.snapshot_manager is not None:
        engine.snapshot_manager.wait()
    if rejected:
        print(f"{rejected}/{batch} submissions backpressured "
              f"(--max-queue {max_queue}, --overload {overload})")
    print(f"{arch}: {snap['finished']} requests x {n_tokens} tokens "
          f"({'Δ-PoT W8' if quantized else 'fp'} weights) — "
          f"{snap['decode_tokens_per_s']:,.0f} decode tok/s, "
          f"TTFT {snap['mean_ttft_s']*1e3:.0f} ms, "
          f"latency {snap['mean_latency_s']*1e3:.0f} ms")
    for k, v in snap.items():
        print(f"  {k}: {v:.3f}" if isinstance(v, float) else f"  {k}: {v}")
    if engine.prefix_cache is not None:
        print("prefix cache:")
        for k, v in engine.prefix_cache.snapshot().items():
            print(f"  {k}: {v:.3f}" if isinstance(v, float)
                  else f"  {k}: {v}")
    return handles


def supervise(argv: list[str], *, max_restarts: int = 3) -> int:
    """Restart-and-resume supervisor (docs/operations.md §supervisor):
    run the serve CLI in a child process; on ANY abnormal exit — an
    injected crash, a SIGKILL, an OOM kill — relaunch it with `--resume`
    so it restores the newest committed snapshot and continues every
    stream bit-identically.  A crash before the first snapshot boundary
    resumes as a fresh start (serve's `--resume` falls back).  Gives up
    after `max_restarts` abnormal exits and returns the child's code."""
    import subprocess
    import sys
    args = [a for a in argv if a != "--supervise"]
    for attempt in range(max_restarts + 1):
        rc = subprocess.call([sys.executable, "-m", "repro.launch.serve",
                              *args])
        if rc == 0:
            return 0
        if attempt == max_restarts:
            print(f"supervisor: giving up after {max_restarts} restarts "
                  f"(last rc={rc})")
            return rc
        print(f"supervisor: serve exited rc={rc}; restarting with "
              f"--resume ({attempt + 1}/{max_restarts})")
        if "--resume" not in args:
            args.append("--resume")
    return rc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv4-169m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--fused", nargs="?", const="block", default=None,
                    choices=["block", "model"],
                    help="fused decode granularity: 'block' (one Pallas "
                         "launch per block; bare --fused keeps the PR 2 "
                         "meaning) or 'model' (the whole-model megakernel "
                         "— ONE launch per decode step; "
                         "kernels/fused_decode.py)")
    ap.add_argument("--fused-prefill", action="store_true",
                    help="fused chunked prefill: whole prompt chunks as "
                         "(S*C, D) matmuls + the on-chip WKV sequence "
                         "kernel, packed weights decoded in-kernel "
                         "(kernels/fused_prefill.py); bit-identical to "
                         "the per-op prefill scan")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="recurrent-state prefix cache: repeated prompt "
                         "prefixes resume from cached chunk-boundary "
                         "states instead of prefilling (bit-identical "
                         "tokens; serving/prefix_cache.py).  The demo "
                         "workload shares a system prompt across requests "
                         "so the hit path shows up in the telemetry")
    ap.add_argument("--prefix-cache-slots", type=int, default=64,
                    help="device-tier cache entries (lane states)")
    ap.add_argument("--prefix-cache-host-slots", type=int, default=256,
                    help="host spill-tier entries; 0 disables spilling")
    ap.add_argument("--speculative", type=int, default=None, metavar="K",
                    help="self-speculative decode: a truncated-stack "
                         "drafter proposes K-1 tokens per tick and one "
                         "chunk-shaped verify call scores the whole "
                         "window; the longest verifier-agreed prefix is "
                         "accepted (bit-identical tokens — K only moves "
                         "tokens/s; serving/plan.py SpeculativePath)")
    ap.add_argument("--draft-depth", type=int, default=None,
                    help="layers the speculative drafter keeps (default "
                         "half the stack)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue (SLO layer): queued-"
                         "request cap, 0 = unbounded; a full queue "
                         "backpressures (typed Overloaded with retry "
                         "hints) or sheds per --overload")
    ap.add_argument("--overload", default="backpressure",
                    choices=["backpressure", "shed"],
                    help="full-queue behavior: refuse the arrival "
                         "(backpressure) or drop the lowest-priority "
                         "queued request (shed); serving/slo.py")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prefill chunk-tokens per tick while lanes are "
                         "decoding (0 = unlimited): caps the inter-token-"
                         "latency jitter a prefill burst can inject")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="default per-request deadline in seconds; "
                         "deadline-exceeded requests are evicted with "
                         "outcome 'deadline' (state slot freed, nothing "
                         "leaked)")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="crash safety: write a tick-boundary engine "
                         "snapshot into DIR every --snapshot-every ticks "
                         "(atomic commits, async writes; serving/"
                         "snapshot.py, docs/operations.md)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="snapshot cadence in scheduler ticks")
    ap.add_argument("--sentinel-every", type=int, default=0,
                    help="NaN/Inf lane sentinel sweep every N ticks "
                         "(0 = off): poisoned lanes are quarantined and "
                         "their requests requeued for a clean replay")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest committed snapshot from "
                         "--snapshot-dir and continue every stream "
                         "bit-identically (fresh start when none exists)")
    ap.add_argument("--supervise", action="store_true",
                    help="restart-and-resume supervisor: run serve in a "
                         "child process and relaunch it with --resume on "
                         "any abnormal exit (needs --snapshot-dir)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="supervisor restart budget")
    ap.add_argument("--devices", type=int, default=None,
                    help="serve data-parallel over N local devices (the "
                         "slot pool and per-tick batch shard over a "
                         "('data',) mesh, weights replicate); CPU hosts "
                         "need XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N set before launch")
    ap.add_argument("--mesh", action="store_true",
                    help="shorthand for --devices over ALL visible "
                         "devices")
    ap.add_argument("--legacy", action="store_true",
                    help="seed single-loop decode instead of the engine")
    ap.add_argument("--hw-numerics", action="store_true",
                    help="paper LUT/PWL numerics (rwkv4; implies --legacy)")
    args = ap.parse_args()
    if args.supervise:
        import sys
        if not args.snapshot_dir:
            ap.error("--supervise needs --snapshot-dir (nothing to "
                     "resume from otherwise)")
        raise SystemExit(supervise(sys.argv[1:],
                                   max_restarts=args.max_restarts))
    if args.legacy or args.hw_numerics:
        serve_legacy(args.arch, smoke=args.smoke, batch=args.batch,
                     n_tokens=args.tokens, quantized=args.quantized,
                     hw_numerics=args.hw_numerics)
    else:
        devices = 0 if args.mesh else args.devices
        serve(args.arch, smoke=args.smoke, batch=args.batch,
              n_tokens=args.tokens, quantized=args.quantized,
              prefill_chunk=args.prefill_chunk,
              prompt_len=args.prompt_len, temperature=args.temperature,
              fused=args.fused, fused_prefill=args.fused_prefill,
              devices=devices, prefix_cache=args.prefix_cache,
              cache_slots=args.prefix_cache_slots,
              cache_host_slots=args.prefix_cache_host_slots,
              speculative=args.speculative, draft_depth=args.draft_depth,
              max_queue=args.max_queue, overload=args.overload,
              prefill_budget=args.prefill_budget,
              deadline_s=args.deadline, snapshot_dir=args.snapshot_dir,
              snapshot_every=args.snapshot_every,
              sentinel_every=args.sentinel_every, resume=args.resume)


if __name__ == "__main__":
    main()
