"""Serving launcher — the paper's workload: token-by-token decode.

Implements the paper's serving mode on the JAX stack: load (or init)
weights, optionally quantize them with the paper's mixed-precision policy
(Δ-PoT matrices + W9 additive + A9 activations for RWKV-4's hw mode),
prefill a prompt, then decode autoregressively with the O(1)/KV state.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv4-169m --smoke \
        --tokens 64 --batch 4 [--quantized]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant.policy import QuantPolicy, fake_quantize_tree
from repro.launch.mesh import make_host_mesh
from repro.models.registry import get_model


def greedy_decode(model, params, state, first_token, n_tokens: int,
                  start_pos: int = 0, *, sample_temp: float = 0.0,
                  rng=None):
    """Autoregressive loop around decode_step (host loop — mirrors real
    serving where each step is one device program)."""
    B = first_token.shape[0]
    tok = first_token
    out = [tok]
    pos = start_pos
    step_fn = jax.jit(model.decode_step)   # traced once, reused every token
    for i in range(n_tokens):
        logits, state = step_fn(params, state, tok, jnp.int32(pos))
        last = logits[:, -1]
        if sample_temp > 0 and rng is not None:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, last / sample_temp)[:, None]
        else:
            tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        pos += 1
        out.append(tok)
    return jnp.concatenate(out, axis=1), state


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          n_tokens: int = 32, quantized: bool = False, seed: int = 0,
          hw_numerics: bool = False):
    model = get_model(arch, smoke=smoke)
    cfg = model.cfg
    rng = jax.random.PRNGKey(seed)
    params = model.init_params(rng)
    if quantized:
        t0 = time.time()
        params = fake_quantize_tree(params, QuantPolicy())
        print(f"quantized (Δ-PoT W9/A9 policy) in {time.time()-t0:.1f}s")
    state = model.init_decode_state(batch, n_tokens + 8)
    first = jax.random.randint(rng, (batch, 1), 0, cfg.vocab)

    # rwkv4 supports the full paper numerics (LUT exp / PWL sigmoid / LUT div)
    if hw_numerics and cfg.rwkv_version == 4:
        from repro.models import rwkv4 as R4

        class HwModel:
            cfg = model.cfg

            def decode_step(self, p, s, t, pos):
                return R4.decode_step(model.cast_params(p), s, t, pos,
                                      cfg, hw=True)
        m = HwModel()
    else:
        m = model

    t0 = time.time()
    toks, state = greedy_decode(m, params, state, first, n_tokens)
    dt = time.time() - t0
    tps = batch * n_tokens / max(dt, 1e-9)
    print(f"{arch}: decoded {n_tokens} tokens x {batch} seqs in "
          f"{dt:.2f}s ({tps:,.0f} tok/s)")
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv4-169m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--hw-numerics", action="store_true")
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, batch=args.batch,
          n_tokens=args.tokens, quantized=args.quantized,
          hw_numerics=args.hw_numerics)


if __name__ == "__main__":
    main()
