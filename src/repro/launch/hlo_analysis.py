"""Loop-aware HLO analysis: exact FLOPs / HBM bytes / collective bytes.

`compiled.cost_analysis()` counts a while-loop body ONCE, but every layer
scan (and flash-attention KV scan, and wkv chunk scan) is a while loop — so
for scanned models it under-counts flops and collective bytes by the trip
count.  This module re-derives the three roofline inputs from
`compiled.as_text()` with loop multipliers:

  * computations are parsed into blocks; `while` ops link body/condition;
  * the trip count is read from the loop condition's `s32[] constant(N)`
    (jax lowers `lax.scan` to a 0..N counter loop);
  * metrics are accumulated over ENTRY + while bodies, each weighted by the
    product of enclosing trip counts (nested loops compose);
  * FLOPs: 2 * numel(result) * K for every `dot` (K = product of the lhs
    contracting dims) — matmul flops dominate all our workloads;
  * HBM bytes: sum of operand + result bytes per top-level op (fusion
    internals excluded — a fusion reads its operands and writes its result
    once), layout-only ops (tuple/gte/bitcast/parameter/constant) free;
  * collective bytes: result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (start ops only).

Oracle/consumer: this IS the oracle for roofline inputs — `tests/
test_hlo_analysis.py` pins its counts against hand-computed matmul/scan
HLO, and `launch.roofline` (the cost_analysis-based fast path) is the
consumer it corrects: `launch.dryrun` reports both so trip-count
under-counting is visible per artifact.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota"}

# Ops the TPU compiler fuses into producers/consumers (no HBM round-trip of
# their own).  The CPU-backend HLO we analyze leaves many of these unfused;
# counting them would claim HBM traffic a TPU never pays.  Bytes are counted
# only at fusion boundaries: `fusion` ops, dots, convs, data movement
# (copy/slice/dus/gather/scatter/sort/reduce), collectives, while carries.
_FUSABLE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "negate", "abs", "compare", "select", "convert", "rsqrt", "sqrt",
    "power", "and", "or", "not", "xor", "clamp", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "cosine", "sine", "atan2",
    "is-finite", "popcnt", "clz", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "rem", "map", "broadcast", "reshape",
    "transpose", "rev", "pad", "expm1", "log1p", "erf", "cbrt", "logistic",
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_WHILE_RE = re.compile(r"condition=%([\w\.\-]+), body=%([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shapes_bytes(type_str: str) -> int:
    return sum(_nelem(d) * _DTYPE_BYTES.get(t, 0)
               for t, d in _SHAPE_RE.findall(type_str))


def _nelem(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return m.group(1), m.group(2)


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # everything after the '('


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict          # op name -> type_str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line:
            continue
        if line[0] not in " }":
            # computation header: `%name (...` or `ENTRY %name ...`
            m = re.match(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[m.group(1)] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if cur is None or line.startswith("}"):
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = Op(name=m.group(1), type_str=m.group(2), opcode=m.group(3),
                rest=m.group(4))
        cur.ops.append(op)
        cur.symbols[op.name] = op.type_str
    return comps


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.opcode == "constant" and op.type_str.strip() == "s32[]":
            m = re.match(r"([0-9]+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, symbols: dict) -> float:
    out_elems = sum(_nelem(d) for _, d in _SHAPE_RE.findall(op.type_str))
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    k = 1
    mc = _LHS_CONTRACT_RE.search(op.rest)
    if operands and mc is not None:
        lhs_type = symbols.get(operands[0])
        if lhs_type:
            sh = _first_shape(lhs_type)
            if sh and sh[1]:
                dims = [int(x) for x in sh[1].split(",")]
                for ci in (int(x) for x in mc.group(1).split(",") if x):
                    if ci < len(dims):
                        k *= dims[ci]
    return 2.0 * out_elems * k


_PARAM_IDX_RE = re.compile(r"^(\d+)\)")


def _fusion_operand_bytes(op: Op, symbols: dict, comps: dict,
                          alias: dict | None = None) -> int:
    """Operand bytes of a fusion, slice-aware: a fusion parameter whose only
    consumers are dynamic-slice/gather reads its *slice*, not the whole
    array — this is how scanned layer stacks are accessed (one layer per
    trip), and charging the full stack per trip would overcount by n_layers.
    Slice bytes are scaled to the operand's STORED dtype (convert aliases).
    """
    alias = alias or {}
    mcall = _CALLS_RE.search(op.rest)
    callee = comps.get(mcall.group(1)) if mcall else None
    arg_str = op.rest.split("), ")[0]
    names = _OPERAND_RE.findall(arg_str)
    if callee is None:
        return sum(_stored_bytes(n, symbols, alias) for n in names)
    # parameter index -> op name inside the callee
    param_names = {}
    for cop in callee.ops:
        if cop.opcode == "parameter":
            m = _PARAM_IDX_RE.match(cop.rest)
            if m:
                param_names[int(m.group(1))] = cop.name
    total = 0
    for i, n in enumerate(names):
        observed = _shapes_bytes(symbols.get(n, ""))
        stored = _stored_bytes(n, symbols, alias)
        ratio = stored / observed if observed else 1.0
        pname = param_names.get(i)
        if pname is None:
            total += stored
            continue
        consumers = [c for c in callee.ops
                     if c.opcode != "parameter"
                     and pname in _OPERAND_RE.findall(
                         c.rest.split("), ")[0])]
        if consumers and all(c.opcode in ("dynamic-slice", "gather")
                             for c in consumers):
            total += ratio * sum(_shapes_bytes(c.type_str)
                                 for c in consumers)
        else:
            total += stored
    return int(total)


def _op_bytes(op: Op, symbols: dict, comps: dict | None = None,
              alias: dict | None = None) -> int:
    alias = alias or {}
    # in-place / windowed ops touch only the moved region, not the full
    # aliased operand (XLA performs DUS in place; DS reads its window):
    if op.opcode == "dynamic-update-slice":
        arg_str = op.rest.split("), ")[0]
        names = _OPERAND_RE.findall(arg_str)
        upd = symbols.get(names[1]) if len(names) > 1 else None
        return 2 * _shapes_bytes(upd) if upd else 0
    if op.opcode == "dynamic-slice":
        return 2 * _shapes_bytes(op.type_str)
    total = _shapes_bytes(op.type_str)
    if op.opcode == "fusion" and comps is not None:
        return total + _fusion_operand_bytes(op, symbols, comps, alias)
    # operand names up to the closing paren of the operand list
    arg_str = op.rest.split("), ")[0]
    for name in _OPERAND_RE.findall(arg_str):
        if name in symbols:
            total += _stored_bytes(name, symbols, alias)
    return total


@dataclasses.dataclass
class HloMetrics:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(default_factory=dict)
    loops: list = dataclasses.field(default_factory=list)


_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")


def _elementwise_only(comp: Computation) -> bool:
    """True if every op in the computation would fuse away on TPU."""
    return all(op.opcode in _FUSABLE_OPS or op.opcode in _FREE_OPS
               for op in comp.ops)


def _build_aliases(comps: dict, pure_elem: set) -> dict:
    """name -> source-name for dtype-changing pass-through ops.

    The CPU backend legalizes bf16 (and would-be int8) dots by hoisting a
    `convert` of the whole operand to f32; a TPU reads the STORED dtype and
    widens in registers.  Counting the converted copy would charge f32
    traffic for bf16/int8 storage, so byte lookups follow these aliases
    back to the stored tensor."""
    alias = {}
    seen_comps = set()
    for comp in comps.values():
        if id(comp) in seen_comps:      # skip the __entry__ duplicate
            continue
        seen_comps.add(id(comp))
        tuples = {}                      # tuple-op name -> element names
        for op in comp.ops:
            src = None
            if op.opcode == "convert":
                names = _OPERAND_RE.findall(op.rest.split("), ")[0])
                src = names[0] if names else None
            elif op.opcode == "fusion":
                mc = _CALLS_RE.search(op.rest)
                if mc and mc.group(1) in pure_elem:
                    names = _OPERAND_RE.findall(op.rest.split("), ")[0])
                    if len(names) == 1:
                        src = names[0]
            elif op.opcode == "tuple":
                tuples[op.name] = _OPERAND_RE.findall(
                    op.rest.split("), ")[0])
            elif op.opcode == "while":
                # bridge loop-invariant carries: body gte(param, i) aliases
                # the i-th element of the init tuple
                names = _OPERAND_RE.findall(op.rest.split("), ")[0])
                init = names[0] if names else None
                m = _WHILE_RE.search(op.rest)
                body = comps.get(m.group(2)) if m else None
                if init in tuples and body is not None:
                    elems = tuples[init]
                    for bop in body.ops:
                        if bop.opcode == "get-tuple-element":
                            mi = re.search(r"index=(\d+)", bop.rest)
                            if mi and int(mi.group(1)) < len(elems):
                                alias.setdefault(
                                    bop.name, elems[int(mi.group(1))])
            if src:
                alias[op.name] = src
    return alias


def _stored_bytes(name: str, symbols: dict, alias: dict) -> int:
    """Bytes of `name` at its stored dtype (following convert aliases),
    never larger than the observed type."""
    observed = _shapes_bytes(symbols.get(name, ""))
    seen = set()
    cur = name
    best = observed if observed else 1 << 62
    while cur in alias and cur not in seen:
        seen.add(cur)
        cur = alias[cur]
        b = _shapes_bytes(symbols.get(cur, ""))
        if b:
            best = min(best, b)
    return best if best < (1 << 62) else 0


def analyze_text(text: str) -> HloMetrics:
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return HloMetrics()
    # CPU-backend HLO wraps single elementwise ops into their own fusions;
    # on TPU those chains fuse into the adjacent dot/reduce, so their
    # boundary traffic is already covered by the dot's operands/results.
    pure_elem = {name for name, c in comps.items() if _elementwise_only(c)}
    alias = _build_aliases(comps, pure_elem)
    # module-global symbol table (names are unique in post-opt dumps)
    gsym: dict = {}
    for c in comps.values():
        gsym.update(c.symbols)
    metrics = HloMetrics()
    work = [(entry, 1.0)]
    seen_pairs = set()
    while work:
        comp, mult = work.pop()
        for op in comp.ops:
            if op.opcode == "while":
                m = _WHILE_RE.search(op.rest)
                if m and m.group(2) in comps:
                    trips = _trip_count(comps[m.group(1)]) \
                        if m.group(1) in comps else 1
                    key = (comp.name, op.name)
                    if key not in seen_pairs:
                        seen_pairs.add(key)
                        metrics.loops.append((op.name, trips, mult))
                        work.append((comps[m.group(2)], mult * trips))
                # the while op itself: carried tuple touched once per entry
                metrics.hbm_bytes += _shapes_bytes(op.type_str) * mult
                continue
            if op.opcode in _FREE_OPS:
                continue
            base = op.opcode.replace("-start", "")
            if op.opcode.endswith("-done"):
                continue
            if base in _COLLECTIVES:
                b = _shapes_bytes(op.type_str) * mult
                metrics.coll_bytes += b
                metrics.coll_breakdown[base] = \
                    metrics.coll_breakdown.get(base, 0.0) + b
                metrics.hbm_bytes += _op_bytes(op, gsym, comps,
                                               alias) * mult
                continue
            if op.opcode == "dot":
                metrics.flops += _dot_flops(op, comp.symbols) * mult
            if op.opcode == "convolution":
                # rare here; approximate with output*2*window elems parsed
                metrics.flops += 2.0 * _shapes_bytes(op.type_str) * mult
            if op.opcode in _FUSABLE_OPS:
                continue
            if op.opcode == "fusion":
                mcall = _CALLS_RE.search(op.rest)
                callee = comps.get(mcall.group(1)) if mcall else None
                if mcall and mcall.group(1) in pure_elem:
                    continue
                if callee is not None and all(
                        c.opcode in _FREE_OPS or c.opcode in _FUSABLE_OPS
                        or c.opcode in ("dynamic-slice", "gather")
                        for c in callee.ops):
                    # slice(+convert) fusion: a TPU reads the sliced input
                    # bytes at the STORED dtype and widens in-register —
                    # the widened result never round-trips HBM
                    metrics.hbm_bytes += _fusion_operand_bytes(
                        op, gsym, comps, alias) * mult
                    continue
            metrics.hbm_bytes += _op_bytes(op, gsym, comps, alias) * mult
    return metrics
