"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (all per-chip: the
compiled module is the SPMD per-device program, so its FLOPs/bytes are
already divided by the chip count):

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

Sources: `compiled.cost_analysis()` for FLOPs / bytes accessed;
collective bytes are NOT in cost_analysis — we parse `compiled.as_text()`
and sum the operand sizes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute op.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).

Oracle/consumer: `launch.hlo_analysis` is the loop-aware oracle for the
same three terms (this module trusts `cost_analysis()`, which under-counts
scanned bodies — the two are cross-checked in `tests/test_hlo_analysis`);
`launch.dryrun` attaches these terms to every compiled artifact and
`benchmarks/summarize_roofline.py` turns them into the paper-style
compute/memory/collective breakdown tables.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\b")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes of every collective op in an HLO module dump.

    Counts each op once (start/done pairs are deduped by skipping `-done`)
    and sums the bytes of the op's *output* shapes, which equal the
    bytes-on-the-wire for AG/AR/RS/A2A up to a small constant factor.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        m = _COLLECTIVE_RE.search(rhs)
        if not m:
            continue
        if m.group(2) == "-done":   # the -start op carries the shape
            continue
        kind = m.group(1)
        # result shapes live between '=' and the opcode
        result_part = rhs[:m.start()]
        total = sum(_shape_bytes(d, s)
                    for d, s in _SHAPE_RE.findall(result_part))
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO FLOPs
    hbm_bytes: float             # per-chip HLO bytes accessed
    coll_bytes: float            # per-chip collective bytes
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None   # 6*N*D (global)
    useful_ratio: Optional[float] = None  # model_flops / (flops * chips)
    xla_flops: float = 0.0                # cost_analysis cross-check
    xla_bytes: float = 0.0                # (loop bodies counted once)

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, chips: int, model_flops: Optional[float] = None,
            hlo_text: Optional[str] = None) -> Roofline:
    """Primary source: the loop-aware HLO analyzer (hlo_analysis), which
    multiplies while-loop bodies by their trip counts — XLA's own
    cost_analysis counts each loop body once and so under-counts every
    scanned-layer model.  cost_analysis is kept as a cross-check field."""
    from repro.launch.hlo_analysis import analyze_text
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hm = analyze_text(text)
    flops = hm.flops
    hbm = hm.hbm_bytes
    coll = {k: float(v) for k, v in hm.coll_breakdown.items()}
    coll_total = float(hm.coll_bytes)
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": coll_total / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / max(flops * chips, 1.0)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll_total,
        coll_breakdown=coll,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bottleneck,
        model_flops=model_flops, useful_ratio=useful,
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=float(cost.get("bytes accessed", 0.0)))


def model_flops_estimate(cfg, shape_kind: str, tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = *active* params
    for MoE."""
    from repro.models.registry import get_model
    model = get_model(cfg)
    n = model.param_count()
    if cfg.is_moe:
        # subtract the non-routed expert fraction: only top_k of n_experts
        # expert params are active per token
        import jax
        from repro.models.param import P
        import numpy as np
        spec = model.spec()
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                spec, is_leaf=lambda x: isinstance(x, P))[0]:
            key = jax.tree_util.keystr(path)
            if "moe" in key and ("wi" in key or "wg" in key or "wo" in key):
                expert += int(np.prod(leaf.shape))
        n = n - expert + expert * cfg.top_k / cfg.n_experts
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens
