"""Launch layer: meshes, step builders, dry-run, roofline."""
