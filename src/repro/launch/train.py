"""Training launcher: end-to-end driver around build_train_step.

Wires together: config -> model -> mesh -> sharded train step -> synthetic
data pipeline -> async checkpointing -> fault-tolerant supervisor loop.
On this CPU container it runs the smoke configs (examples/ use it for the
~100M RWKV-4 run); on a real pod the same code path drives the production
mesh — only `--mesh host` vs `--mesh pod` changes.

    PYTHONPATH=src python -m repro.launch.train --arch rwkv4-169m \
        --smoke --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import build_train_step
from repro.models.registry import get_model
from repro.runtime import StragglerDetector


def train(arch: str, *, smoke: bool = True, **kw):
    return train_model(get_model(arch, smoke=smoke), **kw)


def train_model(model, *, steps: int = 100,
                global_batch: int = 8, seq_len: int = 128, seed: int = 0,
                ckpt_dir: str | None = None, ckpt_every: int = 50,
                mesh_kind: str = "host", log_every: int = 10,
                resume: bool = True):
    cfg = model.cfg
    mesh = (make_host_mesh() if mesh_kind == "host"
            else make_production_mesh(multi_pod=mesh_kind == "multi"))
    shape = ShapeConfig("custom", seq_len, global_batch, "train")
    jitted, _, (p_sh, o_sh, b_sh), (init_opt, _) = build_train_step(
        model, mesh, shape)

    rng = jax.random.PRNGKey(seed)
    params = model.init_params(rng)
    opt_state = init_opt(params)
    start_step = 0
    ckpt = None
    if ckpt_dir:
        ckpt = AsyncCheckpointer(ckpt_dir)
        last = latest_step(ckpt_dir) if resume else None
        if last is not None:
            params = restore_checkpoint(ckpt_dir, last, params)
            opt_state = jax.tree_util.tree_map(
                lambda x: x, opt_state)  # counts restored via params only
            start_step = last
            print(f"resumed from step {last}")
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=seq_len,
                     global_batch=global_batch, seed=seed)
    losses = []
    detector = StragglerDetector([0])
    t_start = time.time()
    for step in range(start_step, steps):
        t0 = time.time()
        hb = ds.batch(step)
        batch = {k: jax.device_put(v, b_sh[k]) for k, v in hb.items()}
        params, opt_state, metrics = jitted(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        detector.record(0, time.time() - t0)
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            tok_s = global_batch * seq_len / max(dt, 1e-9)
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"{dt*1e3:6.1f} ms/step  {tok_s:,.0f} tok/s", flush=True)
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, params)
    if ckpt:
        ckpt.wait()
    wall = time.time() - t_start
    return {"losses": losses, "wall_s": wall, "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv4-169m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", choices=["host", "pod", "multi"],
                    default="host")
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                global_batch=args.batch, seq_len=args.seq,
                ckpt_dir=args.ckpt_dir, mesh_kind=args.mesh)
    print(f"final loss {out['losses'][-1]:.4f}  "
          f"wall {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
