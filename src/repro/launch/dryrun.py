"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines (before ANY other import): jax locks the
device count on first init, and only the dry-run wants 512 placeholder
devices."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import (   # noqa: E402
    ASSIGNED_ARCHS, RWKV4_ARCHS, SHAPES, get_config, supported_shapes)
from repro.launch import roofline as RL                   # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.steps import build_step_for_cell        # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mem_dict(mem) -> dict:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        if hasattr(mem, k):
            out[k] = int(getattr(mem, k))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             smoke: bool = False, save: bool = True,
             keep_text: bool = False, serve_variant: str = "base",
             cfg_overrides: dict | None = None,
             variant_tag: str = "") -> dict:
    cfg = get_config(arch)
    support = supported_shapes(cfg)[shape_name]
    mesh_tag = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_tag}"
    if serve_variant != "base":
        cell_id += f"__{serve_variant}"
    if variant_tag:
        cell_id += f"__{variant_tag}"
    if support != "ok":
        rec = {"cell": cell_id, "status": "skip", "reason": support}
        if save:
            _save(cell_id, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    try:
        jitted, args, kind = build_step_for_cell(
            arch, shape_name, mesh, smoke=smoke,
            serve_variant=serve_variant, cfg_overrides=cfg_overrides)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        shape = SHAPES[shape_name]
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind in ("train", "prefill")
                  else shape.global_batch)
        mf = RL.model_flops_estimate(cfg, shape.kind, tokens)
        text = compiled.as_text()
        roof = RL.analyze(compiled, chips=chips, model_flops=mf,
                          hlo_text=text)
        rec = {
            "cell": cell_id, "status": "ok", "kind": kind,
            "arch": arch, "shape": shape_name, "mesh": mesh_tag,
            "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": _mem_dict(mem),
            "roofline": roof.as_dict(),
        }
        if keep_text:
            rec["hlo_len"] = len(text)
    except Exception as e:  # a failing cell is a bug in our sharding
        rec = {"cell": cell_id, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    if save:
        _save(cell_id, rec)
    return rec


def _save(cell_id: str, rec: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{cell_id}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def all_cells(include_rwkv4: bool = False):
    archs = list(ASSIGNED_ARCHS) + (RWKV4_ARCHS if include_rwkv4 else [])
    for arch in archs:
        for shape_name in SHAPES:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (machinery test)")
    ap.add_argument("--rwkv4", action="store_true",
                    help="include the paper's rwkv4-* family")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells with an existing ok/skip record")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = [(a, s) for a, s in all_cells(args.rwkv4)
             if (args.arch is None or a == args.arch)
             and (args.shape is None or s == args.shape)]
    n_ok = n_skip = n_err = 0
    for arch, shape_name in cells:
        for multi in meshes:
            tag = "multi" if multi else "single"
            cell_id = f"{arch}__{shape_name}__{tag}"
            if args.skip_done:
                p = os.path.join(OUT_DIR, f"{cell_id}.json")
                if os.path.exists(p):
                    with open(p) as f:
                        old = json.load(f)
                    if old.get("status") in ("ok", "skip"):
                        continue
            rec = run_cell(arch, shape_name, multi, smoke=args.smoke)
            if rec["status"] == "ok":
                n_ok += 1
                r = rec["roofline"]
                print(f"[ok]   {cell_id}: bottleneck={r['bottleneck']} "
                      f"compute={r['compute_s']:.2e}s "
                      f"memory={r['memory_s']:.2e}s "
                      f"coll={r['collective_s']:.2e}s "
                      f"(compile {rec['compile_s']}s)", flush=True)
            elif rec["status"] == "skip":
                n_skip += 1
                print(f"[skip] {cell_id}: {rec['reason']}", flush=True)
            else:
                n_err += 1
                print(f"[ERR]  {cell_id}: {rec['error']}", flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skip, {n_err} error")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
