"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only
dryrun.py sets the 512-placeholder-device XLA flag).

Topology model (TPU v5e-class):
  single pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"model" is the intra-pod high-bandwidth ICI axis (TP/EP); "data" carries
FSDP + DP; "pod" is pure DP across the slow inter-pod links (gradient
all-reduce only — see repro.parallel.sharding's AXIS_RULES).
"""
from __future__ import annotations

import jax
import numpy as np

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1x1 mesh over the real local device (smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(devices: int = 0):
    """A 1-D ("data",) mesh over `devices` local devices (all when 0) —
    the serving engine's data-parallel topology: the slot pool and
    per-tick batch shard over "data", weights replicate (there is no
    "model" axis — serving decode is DP-only; see
    repro.parallel.sharding's pool helpers).  On a CPU host, spawn
    virtual devices first with
    XLA_FLAGS=--xla_force_host_platform_device_count=N (must be set
    before jax initializes)."""
    avail = jax.devices()
    n = len(avail) if devices in (0, None) else int(devices)
    if n > len(avail):
        raise ValueError(
            f"requested {n} devices but only {len(avail)} are visible "
            "(CPU hosts: set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before jax initializes)")
    return jax.sharding.Mesh(np.asarray(avail[:n]), ("data",))
