"""Step builders: train_step / prefill_step / serve_step with shardings.

These are the units the dry-run lowers and the launcher runs.  Every builder
returns (jitted_fn, input_specs, in_shardings) so dryrun.py can call
`.lower(*specs)` uniformly.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.models.registry import Model, get_model, loss_fn
from repro.optim import adamw, adafactor, cosine_schedule
from repro.parallel.sharding import (
    batch_spec, sharding_for, spec_for_axes, tree_shardings, use_mesh)


# ---------------------------------------------------------------------------
# Optimizer-state sharding: derive logical axes for moment trees
# ---------------------------------------------------------------------------


def make_optimizer(cfg: ModelConfig, *, lr=None):
    lr = lr if lr is not None else cosine_schedule(3e-4, 200, 10_000)
    if cfg.optimizer == "adafactor":
        return adafactor(lr)
    return adamw(lr)


def opt_state_axes(cfg: ModelConfig, param_axes, abstract_opt_state):
    """Moment trees inherit parameter axes; adafactor's factored vectors
    inherit the matching prefix/suffix of the parameter axes; scalars are
    replicated."""
    if cfg.optimizer == "adafactor":
        flat_p, tdef = jax.tree_util.tree_flatten(
            param_axes, is_leaf=lambda x: isinstance(x, tuple))
        flat_nu = tdef.flatten_up_to(abstract_opt_state.nu)

        def nu_axes(p_axes, nu_leaf):
            if "vr" in nu_leaf:
                return {"vr": p_axes[:-1],
                        "vc": p_axes[:-2] + p_axes[-1:]}
            return {"v": p_axes}
        nu = jax.tree_util.tree_unflatten(
            tdef, [nu_axes(p, n) for p, n in zip(flat_p, flat_nu)])
        return type(abstract_opt_state)(mu=None, nu=nu, count=())
    return type(abstract_opt_state)(
        mu=param_axes, nu=param_axes, count=())


def _axes_shardings(axes_tree, abstract_tree, mesh):
    """NamedSharding tree from (logical-axes tree, abstract tree)."""
    def leafify(axes, sds):
        return sharding_for(axes, sds.shape, mesh)
    return jax.tree_util.tree_map(
        leafify, axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


# ---------------------------------------------------------------------------
# Input specs per (arch x shape)
# ---------------------------------------------------------------------------


def batch_abstract(model: Model, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for a full-sequence batch (train / prefill)."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
    }
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.n_patches:
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def batch_shardings(model: Model, shape: ShapeConfig, mesh) -> dict:
    ab = batch_abstract(model, shape)
    return {k: NamedSharding(mesh, batch_spec(v.shape, mesh))
            for k, v in ab.items()}


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def build_train_step(model: Model, mesh, shape: ShapeConfig):
    """-> (jit(train_step), (abstract args), donate-aware shardings)."""
    cfg = model.cfg
    init_opt, update_opt = make_optimizer(cfg)
    param_axes = model.param_axes()
    abstract_params = model.abstract_params()
    p_sh = _axes_shardings(param_axes, abstract_params, mesh)
    abstract_opt = jax.eval_shape(init_opt, abstract_params)
    o_axes = opt_state_axes(cfg, param_axes, abstract_opt)
    o_sh = _axes_shardings(o_axes, abstract_opt, mesh)
    b_sh = batch_shardings(model, shape, mesh)

    def train_step(params, opt_state, batch):
        with use_mesh(mesh):
            grad_fn = jax.value_and_grad(
                lambda p: loss_fn(model, p, batch), has_aux=True)
            (loss, metrics), grads = grad_fn(params)
            new_params, new_opt = update_opt(grads, opt_state, params)
            return new_params, new_opt, metrics

    jitted = jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    args = (abstract_params, abstract_opt, batch_abstract(model, shape))
    return jitted, args, (p_sh, o_sh, b_sh), (init_opt, update_opt)


def build_prefill_step(model: Model, mesh, shape: ShapeConfig):
    """Inference prefill: forward pass producing logits (no state capture —
    the roofline subject is the forward compute)."""
    param_axes = model.param_axes()
    abstract_params = model.abstract_params()
    p_sh = _axes_shardings(param_axes, abstract_params, mesh)
    b_sh = batch_shardings(model, shape, mesh)

    def prefill_step(params, batch):
        with use_mesh(mesh):
            logits, _ = model.forward(params, batch)
            return logits

    jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
    args = (abstract_params, batch_abstract(model, shape))
    return jitted, args, (p_sh, b_sh)


def build_serve_step(model: Model, mesh, shape: ShapeConfig, *,
                     variant: str = "base"):
    """One decode step: new token against a seq_len-deep cache/state.

    variant:
      "base"       — bf16 weights, training sharding (FSDP+TP)     [paper-ø]
      "replicated" — bf16 weights replicated over 'data' (TP-only) [§Perf]
      "quantized"  — packed Δ-PoT W8 weights, TP-only: the paper's
                     deployment mode (half the weight HBM traffic) [paper ✓]
    """
    from repro.core.quant.serving import (
        packed_abstract, replicate_fsdp, serving_axes, unpack_params)
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    param_axes = model.param_axes()
    # serving takes bf16 weights (f32 masters are a training artifact)
    abstract_params = model.abstract_params(dtype=jnp.bfloat16)
    if variant in ("replicated", "quantized"):
        param_axes = replicate_fsdp(param_axes)
    if variant == "quantized":
        abstract_in = packed_abstract(model.spec(), abstract_params)
        axes_in = serving_axes(param_axes, abstract_in)
    else:
        abstract_in, axes_in = abstract_params, param_axes
    p_sh = _axes_shardings(axes_in, abstract_in, mesh)
    abstract_state = jax.eval_shape(
        lambda: model.init_decode_state(B, S))
    st_axes = model.decode_state_axes()
    st_sh = _axes_shardings(st_axes, abstract_state, mesh)
    tok_sh = NamedSharding(mesh, batch_spec((B, 1), mesh))

    def serve_step(params, state, tokens, pos):
        with use_mesh(mesh):
            if variant == "quantized":
                params = unpack_params(params)  # int8 -> bf16 inside jit
            logits, new_state = model.decode_step(params, state, tokens, pos)
            return logits, new_state

    jitted = jax.jit(
        serve_step,
        in_shardings=(p_sh, st_sh, tok_sh, None),
        out_shardings=(None, st_sh),
        donate_argnums=(1,),
    )
    args = (abstract_in, abstract_state,
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    return jitted, args, (p_sh, st_sh)


def build_step_for_cell(arch: str, shape_name: str, mesh, *,
                        smoke: bool = False, serve_variant: str = "base",
                        cfg_overrides: dict | None = None):
    """The dry-run entry: (arch, shape) -> (jitted, abstract args, kind)."""
    model = get_model(arch, smoke=smoke)
    if cfg_overrides:
        import dataclasses
        model = Model(cfg=dataclasses.replace(model.cfg, **cfg_overrides),
                      module=model.module)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        jitted, args, _, _ = build_train_step(model, mesh, shape)
        return jitted, args, "train_step"
    if shape.kind == "prefill":
        jitted, args, _ = build_prefill_step(model, mesh, shape)
        return jitted, args, "prefill_step"
    jitted, args, _ = build_serve_step(model, mesh, shape,
                                       variant=serve_variant)
    return jitted, args, f"serve_step[{serve_variant}]"
