"""Host-side distributed runtime: health, stragglers, elastic restarts."""
from repro.runtime.monitor import (
    HeartbeatMonitor, StragglerDetector, FailureInjector, TrainingSupervisor)

__all__ = ["HeartbeatMonitor", "StragglerDetector", "FailureInjector",
           "TrainingSupervisor"]
