"""Fault-tolerance + serving-telemetry runtime (host-side; no device code).

At thousands-of-nodes scale the failure model is: some host stops making
progress (hardware fault, preemption, network partition) or makes progress
anomalously slowly (straggler).  JAX SPMD programs cannot "route around" a
dead participant mid-step — the recovery unit is the *job*: detect, restore
the latest checkpoint onto the surviving topology (elastic reshard), resume.
This module provides the detection half plus a supervisor loop implementing
that policy, testable in-process via FailureInjector.

  ServingCounters   — throughput/latency telemetry for the continuous-
                      batching engine (repro.serving): tokens/s, TTFT
                      (with its prefill decomposition: per-request prefill
                      ticks, admit -> first-token wall time, and the
                      prefix-cache probe/state-copy slices split out so a
                      cache hit's TTFT is attributed honestly), per-token
                      inter-token-latency samples with p50/p90/p99 TTFT
                      and ITL in `snapshot()`, SLO-layer outcome counters
                      (shed / deadline-evicted / backpressured / cache
                      errors / budget-deferred prefill tokens), prefix-
                      cache hit/miss/eviction/spill counts with cached-vs-
                      prefilled token accounting, per-request latency,
                      slot occupancy
  HeartbeatMonitor  — per-host last-seen tracking with a dead-host predicate
  StragglerDetector — per-step duration EMA; flags hosts slower than
                      `threshold` x the fleet median (mitigation hook: the
                      caller re-balances or excludes the host at the next
                      restart boundary)
  FailureInjector   — deterministic fault schedule for training drills
  ServingFaultInjector — tick-indexed fault schedule for the serving
                      scheduler (cache-probe failures, forced evictions —
                      including from inside a token callback, i.e. mid-
                      speculation — forced deadline expiry, and the
                      crash-safety drills: in-process/SIGKILL crashes,
                      torn snapshot writes, poisoned state lanes)
  DegradedMode      — typed telemetry event for an automatic path
                      fallback (a repeatedly-faulting fused decode or
                      prefill path demoted to its per-op twin)
  EngineCrash       — the injected in-process crash (crash_at_tick)
  TrainingSupervisor— retry-with-restore driver around a step function

`ServingCounters` also exposes `state_dict()`/`load_state()` so the
serving snapshot layer (repro.serving.snapshot) can carry telemetry
across a crash: a restored engine's counters continue from the
snapshot, with per-request wall-clock anchors rebased onto the new
process's clock.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Callable, Optional


def percentile(xs: list, q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]) — 0.0 on an empty sample.

    Nearest-rank (not interpolated) so a p99 over latency samples is an
    actually-observed latency, never an average of two."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))]


class ServingCounters:
    """Serving-engine telemetry. The engine calls the on_* hooks; callers
    read `snapshot()` — a plain dict safe to log/export.  Timestamps use an
    injectable clock so tests are deterministic."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.t_start = clock()
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.ticks = 0
        self.admitted = 0
        self.finished = 0
        self.cancelled = 0
        self.peak_active = 0
        self.peak_queued = 0
        self._enqueue_t: dict[int, float] = {}
        self._admit_t: dict[int, float] = {}
        self._prefill_ticks: dict[int, int] = {}
        self.ttft_s: list[float] = []      # enqueue -> first token
        self.latency_s: list[float] = []   # enqueue -> completion
        # time-to-first-token decomposition: how many prefill calls each
        # request's prompt took, and the admit -> first-token wall time
        # (the part of TTFT the prefill path controls — queueing excluded).
        # prefill_s EXCLUDES the prefix-cache probe and state-copy time,
        # which land in their own lists below: attributing the whole admit
        # tick to "prefill" would make a cache hit look like prefill work.
        self.prefill_ticks: list[int] = []
        self.prefill_s: list[float] = []
        # prefix-cache telemetry (repro.serving.prefix_cache): probe
        # outcomes + token accounting from the scheduler, eviction/spill
        # flow from the cache itself
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_inserts = 0
        self.cache_evictions = 0
        self.cache_spills = 0
        self.cached_tokens = 0          # prompt tokens restored, not run
        self.cache_probe_s: list[float] = []
        self.state_copy_s: list[float] = []
        self._admit_overhead: dict[int, float] = {}  # rid -> probe+copy s
        # self-speculative decode telemetry (repro.serving scheduler's
        # _spec_tick): drafted counts every token the drafter proposed,
        # accepted the ones the verifier confirmed AND the lane consumed,
        # rejected the rest — acceptance_rate = accepted / drafted is the
        # one number that says whether a (K, draft_depth) choice pays
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.rejected_tokens = 0
        self.spec_ticks = 0             # per-lane window walks, not ticks
        # SLO-layer telemetry (repro.serving.slo): per-token inter-token
        # latency samples (gap between a lane's consecutive emitted
        # tokens — THE user-visible jitter the prefill budget bounds),
        # explicit-overload outcome counters, and robustness counters.
        self.itl_s: list[float] = []
        self._last_token_t: dict[int, float] = {}
        self.shed = 0
        self.deadline_evicted = 0
        self.backpressured = 0
        self.cache_errors = 0
        self.budget_deferred_tokens = 0
        # crash-safety telemetry (repro.serving.snapshot): snapshot writes
        # and their synchronous capture wall time, restores and the lanes
        # they resumed, sentinel quarantines, integrity-checksum failures,
        # and fused-path demotions (with their typed DegradedMode events)
        self.snapshots_written = 0
        self.snapshot_wall_s: list[float] = []
        self.restores = 0
        self.resumed_lanes = 0
        self.quarantined_lanes = 0
        self.checksum_failures = 0
        self.path_fallbacks = 0
        self.degraded_events: list[dict] = []
        # occupancy accumulators: mean active lanes / queue depth per tick
        # give the bench its latency-vs-occupancy axis
        self._active_sum = 0
        self._queued_sum = 0

    def now(self) -> float:
        """The counters' clock (injectable) — the scheduler times its
        cache probe/copy slices on the same clock the latency samples
        use, so the decomposition is exact under a fake clock."""
        return self._clock()

    # -- hooks (called by the engine/scheduler) ----------------------------
    def on_enqueue(self, rid: int):
        self._enqueue_t[rid] = self._clock()

    def on_admit(self, rid: int):
        self.admitted += 1
        self._admit_t[rid] = self._clock()

    def on_prefill(self, rid: int, n_tokens: int):
        """One prefill call absorbed `n_tokens` of request `rid`'s prompt."""
        self.prefill_tokens += n_tokens
        self._prefill_ticks[rid] = self._prefill_ticks.get(rid, 0) + 1

    def on_cache_probe(self, rid: int, *, hit: bool, n_cached: int = 0,
                       probe_s: float = 0.0, copy_s: float = 0.0):
        """One prefix-cache probe at request `rid`'s admission: outcome,
        tokens restored from the hit state (0 on miss), and the wall time
        of the probe and of the state copy into the slot.  Probe+copy are
        subtracted from the request's `prefill_s` sample — they are cache
        time, not prefill time."""
        if hit:
            self.cache_hits += 1
            self.cached_tokens += n_cached
        else:
            self.cache_misses += 1
        self.cache_probe_s.append(probe_s)
        if hit:
            self.state_copy_s.append(copy_s)
        self._admit_overhead[rid] = \
            self._admit_overhead.get(rid, 0.0) + probe_s + copy_s

    def on_cache_insert(self):
        self.cache_inserts += 1

    def on_cache_evict(self):
        self.cache_evictions += 1

    def on_cache_spill(self):
        self.cache_spills += 1

    def on_speculate(self, rid: int, *, drafted: int, accepted: int):
        """One lane finished one speculative window walk: the drafter
        proposed `drafted` tokens, the verifier confirmed `accepted` of
        them (0 <= accepted <= drafted; the window's base token is not a
        draft and is not counted).  Emitted-token accounting stays with
        `on_token` — speculation changes how many decode tokens a tick
        produces, not what a token is."""
        del rid
        self.spec_ticks += 1
        self.drafted_tokens += drafted
        self.accepted_tokens += accepted
        self.rejected_tokens += drafted - accepted

    def on_token(self, rid: int, *, first: bool = False):
        self.decode_tokens += 1
        now = self._clock()
        if first:
            if rid in self._enqueue_t:
                self.ttft_s.append(now - self._enqueue_t[rid])
            t_admit = self._admit_t.pop(rid, None)
            if t_admit is not None:
                self.prefill_s.append(now - t_admit -
                                      self._admit_overhead.pop(rid, 0.0))
            self.prefill_ticks.append(self._prefill_ticks.pop(rid, 0))
        else:
            t_prev = self._last_token_t.get(rid)
            if t_prev is not None:
                self.itl_s.append(now - t_prev)
        self._last_token_t[rid] = now

    def on_finish(self, rid: int):
        self.finished += 1
        t0 = self._enqueue_t.pop(rid, None)
        if t0 is not None:
            self.latency_s.append(self._clock() - t0)
        self._last_token_t.pop(rid, None)

    def _drop(self, rid: int):
        """Forget a request that will never complete (cancel/shed/
        deadline): no latency sample, no stale per-rid state."""
        self._enqueue_t.pop(rid, None)
        self._admit_t.pop(rid, None)
        self._prefill_ticks.pop(rid, None)
        self._admit_overhead.pop(rid, None)
        self._last_token_t.pop(rid, None)

    def on_cancel(self, rid: int):
        """Evicted before completion: not a completion, no latency sample."""
        self.cancelled += 1
        self._drop(rid)

    def on_shed(self, rid: int):
        """Dropped from the queue by the shed overload policy."""
        self.shed += 1
        self._drop(rid)

    def on_deadline_evict(self, rid: int):
        """Deadline exceeded (queued or in-flight): evicted, not finished."""
        self.deadline_evicted += 1
        self._drop(rid)

    def on_backpressure(self):
        """An `enqueue` was refused with `Overloaded` (queue full)."""
        self.backpressured += 1

    def on_cache_error(self):
        """A prefix-cache probe/insert raised; serving degraded to a miss
        instead of dying — counted so faults are observable."""
        self.cache_errors += 1

    def on_budget_defer(self, n_tokens: int):
        """The prefill budget deferred `n_tokens` of ready prompt chunks
        to a later tick (lanes left out of this tick's prefill call)."""
        self.budget_deferred_tokens += n_tokens

    def on_snapshot(self, wall_s: float):
        """One engine snapshot committed to the store; `wall_s` is the
        SYNCHRONOUS capture time (host copies + checksum verify — the part
        decode actually waits on; the file write is async)."""
        self.snapshots_written += 1
        self.snapshot_wall_s.append(wall_s)

    def on_restore(self, *, resumed_lanes: int):
        """The engine was rebuilt from a snapshot, resuming
        `resumed_lanes` in-flight/queued requests."""
        self.restores += 1
        self.resumed_lanes += resumed_lanes

    def on_quarantine(self, rid: int):
        """A NaN/Inf state sentinel quarantined `rid`'s lane; the request
        is re-enqueued for a deterministic replay (its per-rid latency
        anchors reset with it — the requeue re-arms them)."""
        self.quarantined_lanes += 1
        self._drop(rid)

    def on_checksum_failure(self, n: int = 1):
        """Integrity sentinels found `n` corrupt weight planes."""
        self.checksum_failures += n

    def on_path_fallback(self, event):
        """A repeatedly-faulting fused path was demoted to its per-op
        twin; `event` is the typed `DegradedMode` record."""
        self.path_fallbacks += 1
        self.degraded_events.append(dataclasses.asdict(event)
                                    if dataclasses.is_dataclass(event)
                                    else dict(event))

    def on_tick(self, *, active: int, queued: int):
        self.ticks += 1
        self.peak_active = max(self.peak_active, active)
        self.peak_queued = max(self.peak_queued, queued)
        self._active_sum += active
        self._queued_sum += queued

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        dt = max(self._clock() - self.t_start, 1e-9)
        mean = lambda xs: sum(xs) / len(xs) if xs else 0.0
        return {
            "elapsed_s": dt,
            "ticks": self.ticks,
            "admitted": self.admitted,
            "finished": self.finished,
            "cancelled": self.cancelled,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "decode_tokens_per_s": self.decode_tokens / dt,
            "total_tokens_per_s":
                (self.prefill_tokens + self.decode_tokens) / dt,
            "mean_ttft_s": mean(self.ttft_s),
            "ttft_p50_s": percentile(self.ttft_s, 0.50),
            "ttft_p90_s": percentile(self.ttft_s, 0.90),
            "ttft_p99_s": percentile(self.ttft_s, 0.99),
            "mean_itl_s": mean(self.itl_s),
            "itl_p50_s": percentile(self.itl_s, 0.50),
            "itl_p90_s": percentile(self.itl_s, 0.90),
            "itl_p99_s": percentile(self.itl_s, 0.99),
            "mean_latency_s": mean(self.latency_s),
            "latency_p99_s": percentile(self.latency_s, 0.99),
            "shed": self.shed,
            "deadline_evicted": self.deadline_evicted,
            "backpressured": self.backpressured,
            "cache_errors": self.cache_errors,
            "budget_deferred_tokens": self.budget_deferred_tokens,
            "mean_active_slots": self._active_sum / self.ticks
                if self.ticks else 0.0,
            "mean_queue_depth": self._queued_sum / self.ticks
                if self.ticks else 0.0,
            "mean_prefill_ticks": mean(self.prefill_ticks),
            "mean_prefill_s": mean(self.prefill_s),
            "peak_active_slots": self.peak_active,
            "peak_queue_depth": self.peak_queued,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hits /
                (self.cache_hits + self.cache_misses)
                if self.cache_hits + self.cache_misses else 0.0,
            "cache_inserts": self.cache_inserts,
            "cache_evictions": self.cache_evictions,
            "cache_spills": self.cache_spills,
            "cached_tokens": self.cached_tokens,
            "mean_cache_probe_s": mean(self.cache_probe_s),
            "mean_state_copy_s": mean(self.state_copy_s),
            "spec_ticks": self.spec_ticks,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "rejected_tokens": self.rejected_tokens,
            "acceptance_rate": self.accepted_tokens / self.drafted_tokens
                if self.drafted_tokens else 0.0,
            "snapshots_written": self.snapshots_written,
            "snapshot_wall_s": mean(self.snapshot_wall_s),
            "restores": self.restores,
            "resumed_lanes": self.resumed_lanes,
            "quarantined_lanes": self.quarantined_lanes,
            "checksum_failures": self.checksum_failures,
            "path_fallbacks": self.path_fallbacks,
        }

    # -- snapshot/restore (repro.serving.snapshot) -------------------------

    _COUNTER_FIELDS = (
        "prefill_tokens", "decode_tokens", "ticks", "admitted", "finished",
        "cancelled", "peak_active", "peak_queued", "cache_hits",
        "cache_misses", "cache_inserts", "cache_evictions", "cache_spills",
        "cached_tokens", "drafted_tokens", "accepted_tokens",
        "rejected_tokens", "spec_ticks", "shed", "deadline_evicted",
        "backpressured", "cache_errors", "budget_deferred_tokens",
        "snapshots_written", "restores", "resumed_lanes",
        "quarantined_lanes", "checksum_failures", "path_fallbacks",
        "_active_sum", "_queued_sum")
    _LIST_FIELDS = (
        "ttft_s", "latency_s", "prefill_ticks", "prefill_s",
        "cache_probe_s", "state_copy_s", "itl_s", "snapshot_wall_s",
        "degraded_events")
    _TIME_DICT_FIELDS = (    # rid -> absolute clock time, rebased on load
        "_enqueue_t", "_admit_t", "_last_token_t")

    def state_dict(self) -> dict:
        """Everything `load_state` needs to continue this telemetry in a
        NEW process: plain JSON.  Absolute clock anchors (the per-rid
        enqueue/admit/last-token times and the run start) are stored as
        seconds-before-capture, so a restore on a different monotonic
        clock keeps elapsed/latency math consistent."""
        now = self._clock()
        out = {f: getattr(self, f) for f in self._COUNTER_FIELDS}
        out.update({f: list(getattr(self, f)) for f in self._LIST_FIELDS})
        out["elapsed_s"] = now - self.t_start
        for f in self._TIME_DICT_FIELDS:
            out[f] = {str(rid): now - t
                      for rid, t in getattr(self, f).items()}
        out["_prefill_ticks"] = {str(r): n
                                 for r, n in self._prefill_ticks.items()}
        out["_admit_overhead"] = {str(r): v
                                  for r, v in self._admit_overhead.items()}
        return out

    def load_state(self, state: dict):
        """Install a `state_dict` capture, rebasing clock anchors onto
        this counters object's own clock."""
        now = self._clock()
        for f in self._COUNTER_FIELDS:
            setattr(self, f, state[f])
        for f in self._LIST_FIELDS:
            setattr(self, f, list(state[f]))
        self.t_start = now - state["elapsed_s"]
        for f in self._TIME_DICT_FIELDS:
            setattr(self, f, {int(r): now - ago
                              for r, ago in state[f].items()})
        self._prefill_ticks = {int(r): n
                               for r, n in state["_prefill_ticks"].items()}
        self._admit_overhead = {int(r): v
                                for r, v in state["_admit_overhead"].items()}


class HeartbeatMonitor:
    def __init__(self, hosts: list[int], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last = {h: clock() for h in hosts}

    def beat(self, host: int, at: Optional[float] = None):
        self._last[host] = self._clock() if at is None else at

    def dead_hosts(self) -> list[int]:
        now = self._clock()
        return [h for h, t in self._last.items()
                if now - t > self.timeout_s]

    def alive_hosts(self) -> list[int]:
        dead = set(self.dead_hosts())
        return [h for h in self._last if h not in dead]


class StragglerDetector:
    """EMA of per-host step durations; flags hosts above threshold x median."""

    def __init__(self, hosts: list[int], *, alpha: float = 0.2,
                 threshold: float = 1.5, warmup_steps: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self._ema = {h: None for h in hosts}
        self._n = collections.Counter()

    def record(self, host: int, duration_s: float):
        prev = self._ema[host]
        self._ema[host] = (duration_s if prev is None
                           else self.alpha * duration_s +
                           (1 - self.alpha) * prev)
        self._n[host] += 1

    def stragglers(self) -> list[int]:
        vals = [(h, e) for h, e in self._ema.items()
                if e is not None and self._n[h] >= self.warmup_steps]
        if len(vals) < 3:
            return []
        ordered = sorted(e for _, e in vals)
        median = ordered[len(ordered) // 2]
        return [h for h, e in vals if e > self.threshold * median]


@dataclasses.dataclass
class FailureInjector:
    """step -> host failures, for drills. `check(step)` raises HostFailure."""

    schedule: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    enabled: bool = True

    def check(self, step: int):
        if self.enabled and step in self.schedule:
            hosts = self.schedule.pop(step)
            raise HostFailure(step=step, hosts=hosts)


class HostFailure(RuntimeError):
    def __init__(self, step: int, hosts: list[int]):
        super().__init__(f"hosts {hosts} failed at step {step}")
        self.step = step
        self.hosts = hosts


@dataclasses.dataclass(frozen=True)
class DegradedMode:
    """Typed telemetry event for an automatic path fallback: the serving
    scheduler demoted a repeatedly-faulting fused `kind` ("decode" or
    "prefill") path to its per-op `PathDescriptor` twin.  Streams are
    unchanged — per-op and fused paths are bit-identical by construction
    — so a demotion costs throughput, never correctness; the event makes
    the degradation observable (`ServingCounters.degraded_events`)."""
    kind: str           # "decode" | "prefill"
    tick: int           # scheduler tick the demotion happened on
    failures: int       # consecutive failures that triggered it
    from_path: str      # the demoted PathDescriptor name
    to_path: str        # the twin now serving ("per_op")
    error: str          # repr of the last exception


class EngineCrash(RuntimeError):
    """The injected in-process serving crash (`crash_at_tick`): raised at
    the top of the scheduled tick, BEFORE any of that tick's work — the
    crash point every committed snapshot must be consistent against."""

    def __init__(self, tick: int):
        super().__init__(f"injected engine crash at tick {tick}")
        self.tick = tick


@dataclasses.dataclass
class ServingFaultInjector:
    """Tick-indexed fault schedule for the serving scheduler — the
    serving-side sibling of `FailureInjector` (which targets training
    steps).  The scheduler drains `pop(tick)` at the top of each tick
    and applies the faults, so churn tests can force the nasty cases at
    exact points in a request's life:

      ("cache_probe_error", None) — the next prefix-cache probe raises;
          the scheduler must degrade to a miss, never crash or leak a
          lease.
      ("evict", rid)              — evict `rid` at the top of the tick
          (queued or in-flight), exercising mid-prefill cancellation.
      ("evict_on_token", rid)     — evict `rid` from INSIDE its next
          token callback, i.e. mid-tick / mid-speculation: drafts must
          be discarded and the tick must finish cleanly.
      ("deadline", rid)           — force `rid`'s deadline to expire
          now, whether or not it had one.
      ("crash_at_tick", None|"raise"|"sigkill") — kill the engine at the
          top of the tick: raise `EngineCrash` (default), or SIGKILL the
          process ("sigkill" — the CI crash-recovery smoke, nothing gets
          to flush).  Restore-from-snapshot must resume bit-identically.
      ("torn_snapshot_write", None) — the NEXT snapshot write is torn:
          a partial `.tmp-step_X` with no COMMIT, exactly what a crash
          mid-write leaves behind.  Restore must refuse it and fall back
          to the previous committed step.
      ("corrupt_state_leaf", rid) — poison `rid`'s live lane state with
          NaNs; the sentinel sweep must quarantine-and-requeue it
          without leaking the slot or any cache lease.

    `fired` records (tick, kind, payload) for every fault actually
    delivered, so tests can assert the drill ran."""

    schedule: dict[int, list[tuple[str, Any]]] = \
        dataclasses.field(default_factory=dict)
    enabled: bool = True
    fired: list[tuple[int, str, Any]] = \
        dataclasses.field(default_factory=list)

    KINDS = ("cache_probe_error", "evict", "evict_on_token", "deadline",
             "crash_at_tick", "torn_snapshot_write", "corrupt_state_leaf")

    def pop(self, tick: int) -> list[tuple[str, Any]]:
        if not self.enabled:
            return []
        faults = self.schedule.pop(tick, [])
        for kind, _ in faults:
            if kind not in self.KINDS:
                raise ValueError(f"unknown serving fault kind {kind!r}")
        self.fired.extend((tick, k, p) for k, p in faults)
        return list(faults)


class TrainingSupervisor:
    """Retry-with-restore driver.

    run(n_steps) calls `step_fn(step)`; on HostFailure it invokes
    `restore_fn(failed_hosts)` (which reloads the latest checkpoint, possibly
    onto a smaller/elastic mesh) and resumes from the step the restore
    reports.  Gives up after `max_restarts`.
    """

    def __init__(self, step_fn: Callable[[int], None],
                 restore_fn: Callable[[list[int]], int],
                 max_restarts: int = 3):
        self.step_fn = step_fn
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.restarts = 0
        self.log: list[str] = []

    def run(self, n_steps: int, start_step: int = 0) -> int:
        step = start_step
        while step < n_steps:
            try:
                self.step_fn(step)
                step += 1
            except HostFailure as f:
                self.restarts += 1
                self.log.append(f"failure at step {f.step}: hosts {f.hosts}")
                if self.restarts > self.max_restarts:
                    raise
                step = self.restore_fn(f.hosts)
                self.log.append(f"restored, resuming at step {step}")
        return step
