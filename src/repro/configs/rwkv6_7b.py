"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free.
[arXiv:2404.05892; hf]
32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
head size 64 -> 64 wkv heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab=65536, norm="layernorm",
    rwkv_version=6, rwkv_head_dim=64,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, norm="layernorm",
    rwkv_version=6, rwkv_head_dim=16,
)
