"""minicpm3-4b [dense] — MLA (multi-head latent attention).
[hf:openbmb/MiniCPM3-4B; hf]
62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448
MLA ranks follow the HF config: q_lora 768, kv_lora 256, qk_nope 64,
qk_rope 32, v_head 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448, act="swiglu", norm="rmsnorm",
    use_mla=True, q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    shard_kv_seq=False,  # §Perf: MLA latent cache is small; gather dominates
)

SMOKE = ModelConfig(
    name="minicpm3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, act="swiglu", norm="rmsnorm",
    use_mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
)
