"""whisper-medium [audio] — encoder-decoder, conv frontend stubbed.
[arXiv:2212.04356; unverified]
24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865
Per the assignment: the modality frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, 1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, act="gelu", norm="layernorm",
    enc_layers=24, enc_frames=1500, rope_theta=0.0,  # learned pos emb, no rope
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, act="gelu", norm="layernorm",
    enc_layers=2, enc_frames=32, rope_theta=0.0,
)
