"""internvl2-2b [vlm] — InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; hf]
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
Per the assignment: the ViT frontend is a STUB — input_specs() provides
precomputed patch embeddings (B, 256, d_model) prepended to the text.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, act="swiglu", norm="rmsnorm",
    n_patches=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, act="swiglu", norm="rmsnorm",
    n_patches=8,
)
