"""smollm-135m [dense] — llama-arch small.
[hf:HuggingFaceTB/SmolLM-135M; hf]
30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152, act="swiglu", norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, act="swiglu", norm="rmsnorm",
    tie_embeddings=True,
)
