"""llama4-maverick-400b-a17b [moe] — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1

`moe_every=2` (interleaved dense/MoE layers, Llama-4's published layout)
makes the per-layer dims consistent with the ~400B total; see DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, act="swiglu", norm="rmsnorm",
    n_experts=128, top_k=1, moe_every=2, capacity_factor=1.25,
    optimizer="adafactor",  # full Adam moments would not fit a 256-chip pod
    shard_kv_seq=False,     # §Perf: 40-head gather costs more than it saves
)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=256, act="swiglu", norm="rmsnorm",
    n_experts=8, top_k=1, moe_every=2, capacity_factor=2.0,
)
