from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, get_config, list_configs,
    supported_shapes, smoke_config,
)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config",
           "list_configs", "supported_shapes", "smoke_config"]
