"""Model + shape configs: the paper's rwkv4 family and the assigned
architectures, each behind `get_config` / `smoke_config` (see base.py)."""
from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, get_config, list_configs,
    supported_shapes, smoke_config,
)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config",
           "list_configs", "supported_shapes", "smoke_config"]
