"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]
81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
Shared transformer block applied every 6 mamba layers on
concat(hidden, embedding) (the Zamba concatenation trick).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, act="swiglu", norm="rmsnorm",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, act="swiglu", norm="rmsnorm",
    ssm_state=16, ssm_head_dim=16, ssm_expand=2,
    shared_attn_every=3,
)
