"""RWKV-4 — the paper's own model family (BlinkDL/rwkv-4-pile sizes).

  169M: L12 D768     430M: L24 D1024    1.5B: L24 D2048
  3B:   L32 D2560    7B:   L32 D4096
vocab 50277 (pile tokenizer), LayerNorm, channel-mix d_ff = 4·d_model.
"""
from repro.configs.base import ModelConfig

_SIZES = {
    "rwkv4-169m": (12, 768),
    "rwkv4-430m": (24, 1024),
    "rwkv4-1b5": (24, 2048),
    "rwkv4-3b": (32, 2560),
    "rwkv4-7b": (32, 4096),
}


def get(arch_id: str) -> ModelConfig:
    n_layers, d_model = _SIZES[arch_id]
    return ModelConfig(
        name=arch_id, family="rwkv",
        n_layers=n_layers, d_model=d_model,
        n_heads=1, n_kv_heads=1,          # rwkv4 is channel-wise (no heads)
        d_ff=4 * d_model, vocab=50277, norm="layernorm",
        rwkv_version=4,
    )


def smoke(arch_id: str) -> ModelConfig:
    return ModelConfig(
        name=f"{arch_id}-smoke", family="rwkv",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=256, vocab=256, norm="layernorm", rwkv_version=4,
    )
