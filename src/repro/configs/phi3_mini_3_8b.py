"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA (MHA: kv == heads).
[arXiv:2404.14219; unverified]
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, act="swiglu", norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="phi3-mini-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=256, act="swiglu", norm="rmsnorm",
)
