"""Model / shape configuration system.

`get_config(arch_id)` returns the exact published configuration for any of
the ten assigned architectures (plus the paper's own rwkv4-* family);
`smoke_config(arch_id)` returns a reduced same-family config for CPU smoke
tests; `SHAPES` is the assigned input-shape set.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# families that run long_500k (sub-quadratic decode state)
_SUBQUADRATIC = {"ssm", "hybrid", "rwkv"}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | ssm | hybrid | audio | vlm | rwkv
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    act: str = "swiglu"                     # swiglu | gelu | relu_sq
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                      # MoE layer every Nth layer
    capacity_factor: float = 1.25
    # grouped per-sequence dispatch: local cumsum + scatter, bf16 payload,
    # all-to-all resharding instead of buffer all-reduce (§Perf)
    moe_grouped: bool = False
    # --- MLA (MiniCPM3 / DeepSeek-style) ---
    use_mla: bool = False
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    # --- RWKV / SSM ---
    rwkv_version: int = 0                   # 4 or 6 (0 = not rwkv)
    rwkv_head_dim: int = 64                 # rwkv6 head size
    ssm_state: int = 64                     # mamba2 state dim
    ssm_head_dim: int = 64                  # mamba2 head (value) dim
    ssm_expand: int = 2                     # mamba2 inner = expand*d_model
    shared_attn_every: int = 0              # zamba2: shared block period
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 1_500
    # --- VLM ---
    n_patches: int = 0                      # prepended patch embeddings
    # --- training ---
    remat: bool = True
    dtype: str = "bfloat16"
    optimizer: str = "adamw"                # adamw | adafactor
    # route full-sequence attention through the Pallas fused flash kernel
    # (scores never touch HBM). Off by default: the XLA path is the
    # paper-agnostic baseline the §Perf table starts from.
    use_flash_kernel: bool = False
    # dry-run instrumentation: replace attention with a zero-flop stub so
    # the roofline diff (base - stub) isolates attention's traffic/flops —
    # the measurement half of the fused-kernel projection (§Perf).
    attn_stub: bool = False
    # same instrumentation for the WKV recurrence (rwkv4): isolates the
    # recurrence's traffic for the wkv4-kernel projection (§Perf)
    wkv_stub: bool = False
    # --- serving ---
    # shard the KV-cache sequence dim over spare mesh axes (SP). Pays a
    # per-step gather; worth it when the cache dominates HBM and heads
    # cannot shard — measured per-arch in EXPERIMENTS.md §Perf.
    shard_kv_seq: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.rwkv_version in (4, 6)


# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "whisper-medium": "whisper_medium",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "smollm-135m": "smollm_135m",
    "minicpm3-4b": "minicpm3_4b",
    "minitron-4b": "minitron_4b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-7b": "zamba2_7b",
    "internvl2-2b": "internvl2_2b",
    # the paper's own model family
    "rwkv4-169m": "rwkv4_family",
    "rwkv4-430m": "rwkv4_family",
    "rwkv4-1b5": "rwkv4_family",
    "rwkv4-3b": "rwkv4_family",
    "rwkv4-7b": "rwkv4_family",
}

ASSIGNED_ARCHS = [
    "whisper-medium", "moonshot-v1-16b-a3b", "llama4-maverick-400b-a17b",
    "smollm-135m", "minicpm3-4b", "minitron-4b", "phi3-mini-3.8b",
    "rwkv6-7b", "zamba2-7b", "internvl2-2b",
]

RWKV4_ARCHS = ["rwkv4-169m", "rwkv4-430m", "rwkv4-1b5", "rwkv4-3b",
               "rwkv4-7b"]


def list_configs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_configs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.get(arch_id) if hasattr(mod, "get") else mod.CONFIG


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.smoke(arch_id) if hasattr(mod, "smoke") else mod.SMOKE


def supported_shapes(cfg: ModelConfig) -> dict[str, str]:
    """shape name -> "ok" or a skip reason (DESIGN.md §Arch-applicability)."""
    out = {}
    for name, shape in SHAPES.items():
        if name == "long_500k" and cfg.family not in _SUBQUADRATIC:
            out[name] = ("skip: full-attention arch — 500k-token decode "
                         "needs sub-quadratic attention")
        else:
            out[name] = "ok"
    return out
