"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, act="swiglu", norm="rmsnorm",
    n_experts=64, top_k=6, moe_every=1, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=48, vocab=256, act="swiglu", norm="rmsnorm",
    n_experts=8, top_k=2, moe_every=1, capacity_factor=1.5,
)
