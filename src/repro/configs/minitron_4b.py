"""minitron-4b [dense] — pruned nemotron (GELU MLP, large vocab).
[arXiv:2407.14679; hf]
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab=256000, act="relu_sq", norm="layernorm",
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab=512, act="relu_sq", norm="layernorm",
)
