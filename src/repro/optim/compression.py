"""Gradient compression with error feedback (distributed-opt trick).

Int8 symmetric per-tensor quantization of gradients before the cross-pod
all-reduce (4x less inter-pod traffic at bf16->int8... here f32->int8 = 8x),
with an error-feedback accumulator so the quantization error is re-injected
next step (Seide et al. 2014 / EF-SGD): convergence is preserved because the
error is bounded and averaged out, while the collective term of the roofline
drops by the compression ratio.

Usage in a train step (the launcher wires this when cfg enables it):

    ef, cg = compress_grads_int8(grads, ef)
    cg     = jax.lax.pmean(cg, "pod")        # or psum under pjit
    grads  = decompress_grads_int8(cg)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: Any  # same tree as grads, f32


def init_error_feedback(params) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _q_leaf(g, r):
    g32 = g.astype(jnp.float32) + r
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.where(amax <= 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    err = g32 - q.astype(jnp.float32) * scale
    return q, scale, err


def compress_grads_int8(grads, ef: ErrorFeedback):
    """-> (new_ef, {"q": int8 tree, "scale": f32 tree})."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    qs, scales, errs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, e = _q_leaf(g, r)
        qs.append(q), scales.append(s), errs.append(e)
    unf = lambda xs: jax.tree_util.tree_unflatten(tdef, xs)
    return (ErrorFeedback(residual=unf(errs)),
            {"q": unf(qs), "scale": unf(scales)})


def decompress_grads_int8(cg) -> Any:
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, cg["q"], cg["scale"])
