"""Optimizers and distributed-training tricks (pure-JAX, optax-style)."""
from repro.optim.optimizers import (
    adamw, adafactor, OptState, clip_by_global_norm)
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.optim.compression import (
    compress_grads_int8, decompress_grads_int8, ErrorFeedback)

__all__ = [
    "adamw", "adafactor", "OptState", "clip_by_global_norm",
    "cosine_schedule", "linear_warmup",
    "compress_grads_int8", "decompress_grads_int8", "ErrorFeedback",
]
