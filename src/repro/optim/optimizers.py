"""Optimizers, optax-style (init/update pairs) but self-contained.

  adamw     — AdamW with decoupled weight decay; moments in f32
  adafactor — factored second moments (row/col) for the 400B-class configs
              where full Adam moments would not fit HBM

Both return `(init_fn, update_fn)`:
  init_fn(params)                         -> OptState
  update_fn(grads, state, params, step)   -> (new_params, new_state)

Sharding: moment trees inherit the parameter logical axes (the launcher
applies the same tree_shardings to them), so FSDP shards optimizer state too.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: Any        # first moment  (adamw) | None
    nu: Any        # second moment (adamw) | factored dict (adafactor)
    count: jnp.ndarray


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw(lr: Callable | float, *, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1, clip_norm: float | None = 1.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(mu=zeros(), nu=zeros(),
                        count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, step=None):
        count = state.count + 1
        step = count if step is None else step
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        g32 = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if p.ndim >= 2:  # decay matrices only (standard practice)
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, OptState(mu=mu, nu=nu, count=count)

    return init, update


def adafactor(lr: Callable | float, *, decay=0.8, eps=1e-30,
              clip_threshold=1.0, weight_decay=0.0,
              min_dim_size_to_factor=128):
    """Factored Adafactor (Shazeer & Stern 2018), no first moment.

    Tensors whose two trailing dims are both >= min_dim_size_to_factor keep
    only row/col second-moment vectors — O(n+m) instead of O(nm) state, the
    trick that lets a 400B-param optimizer fit a (16,16) pod's HBM.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor
                and p.shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def per_leaf(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(mu=None, nu=jax.tree_util.tree_map(
            per_leaf, params), count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, step=None):
        count = state.count + 1
        step = count if step is None else step
        t = count.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)          # increasing-decay schedule
        lr_t = lr_fn(step)

        def upd(p, g, v):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_v = {"vr": vr, "vc": vc}
            else:
                vhat = beta * v["v"] + (1 - beta) * g2
                new_v = {"v": vhat}
            u = g32 / jnp.sqrt(vhat + eps)
            # update clipping (RMS-capped), the adafactor stabilizer
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state.nu)
        outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        new_nu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        return new_params, OptState(mu=None, nu=new_nu, count=count)

    return init, update
