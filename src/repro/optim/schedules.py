"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak: float, warmup_steps: int):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        return peak * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
    return fn


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    floor: float = 0.1):
    """Linear warmup -> cosine decay to floor*peak."""
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        prog = jnp.clip((s - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * warm * cos
    return fn
