"""SLO layer for the serving engine: priorities, deadlines, budgets,
and graceful overload.

The paper's pipeline (PAPER.md §4) wins throughput by keeping the
datapath saturated; a production engine dies not from steady load but
from bursts.  Before this layer the scheduler admitted FIFO with an
unbounded queue — overload meant latency collapse (per-tick host work
grows with queue depth, TTFT grows without bound, nothing is ever
refused).  RWKV's O(1) recurrent state makes graceful degradation
uniquely cheap: shedding a request frees exactly one state slot, and a
shed-then-retried prompt can resume from its prefix-cache boundary
instead of re-prefilling (`repro.serving.prefix_cache`).  This module
is the configuration surface; the mechanisms live in
`repro.serving.scheduler`:

  * PRIORITY CLASSES + DEADLINES — `Request.priority` (higher = more
    urgent) orders admission; `Request.deadline_s` (seconds from
    enqueue, or `ServingSLO.default_deadline_s`) bounds a request's
    life: a deadline-exceeded request is evicted through the existing
    `Scheduler.evict` machinery — slot released, drafts discarded,
    cache leases never leaked — and reported with outcome "deadline".
  * ANTI-STARVATION AGING — a queued request's *effective* priority
    rises by one level every `aging_ticks` scheduler ticks, so a burst
    of high-priority traffic can delay but never permanently starve
    the background class.
  * CACHE-AWARE ADMISSION — with `prefer_cache_hits` and a prefix
    cache wired in, admission breaks priority ties toward the request
    with the longest cached ancestor prefix (a side-effect-free
    `PrefixCache.hit_length` peek): cache-hit requests cost the engine
    almost nothing to start, so serving them first raises goodput.
  * PER-TICK PREFILL BUDGET — `prefill_budget` bounds the prefill
    chunk-tokens launched per tick while any lane is decoding, capping
    the inter-token-latency jitter a prefill burst can inject.  The
    budget is BUCKET-AWARE (`ExecutionPlan.prefill_quota`): the
    (S, C) prefill program shape is load-independent, so the budget
    only chooses WHICH lanes' validity rows are populated — whole
    chunks, floor of one lane — and the compiled-program cache keeps
    its traced-once guarantee untouched.
  * BOUNDED QUEUE + EXPLICIT OVERLOAD — `max_queue` bounds the
    admission queue.  When it is full, `overload="backpressure"` makes
    `submit` raise a typed `Overloaded` (queue depth + retry-after
    hints: the caller's signal to back off), while `overload="shed"`
    drops the lowest-effective-priority queued request (outcome
    "shed", observable on its handle) to make room for a strictly
    more urgent arrival.  Nothing is ever silently lost: every
    submitted request ends as finished, cancelled, shed, deadline, or
    a raised `Overloaded`.

`benchmarks/bench_serving_slo.py` drives bursty/zipfian arrival traces
against this layer and gates p99 inter-token latency under a 2x
overload into BENCH_serving.json; docs/serving.md §"SLOs and overload"
covers semantics and the backpressure contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

BACKPRESSURE, SHED = "backpressure", "shed"


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """How the scheduler picks from (and bounds) the admission queue.

    max_queue        — queued-request cap; 0 = unbounded (the historical
                       behavior).  With a full queue, `overload` decides.
    overload         — "backpressure": `enqueue` raises `Overloaded`;
                       "shed": drop the lowest-effective-priority queued
                       request IF it is strictly less urgent than the
                       arrival (otherwise the arrival itself is
                       backpressured — equal classes are FIFO-fair).
    prefer_cache_hits— break priority ties toward the request with the
                       longest cached ancestor prefix (needs a prefix
                       cache; a no-op without one).
    aging_ticks      — every `aging_ticks` ticks spent queued raise a
                       request's effective priority by one (0 disables
                       aging).  Guarantees eventual admission under a
                       sustained stream of higher-priority arrivals.
    """
    max_queue: int = 0
    overload: str = BACKPRESSURE
    prefer_cache_hits: bool = True
    aging_ticks: int = 32

    def __post_init__(self):
        if self.overload not in (BACKPRESSURE, SHED):
            raise ValueError(
                f"overload={self.overload!r}: expected "
                f"{BACKPRESSURE!r} or {SHED!r}")
        if self.max_queue < 0 or self.aging_ticks < 0:
            raise ValueError("max_queue and aging_ticks must be >= 0")


@dataclasses.dataclass(frozen=True)
class ServingSLO:
    """The engine/scheduler SLO configuration (see module docstring).

    prefill_budget     — prefill chunk-tokens allowed per tick while any
                         lane decodes (0 = unlimited).  Bucket-aware
                         with a floor of one lane per tick, so prefill
                         always progresses and program shapes never
                         change.
    default_deadline_s — deadline (seconds from enqueue) applied to
                         requests that set none (None = no deadline).
    admission          — the AdmissionPolicy above.
    max_idle_ticks     — `Scheduler.run` watchdog: this many consecutive
                         ticks with work remaining but zero progress
                         (no admission, prefill token, emitted token or
                         retirement) raise `SchedulerHang` instead of
                         spinning forever (0 disables the guard).
    """
    prefill_budget: int = 0
    default_deadline_s: Optional[float] = None
    admission: AdmissionPolicy = dataclasses.field(
        default_factory=AdmissionPolicy)
    max_idle_ticks: int = 10_000

    def __post_init__(self):
        if self.prefill_budget < 0 or self.max_idle_ticks < 0:
            raise ValueError(
                "prefill_budget and max_idle_ticks must be >= 0")
        if (self.default_deadline_s is not None
                and self.default_deadline_s <= 0):
            raise ValueError("default_deadline_s must be positive")


class Overloaded(RuntimeError):
    """Typed backpressure signal: the admission queue is full and the
    request was NOT accepted.  Carries the caller's retry hints —
    `queue_depth` / `max_queue` (how full), and `retry_after_s`, a
    service-time estimate of when a slot-width of queued work will have
    drained (0.0 before any request has completed)."""

    def __init__(self, *, queue_depth: int, max_queue: int,
                 retry_after_s: float = 0.0):
        super().__init__(
            f"admission queue full ({queue_depth}/{max_queue}); "
            f"retry after ~{retry_after_s:.3f}s")
        self.queue_depth = queue_depth
        self.max_queue = max_queue
        self.retry_after_s = retry_after_s


class SchedulerHang(RuntimeError):
    """`Scheduler.run` watchdog: work remains but no tick has made
    progress for `max_idle_ticks` — a wedged lane or leaked slot would
    otherwise spin forever.  Carries the scheduler's state summary so
    the failure is diagnosable from the exception alone."""

    def __init__(self, *, idle_ticks: int, queued: int, active: int,
                 n_free: int, phases: dict):
        super().__init__(
            f"scheduler made no progress for {idle_ticks} ticks: "
            f"{queued} queued, {active} active slots ({phases}), "
            f"{n_free} free pool slots — wedged lane or leaked slot?")
        self.idle_ticks = idle_ticks
        self.queued = queued
        self.active = active
        self.n_free = n_free
        self.phases = dict(phases)
