"""Continuous-batching scheduler: admission, chunked prefill, fused decode.

One `tick()` is the software analog of the paper's pipeline reordering
(PAPER.md §3: overlap data movement with computation so the datapath never
stalls).  Per tick the scheduler

  1. ADMITS queued requests into free pool slots — and, when a prefix
     cache is wired in (`repro.serving.prefix_cache`), probes it with the
     request's prompt: the longest cached ancestor prefix's state is
     copied into the slot via the pool's per-lane write machinery and
     only the uncached SUFFIX is prefilled (the slot starts at
     n_prefilled = hit length with its fresh-reset suppressed, so the
     prefill call advances the restored state instead of wiping it),
  2. advances EVERY prefilling slot by up to one fixed-size prompt chunk
     in ONE fused call (per-op: a jitted scan of `decode_step` over the
     whole pool; fused: the chunk-matmul + on-chip-WKV `prefill_chunk`
     path — bit-identical either way), with a per-slot-per-token validity
     mask so every prompt length and slot combination reuses the same
     compiled shape; newly admitted slots are reset to the fresh state
     inside the same call via a fresh-slot mask, and
  3. runs ONE fused decode step over the whole pool for all DECODE slots,
     with an active-slot mask selecting which lanes' states commit.

Because the pool, the chunk, and the fused step all have fixed shapes,
serving runs on exactly two device programs (fused prefill chunk +
fused decode step) no matter how requests arrive, finish, or interleave
— admission and retirement are pure host bookkeeping.  The prefix cache
rides the same two programs: a cache hit is a per-lane state write at
admission (the pool's traced-once `write_slot`), chunk-boundary capture
is a per-lane `read_slot`, and the resumed suffix prefills through the
unchanged chunk program at the same tick boundaries a full prefill would
have used — which is exactly why cached-state resume is bit-identical to
full prefill (tests/test_prefix_cache.py).  The scheduler
does not build (or select) those programs: it is handed the two
callables by the engine, which takes them from an `ExecutionPlan`'s
compiled-program cache (`repro.serving.plan`) — path choice, param
preparation and mesh placement all live there.  Under a mesh the
callables place each tick's token/mask arrays onto the data-parallel
sharding themselves; nothing here is sharding-aware.

Masking semantics: inactive lanes are *computed* (wasted flops, bought
deliberately — fixed shapes beat recompiles) but their state updates are
discarded via `where(mask, stepped, old)`, so a lane mid-prefill or free
is never disturbed by decode traffic.  Lane results are bitwise equal to
a batch-1 decode of the same sequence (verified in tests/test_scheduler).

SLO layer (repro.serving.slo): a `ServingSLO` adds priority/deadline/
cache-aware admission, a per-tick prefill budget, a bounded queue with
typed `Overloaded` backpressure or lowest-priority shedding, and a
`run()` hang watchdog.  The default `ServingSLO()` preserves historical
behavior: unbounded queue, no deadlines, unlimited budget (admission
order is unchanged too — with every priority equal and no cache hits the
selection scan degenerates to FIFO).  A `ServingFaultInjector`
(repro.runtime.monitor) can force cache-probe failures, evictions —
including from inside a token callback, i.e. mid-speculation — and
deadline expiry at chosen ticks; the churn tests drive every fault and
assert pool/lease/RNG invariants hold.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import signal
import time
from typing import Callable, Optional

import numpy as np

from repro.runtime.monitor import DegradedMode, EngineCrash
from repro.serving.slo import (SHED, Overloaded, SchedulerHang,
                               ServingSLO)


@dataclasses.dataclass
class Request:
    """One generation request (host-side; tokens are python ints).

    priority   — admission class, higher = more urgent (ties FIFO);
                 also the shed-victim order under overload.
    deadline_s — seconds from enqueue until the request is evicted with
                 outcome "deadline" (None = ServingSLO.default_deadline_s,
                 which itself defaults to no deadline)."""
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos_token: Optional[int] = None
    priority: int = 0
    deadline_s: Optional[float] = None


PREFILL, DECODE = "prefill", "decode"

FINISHED, CANCELLED, SHED_OUT, DEADLINE = \
    "finished", "cancelled", "shed", "deadline"


@dataclasses.dataclass
class _Slot:
    """Host metadata for one occupied pool slot."""
    req: Request
    phase: str = PREFILL
    fresh: bool = True              # lane still needs its state reset
    n_prefilled: int = 0
    next_token: int = -1            # token the next decode tick consumes
    generated: list[int] = dataclasses.field(default_factory=list)
    rng: Optional[np.random.Generator] = None
    # prefix-cache bookkeeping: tokens restored from a probe hit, the
    # prompt's rolling boundary digests (hashed once at enqueue), and
    # boundary states captured during prefill, published at completion
    cached_tokens: int = 0
    digests: Optional[dict] = None
    pending_inserts: list = dataclasses.field(default_factory=list)
    # speculation bookkeeping: the tokens the drafter proposed for the
    # CURRENT tick's verify window (tick-local; cleared at emission or on
    # eviction — a drafted token is never engine output until the verifier
    # confirms it)
    drafted: list[int] = dataclasses.field(default_factory=list)
    # SLO bookkeeping: admission sequence number (budget-ordering
    # tiebreak) and the absolute deadline inherited from the queue entry
    seq: int = 0
    deadline_t: Optional[float] = None


@dataclasses.dataclass
class _Queued:
    """Host metadata for one QUEUED request: enqueue order/tick (aging +
    FIFO tiebreaks), absolute deadline, and the prompt's cache digests
    (hashed once so per-tick admission peeks never re-hash)."""
    seq: int
    enqueue_tick: int
    deadline_t: Optional[float] = None
    digests: Optional[dict] = None


def sample_token(logits_row: np.ndarray, temperature: float,
                 rng: Optional[np.random.Generator]) -> int:
    """Greedy argmax at temperature<=0 (ties -> first index, matching
    jnp.argmax, which keeps the engine bit-compatible with the sequential
    loop); Gumbel-max sampling otherwise.  Single-row reference for
    `sample_tokens`, the batched form the scheduler's hot path uses."""
    if temperature <= 0.0 or rng is None:
        return int(np.argmax(logits_row))
    g = rng.gumbel(size=logits_row.shape)
    return int(np.argmax(logits_row.astype(np.float64) / temperature + g))


def greedy_accept(window_row, argmax_rows) -> tuple[list[int], int]:
    """The pure acceptance rule for ONE lane of a speculative tick.

    window_row  — the K verified tokens: the lane's pending token followed
                  by K-1 drafted tokens
    argmax_rows — the verifier's greedy choice after consuming each window
                  prefix (argmax of verify logits row j)

    Emission walks the window: position j's verifier choice e_j is EMITTED
    (it came from true logits — losslessness is unconditional), and the
    walk continues only while e_j confirms the NEXT drafted token.
    Returns (emitted tokens, window tokens consumed); consumed == j+1 and
    the accepted draft prefix is exactly the verifier argmax prefix — the
    property the hypothesis suite drives directly.  `Scheduler._spec_tick`
    follows this walk shape with sampling/retire/evict handling around
    it."""
    emitted, j, k = [], 0, len(window_row)
    while True:
        e = int(argmax_rows[j])
        emitted.append(e)
        if j + 1 < k and e == int(window_row[j + 1]):
            j += 1
            continue
        return emitted, j + 1


def sample_tokens(rows: np.ndarray, metas) -> np.ndarray:
    """Vectorized sampling for one tick's emitting slots.

    rows (n, V) are the slots' last-logits rows (f32), metas the matching
    `_Slot`s.  The Gumbel noise is still drawn from EACH SLOT'S OWN
    Generator — a seeded request's RNG stream consumes exactly the draws
    it would alone, in the same order, so its output never depends on who
    shares the tick — but the temperature scale, the noise add, and above
    all the argmax over the (n, V) block happen in single numpy calls
    instead of one call per slot.  Greedy rows ride the same batched
    argmax: the f32 -> f64 cast is exact, so ties resolve identically to
    `sample_token`'s per-row `np.argmax` (bit-stable either way)."""
    n, V = rows.shape
    sampling = [i for i, meta in enumerate(metas)
                if meta.req.temperature > 0.0 and meta.rng is not None]
    if not sampling:
        # all-greedy tick (the default): one f32 argmax, no temporaries
        return np.argmax(rows, axis=1)
    temps = np.ones((n, 1))
    noise = np.zeros((n, V))
    for i in sampling:
        temps[i, 0] = metas[i].req.temperature
        noise[i] = metas[i].rng.gumbel(size=V)
    # one vectorized scale+add+argmax over the whole block; /1.0 and +0.0
    # are exact, so greedy rows match their per-row argmax bit-for-bit
    return np.argmax(rows.astype(np.float64) / temps + noise, axis=1)


class Scheduler:
    """Drives a SlotStatePool with two compiled functions.

    decode_fn(pool_state, tokens (S,1) i32, mask (S,) bool)
        -> (logits (S,1,V), new_pool_state)           [fused, masked]
    prefill_fn(pool_state, tokens (S,C) i32, valid (S,C) bool,
               fresh (S,) bool)
        -> (new_pool_state, last_logits (S,1,V))      [fused, chunked]

    With `speculative=K` the decode tick is replaced by the speculative
    draft -> verify -> accept tick (`_spec_tick`), driven by three more
    plan programs instead of decode_fn:

    draft_fn(pool_state, tokens (S,1))    -> drafted (S, K-1) i32
    verify_fn(pool_state, tokens (S,K), valid (S,K))
        -> (logits (S,K,V), new_pool_state)           [commit-all, no
                                                       donation: input
                                                       state = snapshot]
    rollback_fn(committed, snapshot, reject (S,))     -> pool_state

    `slo` (a ServingSLO) layers admission control on top: priority +
    deadline + cache-aware selection, a bounded queue with `Overloaded`
    backpressure / lowest-priority shedding, a per-tick prefill lane
    budget (`prefill_quota`, lanes per tick — the engine derives it
    bucket-aware via `ExecutionPlan.prefill_quota`; left None the
    scheduler derives it from `slo.prefill_budget` itself), and the
    `run()` hang watchdog.  `on_finish` is called as
    `on_finish(req, outcome)` with outcome in {"finished", "cancelled",
    "shed", "deadline"}.  `fault_injector` (ServingFaultInjector) is
    drained at the top of every tick for fault drills.
    """

    def __init__(self, pool, decode_fn: Callable, prefill_fn: Callable, *,
                 prefill_chunk: int, counters=None,
                 on_token: Optional[Callable] = None,
                 on_finish: Optional[Callable] = None,
                 prefix_cache=None, cache_variant=None,
                 speculative: int = 0,
                 draft_fn: Optional[Callable] = None,
                 verify_fn: Optional[Callable] = None,
                 rollback_fn: Optional[Callable] = None,
                 slo: Optional[ServingSLO] = None,
                 prefill_quota: Optional[int] = None,
                 fault_injector=None, sentinel_every: int = 0,
                 on_requeue: Optional[Callable] = None,
                 fallback_decode: Optional[Callable] = None,
                 fallback_prefill: Optional[Callable] = None,
                 path_fault_limit: int = 2,
                 path_names: Optional[dict] = None):
        self.pool = pool
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.prefill_chunk = int(prefill_chunk)
        self.counters = counters
        # self-speculative decode (repro.serving.plan.SpeculativePath):
        # with speculative=K >= 1 the decode tick becomes
        # draft -> verify -> accept, driven by the plan's three extra
        # programs.  draft_fn is only needed for K > 1 (K=1 is the
        # degenerate verify-only window).
        self.spec_k = int(speculative)
        self.draft_fn = draft_fn
        self.verify_fn = verify_fn
        self.rollback_fn = rollback_fn
        if self.spec_k:
            if verify_fn is None or rollback_fn is None:
                raise ValueError(
                    "speculative decode needs verify_fn and rollback_fn")
            if self.spec_k > 1 and draft_fn is None:
                raise ValueError(
                    f"speculative={self.spec_k} needs a draft_fn "
                    "(K=1 is the only drafterless window)")
        # tick-local speculation state, exposed for leak auditing: the
        # rollback snapshot and the lanes whose drafts are in flight.
        # ALWAYS empty between ticks (cleared in a finally) — the churn
        # invariant test asserts exactly that.
        self._spec_snapshot = None
        self._spec_inflight: dict[int, _Slot] = {}
        self.on_token = on_token or (lambda req, tok: None)
        self.on_finish = on_finish or (lambda req, outcome: None)
        self.slo = slo if slo is not None else ServingSLO()
        # prefill lane quota per tick (None = unlimited): prefer the
        # engine's bucket-aware ExecutionPlan.prefill_quota; standalone
        # construction derives the same whole-chunks / one-lane-floor
        # rule from the budget directly
        if prefill_quota is not None:
            self._prefill_quota: Optional[int] = int(prefill_quota)
        elif self.slo.prefill_budget > 0:
            self._prefill_quota = max(
                1, self.slo.prefill_budget // self.prefill_chunk)
        else:
            self._prefill_quota = None
        self.fault_injector = fault_injector
        # NaN/Inf sentinels: every `sentinel_every` ticks (0 = off) one
        # jitted reduction over the whole pool flags non-finite lanes;
        # a flagged lane is QUARANTINED — slot released, drafts and
        # staged cache inserts discarded — and its request requeued for
        # a from-scratch deterministic replay (`on_requeue` lets the
        # engine reset the request's handle first).  The re-enqueue
        # bypasses admission bounds: the request was already accepted
        # once and must not be lost to its own quarantine.
        self.sentinel_every = int(sentinel_every)
        self.on_requeue = on_requeue or (lambda req: None)
        # automatic path fallback (degraded mode): `fallback_decode` /
        # `fallback_prefill` are ZERO-ARG PROVIDERS (the engine passes
        # the plan's lazily-built per-op twins) invoked only at demotion
        # time.  After `path_fault_limit` CONSECUTIVE primary-program
        # failures the path is demoted for the life of the scheduler and
        # a DegradedMode event is recorded; below the limit the primary
        # is retried.  Retry/demote-and-rerun are only sound when the
        # failure was raised before the program consumed its donated
        # pool state (host wrapper errors, dispatch failures) — a
        # mid-execution device fault invalidates the donated buffers and
        # the rerun will surface that instead of corrupting state.
        self.fallback_decode = fallback_decode
        self.fallback_prefill = fallback_prefill
        self.path_fault_limit = int(path_fault_limit)
        self.path_names = path_names or {}
        self._path_failures: dict[str, int] = {}
        self._fallback_progs: dict[str, Optional[Callable]] = {}
        self._demoted: set[str] = set()
        # tick-boundary hooks, assigned post-construction by the engine:
        # `after_tick(tick_no)` fires after counters.on_tick — the
        # snapshot cadence lives there (repro.serving.snapshot);
        # `on_torn_snapshot(tick_no)` is the torn-write fault drill.
        self.after_tick: Optional[Callable] = None
        self.on_torn_snapshot: Optional[Callable] = None
        self._tick_no = 0
        self._seq = 0               # plain int: snapshots serialize it
        self._queued: dict[int, _Queued] = {}
        self._has_deadlines = False
        # monotone progress counter (admissions + prefill tokens +
        # emitted tokens + retirements/sheds): the run() watchdog's
        # wedge detector
        self._progress = 0
        # armed fault state (ServingFaultInjector)
        self._fail_next_probe = False
        self._evict_on_token: set[int] = set()
        # prefix cache (repro.serving.prefix_cache.PrefixCache) + the
        # CacheVariant this scheduler's states are filed under; both or
        # neither.  The cache's chunk granularity must equal
        # prefill_chunk — boundaries must be tick boundaries, or resumed
        # suffixes would re-chunk differently from a full prefill and
        # lose bit parity (the engine validates this at construction).
        self.prefix_cache = prefix_cache
        self.cache_variant = cache_variant
        if prefix_cache is not None and cache_variant is None:
            raise ValueError("prefix_cache needs a cache_variant")
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: dict[int, _Slot] = {}

    # -- public ------------------------------------------------------------

    def enqueue(self, req: Request):
        """Queue a request for admission.  With a bounded queue
        (`AdmissionPolicy.max_queue`) a full queue either raises
        `Overloaded` (backpressure — the request was NOT accepted) or
        sheds the lowest-effective-priority queued request when it is
        strictly less urgent than this arrival (otherwise this arrival
        is backpressured: equal classes stay FIFO-fair)."""
        if not req.prompt:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the first token "
                             "is sampled from the prompt's last logits)")
        pol = self.slo.admission
        if pol.max_queue and len(self.queue) >= pol.max_queue:
            if pol.overload == SHED:
                victim = self._shed_victim(req)
                if victim is None:
                    self._backpressure()
                self._shed(victim)
            else:
                self._backpressure()
        deadline_s = (req.deadline_s if req.deadline_s is not None
                      else self.slo.default_deadline_s)
        qm = _Queued(
            seq=self._next_seq(), enqueue_tick=self._tick_no,
            deadline_t=(None if deadline_s is None
                        else self._now() + deadline_s),
            digests=(self.prefix_cache.digests(req.prompt)
                     if self.prefix_cache is not None else None))
        if qm.deadline_t is not None:
            self._has_deadlines = True
        self._queued[req.rid] = qm
        self.queue.append(req)
        if self.counters is not None:
            self.counters.on_enqueue(req.rid)

    def tick(self) -> bool:
        """One scheduling round; returns True while work remains.
        Ticks are numbered from 1 (`ServingFaultInjector` schedules are
        keyed on this number and drained at the top of the tick)."""
        self._tick_no += 1
        if self.fault_injector is not None:
            self._apply_faults()
        self._sentinel_sweep()
        self._expire_deadlines()
        self._admit()
        self._prefill_tick()
        if self.spec_k:
            self._spec_tick()
        else:
            self._decode_tick()
        if self.counters is not None:
            self.counters.on_tick(active=len(self.slots),
                                  queued=len(self.queue))
        if self.after_tick is not None:
            # tick-boundary hook (snapshots): fires with every boundary
            # invariant holding — no speculation in flight, no lease
            # held, all lane states committed
            self.after_tick(self._tick_no)
        return bool(self.queue or self.slots)

    def run(self, *, max_idle_ticks: Optional[int] = None):
        """Tick until no work remains.  Watchdog: `max_idle_ticks`
        (default `ServingSLO.max_idle_ticks`; 0 disables) consecutive
        ticks with work remaining but zero progress — no admission,
        prefill token, emitted token, or retirement — raise
        `SchedulerHang` with a state summary instead of spinning
        forever (e.g. a leaked pool slot leaving queued work
        unadmittable)."""
        limit = (self.slo.max_idle_ticks if max_idle_ticks is None
                 else max_idle_ticks)
        idle, last = 0, self._progress
        while self.tick():
            if self._progress != last:
                idle, last = 0, self._progress
                continue
            idle += 1
            if limit and idle >= limit:
                phases = collections.Counter(
                    m.phase for m in self.slots.values())
                raise SchedulerHang(
                    idle_ticks=idle, queued=len(self.queue),
                    active=len(self.slots), n_free=self.pool.n_free,
                    phases=dict(phases))

    def evict(self, rid: int) -> bool:
        """Cancel an in-flight or queued request and free its slot; counted
        as a cancellation, not a completion (no latency sample)."""
        for slot, meta in list(self.slots.items()):
            if meta.req.rid == rid:
                self._retire(slot, meta, outcome=CANCELLED)
                return True
        for req in list(self.queue):
            if req.rid == rid:
                self._dequeue(req)
                if self.counters is not None:
                    self.counters.on_cancel(rid)
                self.on_finish(req, CANCELLED)
                return True
        return False

    # -- SLO layer ---------------------------------------------------------

    def _backpressure(self):
        if self.counters is not None:
            self.counters.on_backpressure()
        raise Overloaded(queue_depth=len(self.queue),
                         max_queue=self.slo.admission.max_queue,
                         retry_after_s=self._retry_after())

    def _retry_after(self) -> float:
        """Retry hint for `Overloaded`: mean completed-request latency
        scaled by how many queue-lengths of work stand in front of a
        new arrival (0.0 before any completion — no estimate beats a
        made-up one)."""
        c = self.counters
        if c is None or not getattr(c, "latency_s", None):
            return 0.0
        mean_lat = sum(c.latency_s) / len(c.latency_s)
        return mean_lat * (len(self.queue) + 1) / max(self.pool.max_slots, 1)

    def _eff_priority(self, req: Request, qm: _Queued) -> int:
        """Effective priority = class + anti-starvation aging bonus
        (one level per `aging_ticks` ticks spent queued)."""
        aging = self.slo.admission.aging_ticks
        bonus = (self._tick_no - qm.enqueue_tick) // aging if aging else 0
        return req.priority + bonus

    def _shed_victim(self, incoming: Request) -> Optional[Request]:
        """Lowest-effective-priority queued request (youngest on ties —
        it has the least sunk wait), IF strictly less urgent than the
        incoming request; else None (the incoming is backpressured)."""
        best, best_key = None, None
        for r in self.queue:
            qm = self._queued[r.rid]
            key = (self._eff_priority(r, qm), -qm.seq)
            if best is None or key < best_key:
                best, best_key = r, key
        if best is not None and best_key[0] < incoming.priority:
            return best
        return None

    def _shed(self, req: Request):
        self._dequeue(req)
        self._progress += 1
        if self.counters is not None:
            self.counters.on_shed(req.rid)
        self.on_finish(req, SHED_OUT)

    def _dequeue(self, req: Request):
        self.queue.remove(req)
        self._queued.pop(req.rid, None)

    def _apply_faults(self):
        """Drain this tick's `ServingFaultInjector` schedule (see its
        docstring for the fault kinds)."""
        for kind, payload in self.fault_injector.pop(self._tick_no):
            if kind == "cache_probe_error":
                self._fail_next_probe = True
            elif kind == "evict":
                self.evict(int(payload))
            elif kind == "evict_on_token":
                self._evict_on_token.add(int(payload))
            elif kind == "deadline":
                self._force_deadline(int(payload))
            elif kind == "crash_at_tick":
                # the crash-recovery drill: die at the TOP of this tick,
                # BEFORE any of its work — every committed snapshot is
                # consistent with respect to this crash point
                if payload == "sigkill":
                    os.kill(os.getpid(), signal.SIGKILL)
                raise EngineCrash(self._tick_no)
            elif kind == "torn_snapshot_write":
                if self.on_torn_snapshot is not None:
                    self.on_torn_snapshot(self._tick_no)
            elif kind == "corrupt_state_leaf":
                for slot, m in self.slots.items():
                    if m.req.rid == int(payload):
                        self.pool.poison_slot(slot)
                        break

    def _force_deadline(self, rid: int):
        """Fault drill: expire `rid`'s deadline NOW (whether or not it
        had one) — it is evicted by this tick's deadline sweep."""
        for meta in self.slots.values():
            if meta.req.rid == rid:
                meta.deadline_t = float("-inf")
                self._has_deadlines = True
                return
        qm = self._queued.get(rid)
        if qm is not None:
            qm.deadline_t = float("-inf")
            self._has_deadlines = True

    def _next_seq(self) -> int:
        s = self._seq
        self._seq += 1
        return s

    # -- integrity sentinels + quarantine ----------------------------------

    def _sentinel_sweep(self):
        """Every `sentinel_every` ticks: ONE jitted all-lane finiteness
        reduction over the pool; every occupied lane holding a NaN/Inf
        state is quarantined (see `_quarantine`).  Free lanes may hold
        stale garbage legitimately — only occupied ones are judged."""
        if (not self.sentinel_every or not self.slots
                or self._tick_no % self.sentinel_every):
            return
        ok = self.pool.lane_finite()
        if ok is None:              # no floating state leaves: nothing
            return                  # can go non-finite
        for slot in [s for s in self.slots if not ok[s]]:
            self._quarantine(slot, self.slots[slot])

    def _quarantine(self, slot: int, meta: _Slot):
        """Evict a poisoned lane and REQUEUE its request for a clean
        replay: staged cache inserts and drafts are discarded (never
        publish from a poisoned lane), the slot is released (its state is
        fresh-reset in-call at the next admission, like any reacquired
        lane), the engine resets the request's handle via `on_requeue`,
        and the request re-enqueues BYPASSING admission bounds with a
        fresh RNG/deadline at admission.  Decode is deterministic, so the
        replayed stream is bit-identical to an unpoisoned run — the
        quarantine costs latency, never correctness."""
        req = meta.req
        meta.pending_inserts.clear()
        meta.drafted.clear()
        self._spec_inflight.pop(req.rid, None)
        del self.slots[slot]
        self.pool.release(slot)
        self._progress += 1
        if self.counters is not None:
            self.counters.on_quarantine(req.rid)
        self.on_requeue(req)
        deadline_s = (req.deadline_s if req.deadline_s is not None
                      else self.slo.default_deadline_s)
        qm = _Queued(
            seq=self._next_seq(), enqueue_tick=self._tick_no,
            deadline_t=(None if deadline_s is None
                        else self._now() + deadline_s),
            digests=(self.prefix_cache.digests(req.prompt)
                     if self.prefix_cache is not None else None))
        if qm.deadline_t is not None:
            self._has_deadlines = True
        self._queued[req.rid] = qm
        self.queue.append(req)
        if self.counters is not None:
            self.counters.on_enqueue(req.rid)

    # -- path fallback (degraded mode) -------------------------------------

    @property
    def demoted(self) -> frozenset:
        """The paths currently demoted to their per-op twins."""
        return frozenset(self._demoted)

    def _fallback(self, kind: str) -> Optional[Callable]:
        if kind not in self._fallback_progs:
            prov = (self.fallback_decode if kind == "decode"
                    else self.fallback_prefill)
            self._fallback_progs[kind] = None if prov is None else prov()
        return self._fallback_progs[kind]

    def _run_program(self, kind: str, fn: Callable, *args):
        """Run a primary decode/prefill program with consecutive-failure
        tracking: below `path_fault_limit` the primary is retried; at the
        limit the path is demoted to its per-op twin (bit-identical
        stream, DegradedMode event) for the life of the scheduler.  With
        no twin available the error propagates.  See the ctor comment
        for the donation caveat on retries."""
        if kind in self._demoted:
            return self._fallback(kind)(*args)
        while True:
            try:
                out = fn(*args)
            except (EngineCrash, KeyboardInterrupt):
                raise               # injected crashes are not path faults
            except Exception as e:
                n = self._path_failures[kind] = \
                    self._path_failures.get(kind, 0) + 1
                if n < self.path_fault_limit:
                    continue
                fb = self._fallback(kind)
                if fb is None:
                    raise
                self._demote(kind, n, e)
                return fb(*args)
            self._path_failures[kind] = 0
            return out

    def _demote(self, kind: str, failures: int, err: Exception):
        self._demoted.add(kind)
        if self.counters is not None:
            self.counters.on_path_fallback(DegradedMode(
                kind=kind, tick=self._tick_no, failures=failures,
                from_path=self.path_names.get(kind, kind),
                to_path="per_op", error=repr(err)))

    def _expire_deadlines(self):
        """Evict every queued or in-flight request whose deadline has
        passed (outcome "deadline").  In-flight lanes go through the
        `_retire` path — slot released, drafts discarded; like
        cancellation, captured boundary states are NOT published (only
        completed requests publish, keeping write-once semantics
        simple)."""
        if not self._has_deadlines:
            return
        now = self._now()
        for slot, meta in list(self.slots.items()):
            if meta.deadline_t is not None and now >= meta.deadline_t:
                self._retire(slot, meta, outcome=DEADLINE)
        expired = [r for r in self.queue
                   if (qm := self._queued[r.rid]).deadline_t is not None
                   and now >= qm.deadline_t]
        for r in expired:
            self._dequeue(r)
            self._progress += 1
            if self.counters is not None:
                self.counters.on_deadline_evict(r.rid)
            self.on_finish(r, DEADLINE)

    # -- phases ------------------------------------------------------------

    def _pop_next(self) -> Request:
        """Admission selection: highest effective priority first
        (class + aging), ties broken toward the longest cached ancestor
        prefix (`AdmissionPolicy.prefer_cache_hits`, a side-effect-free
        `PrefixCache.hit_length` peek over enqueue-time digests), then
        FIFO.  With every priority equal and no cache hits this is
        exactly the historical FIFO order."""
        if len(self.queue) == 1:
            req = self.queue.popleft()
            return req
        peek = (self.prefix_cache is not None
                and self.slo.admission.prefer_cache_hits)
        best, best_key = None, None
        for r in self.queue:
            qm = self._queued[r.rid]
            hit = (self.prefix_cache.hit_length(
                self.cache_variant, r.prompt, qm.digests) if peek else 0)
            key = (self._eff_priority(r, qm), hit, -qm.seq)
            if best is None or key > best_key:
                best, best_key = r, key
        self.queue.remove(best)
        return best

    def _admit(self):
        while self.queue and self.pool.n_free:
            req = self._pop_next()
            qm = self._queued.pop(req.rid)
            slot = self.pool.acquire()
            meta = _Slot(req=req, rng=np.random.default_rng(req.seed),
                         seq=qm.seq, deadline_t=qm.deadline_t,
                         digests=qm.digests)
            self.slots[slot] = meta
            self._progress += 1
            if self.counters is not None:
                self.counters.on_admit(req.rid)
            if self.prefix_cache is not None:
                self._cache_probe(slot, meta)

    def _now(self) -> float:
        return self.counters.now() if self.counters is not None \
            else time.monotonic()

    def _cache_probe(self, slot: int, meta: _Slot):
        """Admission-side cache path: probe for the longest cached
        ancestor prefix of the prompt and, on a hit, install its state
        into the freshly acquired lane.  The slot then starts mid-prefill
        (n_prefilled = hit length) with `fresh=False`, so the next
        prefill call advances the restored state instead of resetting the
        lane, and only the uncached suffix is ever computed.  Probe and
        state-copy wall time are reported separately from prefill time
        (ServingCounters.on_cache_probe) — a hit's TTFT is cache time
        plus suffix prefill, and the decomposition should say so.

        Robustness: a probe that RAISES (storage fault, injected via
        ServingFaultInjector's "cache_probe_error") degrades to a miss —
        counted in `ServingCounters.cache_errors` — and the lane
        prefills from scratch; the serving loop never dies on cache
        trouble and no lease is held when the probe fails."""
        req = meta.req
        if meta.digests is None:        # enqueue-time hashing is the norm
            meta.digests = self.prefix_cache.digests(req.prompt)
        t0 = self._now()
        try:
            if self._fail_next_probe:
                self._fail_next_probe = False
                raise RuntimeError("injected cache-probe failure")
            lease = self.prefix_cache.probe(self.cache_variant, req.prompt,
                                            meta.digests)
        except Exception:
            if self.counters is not None:
                self.counters.on_cache_error()
                self.counters.on_cache_probe(req.rid, hit=False,
                                             probe_s=self._now() - t0)
            return
        t_probe = self._now() - t0
        if lease is None:
            if self.counters is not None:
                self.counters.on_cache_probe(req.rid, hit=False,
                                             probe_s=t_probe)
            return
        t0 = self._now()
        self.pool.write_slot(slot, lease.state)
        self.pool.sync()            # block so the copy time is honest
        t_copy = self._now() - t0
        meta.fresh = False
        meta.n_prefilled = meta.cached_tokens = lease.n_tokens
        if self.counters is not None:
            self.counters.on_cache_probe(req.rid, hit=True,
                                         n_cached=lease.n_tokens,
                                         probe_s=t_probe, copy_s=t_copy)
        lease.release()

    def _cache_capture(self, slot: int, meta: _Slot):
        """After a prefill tick: if the lane now holds exactly a
        chunk-boundary prefix that is not already cached, copy it out
        (pool.read_slot) and stage it on the slot.  Staged states are
        published to the cache only when the request COMPLETES (_retire)
        — write-once, and cancelled requests never publish."""
        n = meta.n_prefilled
        if n == 0 or n % self.prefill_chunk or n <= meta.cached_tokens:
            return
        if self.prefix_cache.contains(self.cache_variant, meta.req.prompt,
                                      n, meta.digests):
            return
        meta.pending_inserts.append((n, self.pool.read_slot(slot)))

    def _prefill_tick(self):
        prefilling = [(s, m) for s, m in self.slots.items()
                      if m.phase == PREFILL]
        if not prefilling:
            return
        S, C = self.pool.max_slots, self.prefill_chunk
        quota = self._prefill_quota
        if (quota is not None and quota < len(prefilling)
                and any(m.phase == DECODE for m in self.slots.values())):
            # prefill budget (ServingSLO.prefill_budget): while lanes are
            # DECODING, only `quota` prefilling lanes join this tick's
            # call — highest priority first, then earliest deadline, then
            # admission order.  The (S, C) program shape never changes
            # (deferred lanes just keep empty validity rows), so the
            # compiled-program cache is untouched; with no decode lane
            # live there is no inter-token latency to protect and
            # prefill runs unthrottled.
            prefilling.sort(key=lambda sm: (
                -sm[1].req.priority,
                sm[1].deadline_t if sm[1].deadline_t is not None
                else float("inf"),
                sm[1].seq))
            deferred = prefilling[quota:]
            prefilling = prefilling[:quota]
            if self.counters is not None and deferred:
                self.counters.on_budget_defer(sum(
                    min(len(m.req.prompt) - m.n_prefilled, C)
                    for _, m in deferred))
        toks = np.zeros((S, C), np.int32)
        valid = np.zeros((S, C), bool)
        fresh = np.zeros((S,), bool)
        parts = {}
        for slot, meta in prefilling:
            part = meta.req.prompt[
                meta.n_prefilled:meta.n_prefilled + C]
            toks[slot, :len(part)] = part
            valid[slot, :len(part)] = True
            fresh[slot] = meta.fresh
            parts[slot] = len(part)
        self.pool.state, last_logits = self._run_program(
            "prefill", self.prefill_fn, self.pool.state, toks, valid, fresh)
        finishing = []
        for slot, meta in prefilling:
            meta.fresh = False
            meta.n_prefilled += parts[slot]
            self._progress += parts[slot]
            if self.counters is not None:
                self.counters.on_prefill(meta.req.rid, parts[slot])
            if self.prefix_cache is not None:
                self._cache_capture(slot, meta)
            if meta.n_prefilled == len(meta.req.prompt):
                # prompt fully absorbed: the last prompt token's logits
                # yield the first generated token; the slot joins the
                # fused decode batch from this tick on.
                meta.phase = DECODE
                finishing.append((slot, meta))
        if finishing:
            rows = np.asarray(last_logits[:, -1], np.float32)
            self._emit([(s, m, rows[s]) for s, m in finishing])

    def _decode_tick(self):
        active = [(s, m) for s, m in self.slots.items()
                  if m.phase == DECODE]
        if not active:
            return
        S = self.pool.max_slots
        toks = np.zeros((S, 1), np.int32)
        mask = np.zeros((S,), bool)
        for slot, meta in active:
            toks[slot, 0] = meta.next_token
            mask[slot] = True
        logits, self.pool.state = self._run_program(
            "decode", self.decode_fn, self.pool.state, toks, mask)
        rows = np.asarray(logits[:, -1], np.float32)
        self._emit([(s, m, rows[s]) for s, m in active])

    def _spec_tick(self):
        """The speculative decode tick: draft -> verify -> accept.

        One drafter call proposes K-1 tokens per lane (greedy chain of the
        truncated stack over a SLICE of the live pool state), one verify
        call — the chunked-prefill machinery with an all-position head —
        scores the lane's pending token plus every draft in parallel and
        commits state through the whole window, and the host accepts the
        longest prefix the verifier agrees with, sampling every emitted
        token from VERIFIER logits (losslessness does not depend on the
        drafter).  Lanes that consumed fewer than K window tokens roll
        back to the pre-verify snapshot (`rollback_fn` = the engine's one
        `masked_state_commit`) and re-advance by their accepted prefix
        through the same verify program.  Worst case (every draft
        rejected) each lane still emits one token per tick, exactly like
        `_decode_tick`."""
        active = [(s, m) for s, m in self.slots.items()
                  if m.phase == DECODE]
        if not active:
            return
        S, K = self.pool.max_slots, self.spec_k
        toks = np.zeros((S, 1), np.int32)
        for slot, meta in active:
            toks[slot, 0] = meta.next_token
        # the pre-verify pool state IS the rollback snapshot: verify_fn
        # never donates its input, so holding this reference is enough
        snapshot = self.pool.state
        window = np.zeros((S, K), np.int32)
        window[:, 0] = toks[:, 0]
        if K > 1:
            window[:, 1:] = np.asarray(self.draft_fn(snapshot, toks))
        valid = np.zeros((S, K), bool)
        for slot, meta in active:
            valid[slot] = True
            meta.drafted = [int(t) for t in window[slot, 1:]]
        self._spec_snapshot = snapshot
        self._spec_inflight = {m.req.rid: m for _, m in active}
        try:
            logits, committed = self.verify_fn(snapshot, window, valid)
            rows = np.asarray(logits, np.float32)          # (S, K, V)
            consumed = self._spec_emit(active, rows, window)
            # lanes still live that consumed < K window tokens: restore
            # the snapshot, then re-advance by the accepted prefix only
            # (retired/evicted lanes are left as-committed — their lane
            # is fresh-reset or prefilled on reacquisition)
            reject = np.zeros((S,), bool)
            readvance = np.zeros((S, K), bool)
            for slot, meta in active:
                if slot not in self.slots or self.slots[slot] is not meta:
                    continue
                n = consumed.get(slot, 0)
                if n < K:
                    reject[slot] = True
                    readvance[slot, :n] = True
            if reject.any():
                rolled = self.rollback_fn(committed, snapshot, reject)
                _, self.pool.state = self.verify_fn(rolled, window,
                                                    readvance)
            else:
                self.pool.state = committed
        finally:
            self._spec_snapshot = None
            self._spec_inflight = {}

    def _spec_emit(self, active, rows, window) -> dict[int, int]:
        """Per-lane acceptance walk of one verify window (the
        `greedy_accept` rule, with sampling and lifecycle handling).
        Returns {slot: window tokens consumed} for every lane that
        emitted.  Sampling is per-row `sample_token` from EACH SLOT'S OWN
        Generator, one draw per EMITTED token — a seeded stream advances
        by accepted tokens only, so its output is bit-stable no matter
        how many drafts were rejected (tests/test_speculative.py pins
        this).  `on_token` callbacks may evict lanes mid-tick; membership
        checks keep a dead lane's drafts from emitting."""
        K = self.spec_k
        consumed: dict[int, int] = {}
        for slot, meta in active:
            if slot not in self.slots or self.slots[slot] is not meta:
                continue    # evicted by an earlier lane's callback
            req, j = meta.req, 0
            while True:
                tok = sample_token(rows[slot, j], req.temperature,
                                   meta.rng)
                consumed[slot] = j + 1
                meta.generated.append(tok)
                meta.next_token = tok
                self._progress += 1
                if self.counters is not None:
                    self.counters.on_token(
                        req.rid, first=len(meta.generated) == 1)
                self.on_token(req, tok)
                self._check_token_fault(req.rid)
                if slot not in self.slots or self.slots[slot] is not meta:
                    break   # evicted by its own token callback / a fault
                if (len(meta.generated) >= req.max_new_tokens or
                        (req.eos_token is not None and
                         tok == req.eos_token)):
                    self._retire(slot, meta)
                    break
                if j + 1 < K and tok == int(window[slot, j + 1]):
                    j += 1  # verifier confirmed the next draft: keep going
                    continue
                break
            meta.drafted = []
            if self.counters is not None and K > 1:
                self.counters.on_speculate(req.rid, drafted=K - 1,
                                           accepted=consumed[slot] - 1)
        return consumed

    # -- helpers -----------------------------------------------------------

    def _emit(self, emitting: list):
        """Sample + book-keep one tick's emitting slots.  Sampling is the
        batched `sample_tokens` (ONE argmax call for the whole block);
        bookkeeping stays per-slot."""
        toks = sample_tokens(
            np.stack([row for _, _, row in emitting]),
            [meta for _, meta, _ in emitting])
        for (slot, meta, _), tok in zip(emitting, toks):
            req, tok = meta.req, int(tok)
            meta.generated.append(tok)
            meta.next_token = tok
            self._progress += 1
            if self.counters is not None:
                self.counters.on_token(req.rid,
                                       first=len(meta.generated) == 1)
            self.on_token(req, tok)
            self._check_token_fault(req.rid)
            if slot not in self.slots or self.slots[slot] is not meta:
                continue    # evicted by its token callback / a fault
            done = (len(meta.generated) >= req.max_new_tokens or
                    (req.eos_token is not None and tok == req.eos_token))
            if done:
                self._retire(slot, meta)

    def _check_token_fault(self, rid: int):
        """ServingFaultInjector "evict_on_token": evict `rid` from inside
        its own token emission — the mid-tick / mid-speculation eviction
        drill.  Callers re-check slot membership right after."""
        if rid in self._evict_on_token:
            self._evict_on_token.discard(rid)
            self.evict(rid)

    def _retire(self, slot: int, meta: _Slot, *, outcome: str = FINISHED):
        """Release `slot` and report `meta.req` with `outcome` (one of
        "finished" / "cancelled" / "deadline"; queued-only exits use
        "shed" / "cancelled" without reaching here).  Only FINISHED
        requests publish their captured boundary states — a cancelled or
        deadline-evicted lane's pending inserts are discarded."""
        if outcome == FINISHED and self.prefix_cache is not None:
            # publish the boundary states captured during prefill —
            # write-once (the cache keeps the first state for a key;
            # any rival is bit-identical by the resume oracle).  A
            # failing insert degrades to "not cached", never a crash.
            for n, state in meta.pending_inserts:
                try:
                    self.prefix_cache.insert(self.cache_variant,
                                             meta.req.prompt, n, state,
                                             meta.digests)
                except Exception:
                    if self.counters is not None:
                        self.counters.on_cache_error()
        meta.pending_inserts.clear()
        # mid-speculation eviction: the lane's drafted tokens die with it
        # and its in-flight marker clears NOW (not at tick end), so a
        # snapshot can never outlive the request that caused it
        meta.drafted.clear()
        self._spec_inflight.pop(meta.req.rid, None)
        del self.slots[slot]
        self.pool.release(slot)
        self._progress += 1
        if self.counters is not None:
            if outcome == CANCELLED:
                self.counters.on_cancel(meta.req.rid)
            elif outcome == DEADLINE:
                self.counters.on_deadline_evict(meta.req.rid)
            else:
                self.counters.on_finish(meta.req.rid)
        self.on_finish(meta.req, outcome)
