"""Serving engine front-end: submit(prompt) -> token stream.

Wires the slotted state pool and the scheduler to an `ExecutionPlan`
(`repro.serving.plan`) — the engine no longer builds device programs
itself.  The plan owns path selection (registry PathDescriptors instead
of boolean capability flags), one-pass param preparation
(`core.quant.serving.PreparedParams`), the compiled-program cache keyed
by (path, batch bucket, dtype), and mesh placement; the engine's job is
request lifecycle: handles, streaming, scheduler callbacks.

The two programs the plan serves to the scheduler:

  * the FUSED DECODE STEP — the selected decode path (`per_op` oracle,
    `block` single-launch kernel, or the whole-`model` megakernel) over
    the full pool with an active-slot mask; packed Δ-PoT weights unpack
    in-trace (per-op) or decode in-kernel (fused), so int8 codes are what
    crosses HBM — the paper's bandwidth win riding along for free, and
  * the PREFILL CHUNK — absorbing up to `prefill_chunk` prompt tokens for
    EVERY prefilling slot in one device call, per-slot-per-token validity
    masked, fresh lanes reset in-call; the `per_op` scan and the fused
    `chunked` path both compile with defined rounding semantics
    (`kernels.common.exact_jit`) and are bit-identical
    (tests/test_prefill.py).

On a mesh (`mesh=` or a pre-built `plan=`), the pool and per-tick batch
shard data-parallel while weights replicate — bit-identical tokens to the
single-device engine (tests/test_plan.py).  All programs are traced
exactly once (`trace_counts` proves it in tests).  See docs/serving.md
for the API walkthrough and docs/architecture.md for the request
lifecycle and the plan diagram.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterator, Optional

import jax.numpy as jnp

from repro.models.registry import Model
from repro.runtime.monitor import ServingCounters
from repro.serving.plan import ExecutionPlan, build_plan
from repro.serving.prefix_cache import PrefixCache, PrefixCacheConfig
from repro.serving.scheduler import Request, Scheduler
from repro.serving.slo import ServingSLO
from repro.serving.state_pool import SlotStatePool


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos_token: Optional[int] = None


class RequestHandle:
    """Live view of one submitted request; tokens stream in as generated.

    `outcome` is None while in flight and then one of "finished",
    "cancelled", "shed" (dropped by the overload policy — resubmit;
    with a prefix cache any completed boundary of the prompt resumes
    free), or "deadline" (evicted past its deadline)."""

    def __init__(self, request: Request):
        self.request = request
        self.tokens: list[int] = []        # everything generated so far
        # tokens generated BEFORE a snapshot restore (the pre-crash
        # output): a handle re-registered by `ServingEngine.restore`
        # carries them here, so the request's full stream is
        # `resumed + tokens` — bitwise equal to a never-crashed run
        # (repro.serving.snapshot).  Always [] on a fresh submit.
        self.resumed: list[int] = []
        self.done = False
        self.outcome: Optional[str] = None
        self._pending: collections.deque[int] = collections.deque()

    @property
    def rid(self) -> int:
        return self.request.rid

    def drain(self) -> list[int]:
        """Take (and clear) the tokens generated since the last drain.
        The polling counterpart to engine.stream()/astream(); mixing the
        two on one handle splits the stream between them."""
        out = list(self._pending)
        self._pending.clear()
        return out


class ServingEngine:
    """Continuous-batching RWKV serving (see module docstring).

    model      — a Model handle, or an arch id string (resolved with
                 `smoke=` like the rest of the launchers)
    params     — optional pre-built weights (f32/bf16 tree); initialized
                 from `seed` when omitted
    quantized  — pack weights to Δ-PoT W8 once at startup; per-op paths
                 dequantize inside the jit, fused paths in-kernel
    max_batch  — pool width: max concurrent sequences (compiled shape)
    prefill_chunk — prompt tokens absorbed per tick per prefilling slot
    fused_decode — decode path: False (per-op oracle) | "block" (one
                 Pallas launch per block) | "model" (the whole-model
                 megakernel); `True` is accepted as "block" (PR 2
                 compatibility).  All modes are bit-identical
                 (tests/test_fused_decode.py).
    fused_prefill — prefill path: False (per-op scan of decode_step) |
                 True (the fused chunked `prefill_chunk` path).
                 Bit-identical (tests/test_prefill.py).
    speculative — K >= 1: self-speculative decode.  Each decode tick a
                 truncated-stack drafter proposes K-1 tokens per lane,
                 one chunk-shaped verify call scores pending + drafts in
                 parallel, and the longest verifier-agreed prefix is
                 accepted (rejected lanes roll back through
                 masked_state_commit).  Every emitted token is sampled
                 from verifier logits, so the token streams are
                 bit-identical to the non-speculative engine
                 (tests/test_speculative.py) — K only moves throughput.
    draft_depth — layers the drafter keeps (default half the stack).
    mesh       — a `jax.sharding.Mesh` for data-parallel serving: the
                 slot pool and per-tick batch shard over the DP axes,
                 weights replicate (see docs/serving.md §multi-device);
                 tokens are bit-identical to the 1-device engine.
    plan       — a pre-built ExecutionPlan; overrides every path/quant/
                 mesh argument above (they describe a plan, and the plan
                 is the source of truth).
    prefix_cache — recurrent-state prefix cache (docs/serving.md §prefix
                 cache): True builds one with default sizing, a
                 `PrefixCacheConfig` sizes the device/host tiers, and a
                 `PrefixCache` instance is SHARED (its chunk granularity
                 must equal the plan's prefill_chunk).  On admission the
                 scheduler restores the longest cached ancestor prefix's
                 state into the slot and prefills only the uncached
                 suffix — bit-identical tokens to cache-off serving
                 (tests/test_prefix_cache.py).  Entries are keyed by the
                 plan's `cache_variant()` so packed/fp, rwkv4/rwkv6 and
                 per-op/chunked states never alias.
    slo        — a `ServingSLO` (repro.serving.slo): priority/deadline/
                 cache-aware admission, per-tick prefill budget
                 (translated bucket-aware via the plan's
                 `prefill_quota`), bounded queue with `Overloaded`
                 backpressure or load shedding, and the run() hang
                 watchdog.  The default preserves historical behavior
                 (docs/serving.md §"SLOs and overload").
    fault_injector — a `ServingFaultInjector` (repro.runtime.monitor)
                 for fault drills: forces cache-probe failures,
                 mid-speculation evictions, deadline expiry, in-process
                 crashes/SIGKILL, torn snapshot writes and state-leaf
                 corruption at chosen ticks (tests/test_faults.py).
    snapshot   — crash safety (repro.serving.snapshot, docs/operations
                 .md): a `SnapshotConfig` (or a directory string with
                 default cadence) makes the engine write tick-boundary
                 snapshots; `ServingEngine.restore(dir)` resumes every
                 stream bit-identically.
    sentinel_every — every N ticks (0 = off) one jitted reduction flags
                 NaN/Inf lanes; poisoned lanes are quarantined and
                 their requests requeued for a clean replay.
    path_fallback / path_fault_limit — automatic degraded mode: after
                 `path_fault_limit` consecutive fused decode/prefill
                 failures the scheduler demotes to the plan's per-op
                 twin (bit-identical stream, `DegradedMode` event in
                 `counters.degraded_events`).
    """

    def __init__(self, model: Model | str, *, params: Any = None,
                 smoke: bool = True, max_batch: int = 8,
                 prefill_chunk: int = 16, max_len: int = 0,
                 state_dtype=jnp.bfloat16, quantized: bool = False,
                 plane_policy=None,
                 fused_decode: bool | str | None = False,
                 fused_prefill: bool = False, seed: int = 0,
                 speculative: Optional[int] = None,
                 draft_depth: Optional[int] = None,
                 mesh=None, plan: Optional[ExecutionPlan] = None,
                 counters: Optional[ServingCounters] = None,
                 prefix_cache=None, slo: Optional[ServingSLO] = None,
                 fault_injector=None, snapshot=None,
                 sentinel_every: int = 0, path_fallback: bool = True,
                 path_fault_limit: int = 2):
        if plan is None:
            plan = build_plan(model, params, smoke=smoke, mesh=mesh,
                              quantized=quantized,
                              plane_policy=plane_policy,
                              fused_decode=fused_decode,
                              fused_prefill=fused_prefill,
                              prefill_chunk=prefill_chunk,
                              max_len=max_len, state_dtype=state_dtype,
                              seed=seed, speculative=speculative,
                              draft_depth=draft_depth)
        self.plan = plan
        self.model = plan.model
        self.quantized = plan.prepared.quantized
        self.fused_decode = False if plan.decode_desc.name == "per_op" \
            else plan.decode_desc.name
        self.fused_prefill = plan.prefill_desc.name == "chunked"
        self.params = plan.prepared.raw
        self.counters = counters if counters is not None else \
            ServingCounters()
        self.pool = SlotStatePool(self.model, max_batch,
                                  max_len=plan.max_len,
                                  dtype=plan.state_dtype,
                                  shardings=plan.state_shardings(max_batch))
        self.prefix_cache = self._build_cache(prefix_cache)
        sp = plan.speculative
        self.speculative = 0 if sp is None else sp.k
        self.slo = slo if slo is not None else ServingSLO()
        self.scheduler = Scheduler(
            self.pool, plan.decode_fn(max_batch), plan.prefill_fn(max_batch),
            prefill_chunk=plan.prefill_chunk, counters=self.counters,
            on_token=self._on_token, on_finish=self._on_finish,
            prefix_cache=self.prefix_cache,
            cache_variant=None if self.prefix_cache is None
            else self.plan.cache_variant(),
            speculative=self.speculative,
            draft_fn=plan.draft_fn(max_batch)
            if sp is not None and sp.k > 1 else None,
            verify_fn=plan.verify_fn(max_batch) if sp is not None else None,
            rollback_fn=plan.rollback_fn(max_batch)
            if sp is not None else None,
            slo=self.slo,
            prefill_quota=plan.prefill_quota(self.slo.prefill_budget,
                                             max_batch)
            if self.slo.prefill_budget > 0 else None,
            fault_injector=fault_injector,
            sentinel_every=sentinel_every, on_requeue=self._on_requeue,
            fallback_decode=(lambda: plan.fallback_decode_fn(max_batch))
            if path_fallback else None,
            fallback_prefill=(lambda: plan.fallback_prefill_fn(max_batch))
            if path_fallback else None,
            path_fault_limit=path_fault_limit,
            path_names={"decode": plan.decode_desc.name,
                        "prefill": plan.prefill_desc.name})
        self._handles: dict[int, RequestHandle] = {}
        self._next_rid = 0          # plain int: snapshots serialize it
        # crash safety (repro.serving.snapshot): a SnapshotConfig (or a
        # directory string) wires tick-boundary snapshots through the
        # scheduler's after_tick hook — and the torn-write fault drill
        # through on_torn_snapshot
        self.snapshot_manager = None
        if snapshot is not None and snapshot is not False:
            from repro.serving.snapshot import (SnapshotConfig,
                                                SnapshotManager)
            cfg = snapshot if isinstance(snapshot, SnapshotConfig) \
                else SnapshotConfig(directory=str(snapshot))
            self.snapshot_manager = SnapshotManager(self, cfg)
            self.scheduler.after_tick = self.snapshot_manager.maybe_save
            self.scheduler.on_torn_snapshot = self.snapshot_manager.\
                write_torn

    def _build_cache(self, prefix_cache) -> Optional[PrefixCache]:
        """Resolve the `prefix_cache=` ctor arg (None/False | True |
        PrefixCacheConfig | a shared PrefixCache) into a cache whose chunk
        granularity matches the plan — cached boundaries must be tick
        boundaries or a resumed suffix would re-chunk differently from a
        full prefill and lose bit parity."""
        if prefix_cache is None or prefix_cache is False:
            return None
        if isinstance(prefix_cache, PrefixCache):
            if prefix_cache.chunk != self.plan.prefill_chunk:
                raise ValueError(
                    f"shared prefix cache has chunk={prefix_cache.chunk} but "
                    f"the plan prefills in chunks of {self.plan.prefill_chunk}"
                    " — boundary states would not land on tick boundaries")
            cache = prefix_cache
        else:
            cfg = prefix_cache if isinstance(prefix_cache, PrefixCacheConfig) \
                else PrefixCacheConfig()
            cache = PrefixCache(self.plan.prefill_chunk, config=cfg)
        if cache.counters is None:
            cache.counters = self.counters
        return cache

    @property
    def trace_counts(self) -> dict:
        """The plan's trace counters ({"decode": 1, "prefill": 1} after
        any amount of serving — the no-recompile guarantee)."""
        return self.plan.trace_counts

    # -- request API ---------------------------------------------------------

    def submit(self, prompt: list[int],
               sampling: Optional[SamplingParams] = None, *,
               priority: int = 0, deadline_s: Optional[float] = None,
               **kw) -> RequestHandle:
        """Queue a request; returns a handle whose tokens fill in as the
        engine steps.  `kw` shorthand: max_new_tokens/temperature/seed/
        eos_token override the SamplingParams fields.  `priority` and
        `deadline_s` are SLO fields (repro.serving.slo).  With a bounded
        queue (`AdmissionPolicy.max_queue`) a full queue raises
        `Overloaded` — the request was NOT accepted and NO handle exists
        for it — or, under the shed policy, drops a strictly-less-urgent
        queued request (its handle completes with outcome "shed")."""
        sp = sampling or SamplingParams()
        if kw:
            sp = dataclasses.replace(sp, **kw)
        rid, self._next_rid = self._next_rid, self._next_rid + 1
        req = Request(rid=rid,
                      prompt=[int(t) for t in prompt],
                      max_new_tokens=sp.max_new_tokens,
                      temperature=sp.temperature, seed=sp.seed,
                      eos_token=sp.eos_token, priority=priority,
                      deadline_s=deadline_s)
        handle = RequestHandle(req)
        # register BEFORE enqueue (a shed victim's on_finish fires inside
        # enqueue and needs its own handle), but unregister if THIS
        # request is refused: a raised Overloaded leaves no handle behind
        self._handles[req.rid] = handle
        try:
            self.scheduler.enqueue(req)
        except BaseException:
            self._handles.pop(req.rid, None)
            raise
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        ok = self.scheduler.evict(handle.rid)
        return ok

    @property
    def handles(self) -> dict:
        """Live rid -> RequestHandle map (a copy).  Handles are popped as
        requests retire, so grab this BEFORE `run()` when you need every
        stream afterwards — in particular right after `restore`, where
        the resumed requests' handles are pre-registered here."""
        return dict(self._handles)

    def step(self) -> bool:
        """One scheduler tick; True while any request is in flight."""
        return self.scheduler.tick()

    def run(self) -> dict:
        """Drive until drained (with the scheduler's hang watchdog —
        see `ServingSLO.max_idle_ticks`); returns a counters snapshot."""
        self.scheduler.run()
        return self.counters.snapshot()

    def stream(self, handle: RequestHandle) -> Iterator[int]:
        """Synchronous token stream for one request; steps the engine
        (advancing ALL in-flight requests) whenever the stream runs dry."""
        while True:
            while handle._pending:
                yield handle._pending.popleft()
            if handle.done:
                return
            self.step()

    async def astream(self, handle: RequestHandle):
        """Async token stream; yields control to the event loop between
        engine ticks so concurrent consumers interleave."""
        import asyncio
        while True:
            while handle._pending:
                yield handle._pending.popleft()
            if handle.done:
                return
            self.step()
            await asyncio.sleep(0)

    # -- scheduler callbacks -------------------------------------------------

    def _on_token(self, req: Request, tok: int):
        h = self._handles[req.rid]
        h.tokens.append(tok)
        h._pending.append(tok)

    def _on_finish(self, req: Request, outcome: str = "finished"):
        h = self._handles.pop(req.rid)
        h.outcome = outcome
        h.done = True

    def _on_requeue(self, req: Request):
        """Quarantine callback: the request replays from scratch, so its
        handle forgets everything emitted from the poisoned lane — the
        deterministic replay re-delivers an identical (clean) stream."""
        h = self._handles.get(req.rid)
        if h is not None:
            h.tokens.clear()
            h.resumed = []
            h._pending.clear()

    # -- crash recovery ------------------------------------------------------

    @classmethod
    def restore(cls, directory: str, **kw) -> "ServingEngine":
        """Rebuild an engine from its newest committed snapshot and
        continue every stream bit-identically — pre-crash output is on
        each handle's `.resumed`, so `resumed + tokens` equals the
        never-crashed stream.  See `repro.serving.snapshot.restore_engine`
        for the keyword arguments (params/step/mesh/snapshot/...)."""
        from repro.serving.snapshot import restore_engine
        return restore_engine(directory, **kw)
