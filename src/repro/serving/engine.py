"""Serving engine front-end: submit(prompt) -> token stream.

Wires the slotted state pool and the scheduler to a model and builds the
engine's only two device programs:

  * the FUSED DECODE STEP — `decode_step` over the full pool with an
    active-slot mask (optionally unpacking Δ-PoT-quantized weights inside
    the jit, so int8 codes are what crosses HBM — the paper's bandwidth
    win riding along for free), and
  * the PREFILL CHUNK — absorbing up to `prefill_chunk` prompt tokens for
    EVERY prefilling slot in one device call; a per-slot-per-token
    validity mask maps every prompt length onto one compiled shape, and a
    fresh-slot mask resets newly admitted lanes to the initial state
    inside the same call.  Two structures, selected by `fused_prefill`:
    the per-op ORACLE (a `lax.scan` of the masked pool-wide `decode_step`
    — one D-wide matvec per token), and the FUSED CHUNKED path
    (`Model.prefill_chunk`): the whole chunk's token-shift / layernorm /
    projections / FFN as (S·C, D)-shaped matmuls, the WKV recurrence
    on-chip through the Pallas sequence kernels, and Δ-PoT-packed weights
    decoded INSIDE the matmul kernels so uint8 codes are all that crosses
    HBM during the prompt phase.  Both prefill structures are compiled
    with defined rounding semantics (`kernels.common.exact_jit`), which
    is what makes them BIT-identical to each other
    (tests/test_prefill.py).

All programs are traced exactly once (`trace_counts` proves it in
tests).  See docs/serving.md for the API walkthrough and
docs/architecture.md for the request lifecycle.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import exact_jit
from repro.models.registry import Model, get_model
from repro.runtime.monitor import ServingCounters
from repro.serving.scheduler import Request, Scheduler
from repro.serving.state_pool import SlotStatePool


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos_token: Optional[int] = None


class RequestHandle:
    """Live view of one submitted request; tokens stream in as generated."""

    def __init__(self, request: Request):
        self.request = request
        self.tokens: list[int] = []        # everything generated so far
        self.done = False
        self._pending: collections.deque[int] = collections.deque()

    @property
    def rid(self) -> int:
        return self.request.rid

    def drain(self) -> list[int]:
        """Take (and clear) the tokens generated since the last drain.
        The polling counterpart to engine.stream()/astream(); mixing the
        two on one handle splits the stream between them."""
        out = list(self._pending)
        self._pending.clear()
        return out


class ServingEngine:
    """Continuous-batching RWKV serving (see module docstring).

    model      — a Model handle, or an arch id string (resolved with
                 `smoke=` like the rest of the launchers)
    params     — optional pre-built weights (f32/bf16 tree); initialized
                 from `seed` when omitted
    quantized  — pack weights to Δ-PoT W8 once at startup; the fused step
                 dequantizes inside the jit (core.quant.serving)
    max_batch  — pool width: max concurrent sequences (compiled shape)
    prefill_chunk — prompt tokens absorbed per tick per prefilling slot
    fused_decode — decode-tick kernel granularity:
                 False    — per-op `decode_step` (the oracle);
                 "block"  — `decode_step_fused`: ONE Pallas launch per
                            block (L launches per tick), the whole block
                            datapath — including in-kernel Δ-PoT weight
                            decode when `quantized` — on-chip per launch;
                 "model"  — `decode_step_fused_model`: the whole-model
                            megakernel, ONE launch per tick with the grid
                            iterating over layers, the residual carried in
                            VMEM scratch and each layer's weight stream
                            double-buffered behind the previous layer's
                            compute.
                 `True` is accepted as "block" (PR 2 compatibility).  All
                 modes are bit-identical (tests/test_fused_decode.py).
    fused_prefill — prompt-phase kernel granularity:
                 False — the per-op oracle: one `lax.scan` of the masked
                         pool-wide `decode_step` over the chunk;
                 True  — the fused chunked path (`Model.prefill_chunk`):
                         chunk-shaped matmuls + the masked on-chip WKV
                         sequence kernel, with packed Δ-PoT weights
                         decoded in-kernel (no `unpack_params` in the
                         prefill trace).  Bit-identical to the oracle
                         (tests/test_prefill.py); decode is unaffected.
    """

    def __init__(self, model: Model | str, *, params: Any = None,
                 smoke: bool = True, max_batch: int = 8,
                 prefill_chunk: int = 16, max_len: int = 0,
                 state_dtype=jnp.bfloat16, quantized: bool = False,
                 fused_decode: bool = False, fused_prefill: bool = False,
                 seed: int = 0,
                 counters: Optional[ServingCounters] = None):
        if isinstance(model, str):
            model = get_model(model, smoke=smoke)
        if not model.has_decode:
            raise ValueError(f"{model.cfg.name} has no decode_step")
        if not model.position_free_decode:
            raise ValueError(
                f"{model.cfg.name}: decode_step consumes `pos`; the slotted "
                "engine needs a position-free recurrent state (rwkv4/rwkv6)")
        if fused_decode is True:
            fused_decode = "block"
        if fused_decode not in (False, None, "block", "model"):
            raise ValueError(
                f"fused_decode={fused_decode!r}: expected False, 'block' "
                "or 'model'")
        fused_decode = fused_decode or False
        if fused_decode == "block" and not model.has_fused_decode:
            raise ValueError(
                f"{model.cfg.name} has no decode_step_fused; fused_decode "
                "needs a model with the single-launch Pallas block kernel")
        if fused_decode == "model" and not model.has_fused_model_decode:
            raise ValueError(
                f"{model.cfg.name} has no decode_step_fused_model; "
                "fused_decode='model' needs a model with the whole-model "
                "Pallas megakernel")
        if fused_prefill and not model.has_fused_prefill:
            raise ValueError(
                f"{model.cfg.name} has no prefill_chunk; fused_prefill "
                "needs a model with the fused chunked-prefill entry "
                "(kernels/fused_prefill.py)")
        self.model = model
        self.quantized = quantized
        self.fused_decode = fused_decode
        self.fused_prefill = bool(fused_prefill)
        if params is None:
            params = model.init_params(jax.random.PRNGKey(seed))
        if quantized:
            from repro.core.quant.serving import pack_params
            params = pack_params(params)
        self.params = params
        # Megakernel hot path: cast + chunk the per-layer weight stream
        # ONCE at startup (per-dtype contiguous slabs; see
        # core.quant.serving.fuse_layer_stack).  Decode ticks consume the
        # prepared form; prefill keeps the raw tree (its per-op scan
        # needs stacked leaves).
        self._decode_params = model.prepare_fused_model_params(params) \
            if fused_decode == "model" else params
        # Fused-prefill hot path: pre-decode the few packed leaves the
        # chunk datapath consumes element-wise (rwkv6; rwkv4 is identity)
        # ONCE at startup, so the prefill trace never unpacks anything —
        # every remaining Δ-PoT code plane streams straight into a
        # chunk-matmul kernel.
        self._prefill_params = model.prepare_prefill_params(params) \
            if fused_prefill else params
        self.counters = counters if counters is not None else \
            ServingCounters()
        self.pool = SlotStatePool(model, max_batch, max_len=max_len,
                                  dtype=state_dtype)
        self.trace_counts = {"decode": 0, "prefill": 0}
        decode_fn, prefill_fn = self._build_steps(prefill_chunk)
        self.scheduler = Scheduler(
            self.pool, decode_fn, prefill_fn, prefill_chunk=prefill_chunk,
            counters=self.counters, on_token=self._on_token,
            on_finish=self._on_finish)
        self._handles: dict[int, RequestHandle] = {}
        self._rids = itertools.count()

    # -- compiled steps ------------------------------------------------------

    def _build_steps(self, prefill_chunk: int):
        model, axes = self.model, self.pool._axes
        tdef = self.pool._tdef
        quantized = self.quantized

        def maybe_unpack(params):
            if quantized:
                from repro.core.quant.serving import unpack_params
                return unpack_params(params)
            return params

        def masked(new_state, old_state, mask):
            new_l = jax.tree_util.tree_leaves(new_state)
            old_l = jax.tree_util.tree_leaves(old_state)
            out = []
            for n, o, ax in zip(new_l, old_l, axes):
                m = mask.reshape(tuple(
                    -1 if i == ax else 1 for i in range(n.ndim)))
                out.append(jnp.where(m, n, o))
            return jax.tree_util.tree_unflatten(tdef, out)

        fused = self.fused_decode

        def decode(params, state, tokens, mask):
            self.trace_counts["decode"] += 1   # increments only on trace
            if fused == "model":
                # whole-model megakernel: ONE launch for the layer stack;
                # packed Δ-PoT leaves pass through whole and decode inside
                logits, new_state = model.decode_step_fused_model(
                    params, state, tokens, jnp.int32(0))
            elif fused == "block":
                # single-launch block kernel; packed Δ-PoT leaves pass
                # through whole and decode inside the launch
                logits, new_state = model.decode_step_fused(
                    params, state, tokens, jnp.int32(0))
            else:
                logits, new_state = model.decode_step(
                    maybe_unpack(params), state, tokens, jnp.int32(0))
            return logits, masked(new_state, state, mask)

        # logits shape/dtype for the scan carry, without running anything
        S = self.pool.max_slots
        ab_logits = jax.eval_shape(
            lambda p, s, t: model.decode_step(p, s, t, jnp.int32(0))[0],
            jax.eval_shape(maybe_unpack, self.params),
            self.pool.state, jax.ShapeDtypeStruct((S, 1), jnp.int32))
        fresh_lane = self.pool._fresh   # batch-1 leaves broadcast per slot
        fused_prefill = self.fused_prefill

        def prefill(params, state, tokens, valid, fresh):
            self.trace_counts["prefill"] += 1  # increments only on trace
            # reset newly admitted lanes to the fresh state in-call
            state = masked(state, fresh_lane, ~fresh)
            if fused_prefill:
                # fused chunked path: chunk-shaped matmuls + on-chip WKV
                # scan; packed Δ-PoT leaves decode INSIDE the kernels, so
                # no maybe_unpack here — codes cross HBM, not bf16
                return model.prefill_chunk(params, state, tokens, valid)
            p = maybe_unpack(params)

            def body(carry, xs):
                state, last = carry
                tok, ok = xs                    # tok (S,), ok (S,)
                logits, stepped = model.decode_step(
                    p, state, tok[:, None], jnp.int32(0))
                state = masked(stepped, state, ok)
                last = jnp.where(ok[:, None, None], logits, last)
                return (state, last), None

            last0 = jnp.zeros(ab_logits.shape, ab_logits.dtype)
            (state, last), _ = jax.lax.scan(
                body, (state, last0), (tokens.T, valid.T))
            return state, last

        j_decode = jax.jit(decode, donate_argnums=(1,))
        # BOTH prefill structures compile with defined rounding semantics
        # (exact_jit: no excess-precision folding) — the property that
        # makes the fused chunked path bit-identical to the per-op scan;
        # decode keeps the plain jit (its bits are pinned by PR 2/3 tests).
        j_prefill = exact_jit(prefill, donate_argnums=(1,))
        return (lambda state, toks, mask:
                j_decode(self._decode_params, state, jnp.asarray(toks),
                         jnp.asarray(mask)),
                lambda state, toks, valid, fresh:
                j_prefill(self._prefill_params, state, jnp.asarray(toks),
                          jnp.asarray(valid), jnp.asarray(fresh)))

    # -- request API ---------------------------------------------------------

    def submit(self, prompt: list[int],
               sampling: Optional[SamplingParams] = None,
               **kw) -> RequestHandle:
        """Queue a request; returns a handle whose tokens fill in as the
        engine steps.  `kw` shorthand: max_new_tokens/temperature/seed/
        eos_token override the SamplingParams fields."""
        sp = sampling or SamplingParams()
        if kw:
            sp = dataclasses.replace(sp, **kw)
        req = Request(rid=next(self._rids),
                      prompt=[int(t) for t in prompt],
                      max_new_tokens=sp.max_new_tokens,
                      temperature=sp.temperature, seed=sp.seed,
                      eos_token=sp.eos_token)
        handle = RequestHandle(req)
        self._handles[req.rid] = handle
        self.scheduler.enqueue(req)
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        ok = self.scheduler.evict(handle.rid)
        return ok

    def step(self) -> bool:
        """One scheduler tick; True while any request is in flight."""
        return self.scheduler.tick()

    def run(self) -> dict:
        """Drive until drained; returns a counters snapshot."""
        while self.step():
            pass
        return self.counters.snapshot()

    def stream(self, handle: RequestHandle) -> Iterator[int]:
        """Synchronous token stream for one request; steps the engine
        (advancing ALL in-flight requests) whenever the stream runs dry."""
        while True:
            while handle._pending:
                yield handle._pending.popleft()
            if handle.done:
                return
            self.step()

    async def astream(self, handle: RequestHandle):
        """Async token stream; yields control to the event loop between
        engine ticks so concurrent consumers interleave."""
        import asyncio
        while True:
            while handle._pending:
                yield handle._pending.popleft()
            if handle.done:
                return
            self.step()
            await asyncio.sleep(0)

    # -- scheduler callbacks -------------------------------------------------

    def _on_token(self, req: Request, tok: int):
        h = self._handles[req.rid]
        h.tokens.append(tok)
        h._pending.append(tok)

    def _on_finish(self, req: Request):
        h = self._handles.pop(req.rid)
        h.done = True
