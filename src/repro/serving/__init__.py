"""Continuous-batching serving for recurrent-state models.

The JAX analog of the paper's fully-on-chip serving story: all requests'
O(1) recurrent states stay resident in one preallocated device pool
(`state_pool`), a scheduler interleaves chunked prefill with one fused
masked decode step per tick (`scheduler`), an `ExecutionPlan` selects the
decode/prefill paths, prepares params once, caches the compiled programs
and places everything on the (optional) mesh (`plan`), and the engine
front-end turns `submit(prompt)` into a token stream (`engine`).  A
recurrent-state prefix cache (`prefix_cache`) turns repeated prompt
prefixes into O(1) state restores — near-zero TTFT, bit-identical
tokens.  An SLO layer (`slo`) adds priority/deadline/cache-aware
admission, a per-tick prefill budget, and explicit overload behavior —
bounded queue with typed `Overloaded` backpressure or load shedding —
so bursts degrade gracefully instead of collapsing latency.  Crash
safety (`snapshot`): tick-boundary engine snapshots with bit-identical
resume, prepared-param integrity checksums, NaN/Inf lane sentinels
with quarantine-and-requeue, and automatic fused→per-op path fallback.
docs/serving.md has the API guide; docs/architecture.md walks a
request through the lifecycle and the plan diagram;
docs/operations.md is the crash-recovery runbook.
"""
from repro.serving.engine import (RequestHandle, SamplingParams,
                                  ServingEngine)
from repro.serving.plan import ExecutionPlan, build_plan
from repro.serving.prefix_cache import (CacheVariant, PrefixCache,
                                        PrefixCacheConfig, StateLease)
from repro.serving.scheduler import Request, Scheduler, sample_token
from repro.serving.slo import (AdmissionPolicy, Overloaded,
                               SchedulerHang, ServingSLO)
from repro.serving.snapshot import (EngineSnapshot, IntegrityError,
                                    SnapshotConfig, SnapshotManager,
                                    load_snapshot, restore_engine)
from repro.serving.state_pool import SlotStatePool

__all__ = ["ServingEngine", "SamplingParams", "RequestHandle",
           "Request", "Scheduler", "sample_token", "SlotStatePool",
           "ExecutionPlan", "build_plan", "PrefixCache",
           "PrefixCacheConfig", "CacheVariant", "StateLease",
           "ServingSLO", "AdmissionPolicy", "Overloaded",
           "SchedulerHang", "SnapshotConfig", "SnapshotManager",
           "EngineSnapshot", "IntegrityError", "load_snapshot",
           "restore_engine"]
