"""Execution plans: the serving engine's declarative path + placement layer.

PRs 1–4 grew the engine an ad-hoc capability matrix — `has_decode` /
`has_fused_decode` / `has_fused_model_decode` / `has_fused_prefill`
boolean flags, three separately-wired `prepare_*` param transforms, and
program building inlined in `ServingEngine._build_steps`.  An
`ExecutionPlan` collapses that matrix into one object:

    plan = build_plan(model, params, mesh=mesh,
                      fused_decode="model", fused_prefill=True,
                      prefill_chunk=16, max_batch=8)

owning, in order:

  * PATH SELECTION — the decode and prefill paths are picked from the
    registry's `PathDescriptor` tables (`models.registry.DECODE_PATHS` /
    `PREFILL_PATHS`), not from booleans: a path exists iff the model ships
    its entry point, and its descriptor says how params are prepared and
    whether packed Δ-PoT leaves decode in-kernel.
  * PARAM PREPARATION — `pack_params` (when quantized) plus each selected
    path's one-time prep run in ONE pass, producing a
    `core.quant.serving.PreparedParams` (raw / decode / prefill forms);
    the engine never re-derives a transform per flag again.
  * PROGRAM CACHE — compiled decode/prefill programs keyed by
    (path, batch bucket, state dtype).  A key is traced exactly once for
    the life of the plan (`trace_counts` proves it, exactly as the engine
    tests always asserted); re-requesting a bucket is a cache hit, never a
    recompile.  The masking semantics every program commits state through
    live here too (`masked_state_commit`) — the single definition shared
    with the sequential test oracle.
  * MESH PLACEMENT — on a `jax.sharding.Mesh` the slot state pool and the
    per-tick token batch shard data-parallel over the DP axes
    (`parallel.sharding.pool_shardings` / `batch_sharding`, with the
    divisibility fallback), while every prepared weight form — including
    the megakernel's L-stacked `FusedLayerStack` slabs — is placed ONCE,
    replicated, at plan build.  Slots are independent sequences, so DP
    sharding introduces no step-time collectives and the sharded engine's
    tokens are bit-identical to the 1-device engine's
    (tests/test_plan.py runs the 8-virtual-device proof).

See docs/architecture.md for the plan diagram and docs/serving.md for the
multi-device serving walkthrough.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quant.serving import PreparedParams
from repro.kernels.common import exact_jit
from repro.models.registry import DraftDescriptor, Model, PathDescriptor

# ---------------------------------------------------------------------------
# Shared semantics: masked state commits + in-trace Δ-PoT unpack
# ---------------------------------------------------------------------------


def masked_state_commit(new_state, old_state, mask, axes):
    """Commit `new_state` only where `mask` is set along each leaf's slot
    axis: `where(mask, new, old)` with the mask broadcast into position
    `axes[i]` of leaf i (the `Model.decode_state_batch_axes` layout).

    THE masking semantics of the serving engine — a lane whose mask is
    False is *computed* (fixed shapes beat recompiles) but its state never
    moves, so free or mid-prefill slots are undisturbed by decode traffic.
    Defined once here and shared by every plan program AND the sequential
    test oracle (tests/test_prefill.py), so the engine and its
    bit-identity reference can never drift."""
    new_l = jax.tree_util.tree_leaves(new_state)
    old_l = jax.tree_util.tree_leaves(old_state)
    tdef = jax.tree_util.tree_structure(old_state)
    out = []
    for n, o, ax in zip(new_l, old_l, axes):
        m = mask.reshape(tuple(
            -1 if i == ax else 1 for i in range(n.ndim)))
        out.append(jnp.where(m, n, o))
    return jax.tree_util.tree_unflatten(tdef, out)


def maybe_unpack(params, quantized: bool):
    """In-trace Δ-PoT decode for the per-op paths: packed trees unpack
    INSIDE the jit (uint8 codes cross HBM; the exp2 decode fuses into the
    consumer matmuls).  Fused paths never call this — their descriptors
    carry `fused=True` and the kernels decode per leaf."""
    if quantized:
        from repro.core.quant.serving import unpack_params
        return unpack_params(params)
    return params


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpeculativePath:
    """The plan's self-speculative decode configuration.

    Each decode tick becomes draft -> verify -> accept: a cheap drafter
    (the first `draft_depth` layers of the SAME model, running the per-op
    decode step on a slice of the live pool state) proposes k-1 tokens per
    lane, ONE chunk-shaped verify call — the PR 4 prefill restructuring,
    `exact_jit`-pinned — scores the lane's pending token plus all drafts
    in parallel, and the scheduler accepts the longest prefix the verifier
    agrees with, rolling rejected lanes back through the same
    `masked_state_commit` every other program uses.  The drafter's quality
    only moves the ACCEPTANCE RATE: every emitted token is sampled from
    verifier logits, so the output stream is bit-identical to the
    non-speculative engine by construction (tests/test_speculative.py).

    k           — verify window width per tick: the lane's pending token
                  plus k-1 drafted tokens (k=1 is the degenerate
                  verify-only tick: no drafter, no draft program)
    draft_depth — layers the truncated-stack drafter keeps
    desc        — the registry DraftDescriptor this path was built from
    """
    k: int
    draft_depth: int
    desc: DraftDescriptor


def _normalize_decode(fused_decode) -> str:
    if fused_decode is True:          # PR 2 compatibility
        fused_decode = "block"
    if fused_decode in (False, None):
        return "per_op"
    if fused_decode in ("block", "model"):
        return fused_decode
    raise ValueError(
        f"fused_decode={fused_decode!r}: expected False, 'block' "
        "or 'model'")


class ExecutionPlan:
    """One model's executable serving configuration (see module docstring).

    Attributes:
      model         — the registry Model handle
      prepared      — PreparedParams (raw / decode / prefill forms, placed
                      on the mesh when one is set)
      decode_desc / prefill_desc — the selected PathDescriptors
      prefill_chunk — prompt tokens absorbed per prefill call per slot
      mesh          — the serving mesh, or None (single device)
      trace_counts  — {"decode": n, "prefill": n} trace counters; stays at
                      1 per used (path, bucket, dtype) key for the life of
                      the plan (the no-recompile guarantee)
    """

    def __init__(self, model: Model, prepared: PreparedParams,
                 decode_desc: PathDescriptor, prefill_desc: PathDescriptor,
                 *, prefill_chunk: int = 16, max_len: int = 0,
                 state_dtype=jnp.bfloat16, mesh=None,
                 speculative: Optional[SpeculativePath] = None):
        self.model = model
        self.prepared = prepared
        self.decode_desc = decode_desc
        self.prefill_desc = prefill_desc
        self.prefill_chunk = int(prefill_chunk)
        self.max_len = int(max_len)
        self.state_dtype = jnp.dtype(state_dtype)
        self.mesh = mesh
        self.speculative = speculative
        self.state_axes = model.decode_state_batch_axes()
        self.trace_counts = {"decode": 0, "prefill": 0}
        if speculative is not None:
            # speculative programs get their own counters; the keys exist
            # only when the path is configured, so non-speculative plans
            # keep the exact historical {"decode", "prefill"} shape
            self.trace_counts.update({"verify": 0, "rollback": 0})
            if speculative.k > 1:
                self.trace_counts["draft"] = 0
        self._programs: dict = {}
        self._batch_shardings: dict = {}
        self._fresh_lane_cache = None
        # per-op twin params for path-fallback demotion, prepared lazily
        # the first time a fallback program is requested
        self._fallback_decode_params = None
        self._fallback_prefill_params = None
        # build_plan records its keyword inputs here so a snapshot can
        # rebuild an identical plan from config alone (repro.serving
        # .snapshot); None on hand-constructed plans, which are then not
        # snapshot-restorable
        self.build_config: Optional[dict] = None
        if mesh is not None:
            self._place_params()

    # -- mesh placement ----------------------------------------------------

    def _place_params(self):
        """Replicate every prepared weight form across the mesh ONCE at
        startup — including the megakernel's L-stacked FusedLayerStack
        slabs — so no step ever moves a weight.  Placement is LEAF-wise
        with an identity cache: a prepared form that rebuilt the tree but
        kept most weight leaves (e.g. rwkv6's prefill prep, which decodes
        4 small leaves and aliases every matmul weight) shares the raw
        form's device buffers instead of replicating the model twice."""
        from repro.parallel.sharding import replicated_sharding
        rep = replicated_sharding(self.mesh)
        placed: dict = {}   # id(leaf) -> (leaf pin, placed leaf)

        def put(leaf):
            key = id(leaf)
            if key not in placed:
                placed[key] = (leaf, jax.device_put(leaf, rep))
            return placed[key][1]

        self.prepared = dataclasses.replace(
            self.prepared,
            raw=jax.tree_util.tree_map(put, self.prepared.raw),
            decode=jax.tree_util.tree_map(put, self.prepared.decode),
            prefill=jax.tree_util.tree_map(put, self.prepared.prefill),
            draft=None if self.prepared.draft is None else
            jax.tree_util.tree_map(put, self.prepared.draft))

    def cache_variant(self, *, numerics: str = "exact"):
        """The prefix-cache `CacheVariant` this plan's prefill states file
        under — derived HERE so the isolation key can never drift from
        what actually executes: arch from the model config, quant form
        from the prepared params' ACTUAL per-tensor planes
        (`core.quant.serving.plane_fingerprint` — "fp" / "dpot_w8" /
        "dpot_mix_<hash>", so two plane policies can never alias one
        cache entry), prefill path from the selected descriptor, state
        dtype from the pool dtype.  The engine's paths all run exact
        numerics; `numerics="hw_lut"` exists for callers driving the
        paper's LUT/PWL variant directly (tests/test_prefix_cache.py)."""
        from repro.core.quant.serving import plane_fingerprint
        from repro.serving.prefix_cache import CacheVariant
        return CacheVariant(
            arch=self.model.cfg.name,
            quant=plane_fingerprint(self.prepared.raw),
            numerics=numerics,
            prefill=self.prefill_desc.name,
            state_dtype=self.state_dtype.name)

    def prefill_quota(self, budget_tokens: int, batch: int) -> int:
        """Per-tick prefill LANE quota for a chunk-token budget — the
        bucket-aware translation the SLO layer uses (repro.serving.slo).

        The prefill program's shape is (batch bucket, prefill_chunk)
        regardless of load, so a budget can never shrink a call — it can
        only choose HOW MANY lanes' validity rows are populated this
        tick.  The budget therefore rounds down to whole chunks
        (budget // prefill_chunk lanes) with a floor of ONE lane, so
        prefill always makes forward progress (a budget below one chunk
        throttles to one lane per tick, never zero — no budget-induced
        wedge).  Because the compiled-program cache key (path, batch
        bucket, dtype) never sees the budget, the traced-once guarantee
        is untouched: budgeted and unbudgeted serving hit the same
        compiled programs (tests assert `trace_counts` stays 1)."""
        if budget_tokens <= 0:
            return int(batch)
        return max(1, min(int(batch),
                          int(budget_tokens) // self.prefill_chunk))

    def state_shardings(self, batch: int):
        """NamedSharding tree for a `batch`-slot pool on this plan's mesh
        (None without a mesh): slot axis data-parallel, divisibility
        fallback to replication."""
        if self.mesh is None:
            return None
        from repro.parallel.sharding import pool_shardings
        ab = jax.eval_shape(
            lambda: self.model.init_slot_state(batch, self.max_len,
                                               self.state_dtype))
        return pool_shardings(self.model.decode_state_axes(), ab, self.mesh)

    def _place_batch(self, x):
        """Per-tick host batch (tokens / masks) -> device, slot axis
        sharded like the pool.  The NamedSharding is cached per shape —
        tick shapes are fixed for a program's life, so the spec-building
        Python never runs in the serving hot loop."""
        x = jnp.asarray(x)
        if self.mesh is None:
            return x
        sh = self._batch_shardings.get(x.shape)
        if sh is None:
            from repro.parallel.sharding import batch_sharding
            sh = self._batch_shardings[x.shape] = batch_sharding(
                x.shape, self.mesh)
        return jax.device_put(x, sh)

    # -- program cache -----------------------------------------------------

    def _key(self, kind: str, batch: int):
        desc = self.decode_desc if kind == "decode" else self.prefill_desc
        return (kind, desc.name, int(batch), self.state_dtype.name)

    def _fresh_lane(self):
        # batch-1 template; leaves broadcast per slot inside the programs
        if self._fresh_lane_cache is None:
            self._fresh_lane_cache = self.model.init_slot_state(
                1, self.max_len, self.state_dtype)
        return self._fresh_lane_cache

    def decode_fn(self, batch: int):
        """The compiled decode program for a `batch`-slot pool:
        fn(state, tokens (S,1), mask (S,)) -> (logits, new_state).
        Cached by (path, batch bucket, dtype) — the same key always
        returns the same program, traced once."""
        key = self._key("decode", batch)
        if key not in self._programs:
            self._programs[key] = self._build_decode()
        return self._programs[key]

    def prefill_fn(self, batch: int):
        """The compiled prefill program for a `batch`-slot pool:
        fn(state, tokens (S,C), valid (S,C), fresh (S,))
        -> (new_state, last-valid logits).  Cached like `decode_fn`."""
        key = self._key("prefill", batch)
        if key not in self._programs:
            self._programs[key] = self._build_prefill(batch)
        return self._programs[key]

    def draft_fn(self, batch: int):
        """The compiled drafter for a `batch`-slot pool:
        fn(state, tokens (S,1)) -> drafted (S, K-1) int32 — a greedy
        argmax chain of the truncated layer stack, its state sliced from
        the live pool IN-trace (`Model.truncate_state`; never a second
        pool).  Cached like `decode_fn`; only exists for K > 1."""
        sp = self.speculative
        key = ("draft", sp.desc.name, int(batch), self.state_dtype.name)
        if key not in self._programs:
            self._programs[key] = self._build_draft()
        return self._programs[key]

    def verify_fn(self, batch: int):
        """The compiled speculative verifier for a `batch`-slot pool:
        fn(state, tokens (S,K), valid (S,K))
        -> (logits (S,K,V), new_state).  Row j holds the logits the plain
        decode tick would produce after consuming tokens[:, :j+1]; state
        commits through every valid position (the chunked-prefill
        machinery, all-position head).  NOT donating its input state —
        the caller's pre-verify pool-state reference IS the rollback
        snapshot.  Cached like `decode_fn`."""
        key = ("verify", self.prefill_desc.name, int(batch),
               self.state_dtype.name)
        if key not in self._programs:
            self._programs[key] = self._build_verify()
        return self._programs[key]

    def rollback_fn(self, batch: int):
        """The compiled speculation rollback for a `batch`-slot pool:
        fn(committed, snapshot, reject (S,)) -> state where rejected
        lanes take the pre-verify snapshot and everyone else keeps the
        verified commit — `masked_state_commit`, the engine's one masking
        semantics.  Donates `committed` (consumed); the snapshot
        survives.  Cached like `decode_fn`."""
        key = ("rollback", "masked", int(batch), self.state_dtype.name)
        if key not in self._programs:
            self._programs[key] = self._build_rollback()
        return self._programs[key]

    # -- program builders (the former ServingEngine._build_steps) ----------

    def _decode_step(self, name: Optional[str] = None):
        """The selected decode path (or an explicit `name` override — the
        fallback twins use "per_op") as a uniform
        (params, state, tokens) -> (logits, new_state) step."""
        model, quantized = self.model, self.prepared.quantized
        if name is None:
            name = self.decode_desc.name
        if name == "model":
            # whole-model megakernel: ONE launch for the layer stack;
            # packed Δ-PoT leaves pass through whole and decode inside
            return lambda p, s, t: model.decode_step_fused_model(
                p, s, t, jnp.int32(0))
        if name == "block":
            # single-launch block kernel; packed leaves decode per launch
            return lambda p, s, t: model.decode_step_fused(
                p, s, t, jnp.int32(0))
        return lambda p, s, t: model.decode_step(
            maybe_unpack(p, quantized), s, t, jnp.int32(0))

    def _build_decode(self, *, path: Optional[str] = None,
                      path_params=None, count_key: str = "decode"):
        axes = self.state_axes
        step = self._decode_step(path)

        def decode(params, state, tokens, mask):
            self.trace_counts[count_key] += 1  # increments only on trace
            logits, new_state = step(params, state, tokens)
            return logits, masked_state_commit(new_state, state, mask, axes)

        # exact_jit like every other token-producing program: defined
        # rounding semantics make the speculative verifier's bit-parity
        # with this step STRUCTURAL, not an accident of fusion choices
        # (bits unchanged vs. the former plain jit — PR 2/3 pins hold)
        j_decode = exact_jit(decode, donate_argnums=(1,))
        params = (self.prepared.decode if path_params is None
                  else path_params)
        return lambda state, toks, mask: j_decode(
            params, state, self._place_batch(toks), self._place_batch(mask))

    def _build_prefill(self, batch: int, *,
                       chunked: Optional[bool] = None,
                       path_params=None, count_key: str = "prefill"):
        model, axes = self.model, self.state_axes
        quantized = self.prepared.quantized
        fresh_lane = self._fresh_lane()
        if chunked is None:
            chunked = self.prefill_desc.name == "chunked"
        # logits shape/dtype for the scan carry, without running anything
        ab_logits = jax.eval_shape(
            lambda p, s, t: model.decode_step(p, s, t, jnp.int32(0))[0],
            jax.eval_shape(lambda p: maybe_unpack(p, quantized),
                           self.prepared.raw),
            jax.eval_shape(
                lambda: model.init_slot_state(batch, self.max_len,
                                              self.state_dtype)),
            jax.ShapeDtypeStruct((batch, 1), jnp.int32))

        def prefill(params, state, tokens, valid, fresh):
            self.trace_counts[count_key] += 1  # increments only on trace
            # reset newly admitted lanes to the fresh state in-call (the
            # batch-1 fresh template broadcasts into the masked-off lanes)
            state = masked_state_commit(state, fresh_lane, ~fresh, axes)
            if chunked:
                # fused chunked path: chunk-shaped matmuls + on-chip WKV
                # scan; packed Δ-PoT leaves decode INSIDE the kernels, so
                # no maybe_unpack here — codes cross HBM, not bf16
                return model.prefill_chunk(params, state, tokens, valid)
            p = maybe_unpack(params, quantized)

            def body(carry, xs):
                state, last = carry
                tok, ok = xs                    # tok (S,), ok (S,)
                logits, stepped = model.decode_step(
                    p, state, tok[:, None], jnp.int32(0))
                state = masked_state_commit(stepped, state, ok, axes)
                last = jnp.where(ok[:, None, None], logits, last)
                return (state, last), None

            last0 = jnp.zeros(ab_logits.shape, ab_logits.dtype)
            (state, last), _ = jax.lax.scan(
                body, (state, last0), (tokens.T, valid.T))
            return state, last

        # BOTH prefill structures compile with defined rounding semantics
        # (exact_jit: no excess-precision folding) — the property that
        # makes the fused chunked path bit-identical to the per-op scan.
        j_prefill = exact_jit(prefill, donate_argnums=(1,))
        params = (self.prepared.prefill if path_params is None
                  else path_params)
        return lambda state, toks, valid, fresh: j_prefill(
            params, state, self._place_batch(toks),
            self._place_batch(valid), self._place_batch(fresh))

    # -- path-fallback twins (degraded mode) -------------------------------

    def fallback_decode_fn(self, batch: int):
        """The per-op twin of the selected decode path — built lazily the
        first time the scheduler demotes a repeatedly-faulting fused
        decode path (DegradedMode, docs/operations.md).  Returns None
        when the selected path already IS per_op (nothing to demote to).
        Per-op and fused paths are bit-identical by the repo's parity
        pins, so a demotion never changes the token stream.  The twin's
        params and programs cache like every other plan program; the
        "decode_fallback" trace key is added lazily so undemoted plans
        keep the historical trace_counts shape."""
        if self.decode_desc.name == "per_op":
            return None
        key = ("decode_fallback", "per_op", int(batch),
               self.state_dtype.name)
        if key not in self._programs:
            self.trace_counts.setdefault("decode_fallback", 0)
            if self._fallback_decode_params is None:
                desc = self.model.decode_paths()["per_op"]
                self._fallback_decode_params = \
                    self.model.prepare_path_params(desc, self.prepared.raw)
            self._programs[key] = self._build_decode(
                path="per_op", path_params=self._fallback_decode_params,
                count_key="decode_fallback")
        return self._programs[key]

    def fallback_prefill_fn(self, batch: int):
        """The per-op-scan twin of the chunked prefill path, for prefill
        demotion.  Returns None when prefill is already per_op.  Same
        caching and bit-parity story as `fallback_decode_fn`."""
        if self.prefill_desc.name == "per_op":
            return None
        key = ("prefill_fallback", "per_op", int(batch),
               self.state_dtype.name)
        if key not in self._programs:
            self.trace_counts.setdefault("prefill_fallback", 0)
            if self._fallback_prefill_params is None:
                desc = self.model.prefill_paths()["per_op"]
                self._fallback_prefill_params = \
                    self.model.prepare_path_params(desc, self.prepared.raw)
            self._programs[key] = self._build_prefill(
                batch, chunked=False,
                path_params=self._fallback_prefill_params,
                count_key="prefill_fallback")
        return self._programs[key]

    def _build_draft(self):
        sp = self.speculative
        model, quantized = self.model, self.prepared.quantized
        dmodel = model.truncated(sp.draft_depth)
        depth, steps = sp.draft_depth, sp.k - 1

        def draft(params, state, tokens):
            self.trace_counts["draft"] += 1    # increments only on trace
            p = maybe_unpack(params, quantized)
            tstate = model.truncate_state(state, depth)

            def body(carry, _):
                tok, st = carry
                logits, st = dmodel.decode_step(p, st, tok, jnp.int32(0))
                nxt = jnp.argmax(logits[:, 0].astype(jnp.float32),
                                 axis=-1).astype(jnp.int32)[:, None]
                return (nxt, st), nxt[:, 0]

            _, toks = jax.lax.scan(body, (tokens, tstate), None,
                                   length=steps)
            return toks.T                      # (S, K-1)

        # ONE device call proposes the whole window (greedy feedback runs
        # in the scan, not in K-1 host round-trips).  NO donation: the
        # pool state this slices from is the tick's rollback snapshot.
        j_draft = exact_jit(draft)
        params = self.prepared.draft
        return lambda state, toks: j_draft(params, state,
                                           self._place_batch(toks))

    def _build_verify(self):
        model, axes = self.model, self.state_axes
        quantized = self.prepared.quantized
        chunked = self.prefill_desc.name == "chunked"

        def verify(params, state, tokens, valid):
            self.trace_counts["verify"] += 1   # increments only on trace
            if chunked:
                # the PR 4 chunk-shaped restructuring with an all-position
                # head: every valid window token's logits in one call
                new_state, logits = model.prefill_chunk_logits(
                    params, state, tokens, valid)
                return logits, new_state
            p = maybe_unpack(params, quantized)

            def body(state, xs):
                tok, ok = xs                   # (S,), (S,)
                logits, stepped = model.decode_step(
                    p, state, tok[:, None], jnp.int32(0))
                state = masked_state_commit(stepped, state, ok, axes)
                row = jnp.where(ok[:, None], logits[:, 0],
                                jnp.zeros_like(logits[:, 0]))
                return state, row

            state, rows = jax.lax.scan(body, state, (tokens.T, valid.T))
            return jnp.swapaxes(rows, 0, 1), state      # (S, K, V)

        # exact_jit (same rounding semantics as the decode step — the
        # losslessness theorem); NO donation: the caller's pre-verify
        # pool-state reference is the rollback snapshot.
        j_verify = exact_jit(verify)
        params = self.prepared.prefill
        return lambda state, toks, valid: j_verify(
            params, state, self._place_batch(toks),
            self._place_batch(valid))

    def _build_rollback(self):
        axes = self.state_axes

        def rollback(committed, snapshot, reject):
            self.trace_counts["rollback"] += 1  # increments only on trace
            return masked_state_commit(snapshot, committed, reject, axes)

        # `committed` is consumed (donated); the snapshot survives — the
        # scheduler re-advances rejected lanes from the rolled-back state
        # through the verifier with accepted-prefix validity masks.
        j_rollback = exact_jit(rollback, donate_argnums=(0,))
        return lambda committed, snapshot, reject: j_rollback(
            committed, snapshot, self._place_batch(reject))


def _registry_arch_id(cfg_name: str, smoke: bool) -> str:
    """The registry arch id whose (smoke) config produced `cfg_name`.
    Smoke configs don't always embed the full id (rwkv6-7b's smoke cfg is
    named "rwkv6-smoke"), so stripping the suffix isn't enough — scan the
    registry for the id whose config name matches, so a snapshot's
    `build_config["arch"]` always round-trips through `get_model`."""
    from repro.configs.base import get_config, list_configs, smoke_config
    base = cfg_name[:-len("-smoke")] if smoke else cfg_name
    known = list_configs()
    for arch in ([base] if base in known else []) + known:
        try:
            cfg = smoke_config(arch) if smoke else get_config(arch)
        except Exception:
            continue
        if cfg.name == cfg_name:
            return arch
    return base     # unregistered/ad-hoc config: best effort


def build_plan(model: Model | str, params: Any = None, *,
               mesh=None, smoke: bool = True, quantized: bool = False,
               plane_policy=None,
               fused_decode: bool | str | None = False,
               fused_prefill: bool = False, prefill_chunk: int = 16,
               max_len: int = 0, state_dtype=jnp.bfloat16,
               seed: int = 0, speculative: Optional[int] = None,
               draft_depth: Optional[int] = None,
               decode_prepare_kw: Optional[dict] = None) -> ExecutionPlan:
    """Select paths, prepare params (one pass) and build an ExecutionPlan.

    model         — a Model handle or arch id (resolved with `smoke=`)
    params        — pre-built weights (f32/bf16 tree); initialized from
                    `seed` when omitted
    mesh          — a jax Mesh for data-parallel serving, or None
    quantized     — pack weights once; per-op paths unpack in-trace, fused
                    paths decode in-kernel.  Default plane is Δ-PoT W8.
    plane_policy  — a `core.quant.PlanePolicy` choosing W8 / W4-nibble /
                    VQ-codebook per tensor (requires quantized=True); None
                    keeps the historical all-W8 packing
    fused_decode  — False | "block" | "model" (True means "block")
    fused_prefill — False (per-op scan) | True (fused chunked path)
    speculative   — K >= 1: self-speculative decode with a K-token verify
                    window per tick (SpeculativePath; K=1 is verify-only)
    draft_depth   — layers the truncated-stack drafter keeps (default:
                    the registry DraftDescriptor's, else half the stack)

    Raises ValueError with the engine's historical messages when the model
    lacks a requested path — the descriptor tables are the source of
    truth."""
    from repro.models.registry import get_model
    if isinstance(model, str):
        model = get_model(model, smoke=smoke)
    decode_paths = model.decode_paths()
    prefill_paths = model.prefill_paths()
    if "per_op" not in decode_paths:
        raise ValueError(f"{model.cfg.name} has no decode_step")
    if not model.position_free_decode:
        raise ValueError(
            f"{model.cfg.name}: decode_step consumes `pos`; the slotted "
            "engine needs a position-free recurrent state (rwkv4/rwkv6)")
    decode_name = _normalize_decode(fused_decode)
    if decode_name == "block" and "block" not in decode_paths:
        raise ValueError(
            f"{model.cfg.name} has no decode_step_fused; fused_decode "
            "needs a model with the single-launch Pallas block kernel")
    if decode_name == "model" and "model" not in decode_paths:
        raise ValueError(
            f"{model.cfg.name} has no decode_step_fused_model; "
            "fused_decode='model' needs a model with the whole-model "
            "Pallas megakernel")
    prefill_name = "chunked" if fused_prefill else "per_op"
    if prefill_name == "chunked" and "chunked" not in prefill_paths:
        raise ValueError(
            f"{model.cfg.name} has no prefill_chunk; fused_prefill "
            "needs a model with the fused chunked-prefill entry "
            "(kernels/fused_prefill.py)")
    decode_desc = decode_paths[decode_name]
    prefill_desc = prefill_paths[prefill_name]

    # -- speculative path selection ----------------------------------------
    spec_path = None
    if speculative is not None:
        k = int(speculative)
        if k < 1:
            raise ValueError(
                f"speculative={k}: the verify window needs at least the "
                "lane's pending token (K >= 1)")
        drafts = model.draft_paths()
        if "truncated" not in drafts:
            raise ValueError(
                f"{model.cfg.name} has no truncated-stack drafter; "
                "speculative decode needs a position-free decode_step, "
                "stacked `blocks` params and a named `layers` state axis")
        desc = drafts["truncated"]
        depth = draft_depth if draft_depth is not None else (
            desc.depth if desc.depth is not None
            else max(1, model.cfg.n_layers // 2))
        model.truncated(int(depth))     # validates 1 <= depth <= n_layers
        spec_path = SpeculativePath(k=k, draft_depth=int(depth), desc=desc)
    elif draft_depth is not None:
        raise ValueError("draft_depth without speculative=K does nothing")

    # -- param preparation: ONE pass over one weight set -------------------
    from_seed = params is None
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))
    if plane_policy is not None and not quantized:
        raise ValueError("plane_policy selects quantized weight planes; "
                         "it does nothing without quantized=True")
    if quantized:
        from repro.core.quant.serving import pack_params
        params = pack_params(params, plane_policy)
    prepared = PreparedParams(
        raw=params,
        decode=model.prepare_path_params(decode_desc, params,
                                         **(decode_prepare_kw or {})),
        prefill=model.prepare_path_params(prefill_desc, params),
        quantized=quantized,
        decode_path=decode_name, prefill_path=prefill_name,
        # the drafter consumes the raw (possibly packed) tree: its per-op
        # step unpacks in-trace exactly like the per-op decode path
        draft=None if spec_path is None or spec_path.k == 1 else
        model.truncate_params(params, spec_path.draft_depth))
    plan = ExecutionPlan(model, prepared, decode_desc, prefill_desc,
                         prefill_chunk=prefill_chunk, max_len=max_len,
                         state_dtype=state_dtype, mesh=mesh,
                         speculative=spec_path)
    # record the build inputs so a serving snapshot can reconstruct this
    # exact plan from config alone (repro.serving.snapshot).  `from_seed`
    # says whether `seed` alone reproduces the weights; restore verifies
    # param checksums either way, so externally-supplied weights still
    # restore — the caller just has to pass them back in.
    name = model.cfg.name
    smoke_flag = name.endswith("-smoke")
    plan.build_config = {
        "arch": _registry_arch_id(name, smoke_flag),
        "smoke": smoke_flag,
        "quantized": bool(quantized),
        "plane_policy": None if plane_policy is None
        else plane_policy.to_config(),
        "fused_decode": decode_name,
        "fused_prefill": prefill_name == "chunked",
        "prefill_chunk": int(prefill_chunk),
        "max_len": int(max_len),
        "state_dtype": jnp.dtype(state_dtype).name,
        "seed": int(seed),
        "from_seed": from_seed,
        "speculative": None if spec_path is None else spec_path.k,
        "draft_depth": None if spec_path is None else spec_path.draft_depth,
        "mesh_devices": None if mesh is None else int(mesh.devices.size),
    }
    return plan
