"""Slotted recurrent-state pool — the engine's on-device residency story.

The HFRWKV accelerator keeps the whole RWKV state on-chip so serving never
pays state movement (PAPER.md §1).  The JAX translation: ONE preallocated
device buffer per state leaf holding `max_slots` independent sequences'
O(1) states, where the model's batch axis is reinterpreted as the *slot*
axis.  Requests come and go; the buffers never reallocate, so the fused
decode step keeps a single compiled shape for the life of the engine.

Slot addressing is generic over state layout: the per-leaf position of the
slot axis is derived from the model's `decode_state_axes()` naming (see
`Model.decode_state_batch_axes`), so wkv4 `(L,B,D)` leaves, wkv6
`(L,B,H,N,N)` leaves, and ssd/hybrid `(G,g,B,...)` leaves all work without
per-model code.

Host-side bookkeeping is a plain LIFO free list: `acquire` pops the
lowest-numbered free slot, `release` returns it.  The pool also exposes a
generic per-lane device API (three jitted helpers, traced once each):

  read_slot(i)         -> batch-1 state tree (a lane copy)
  write_slot(i, lane)  -> install a batch-1 state tree into lane i
  reset_slot(i)        -> write the fresh-state template

The scheduler's per-token hot path does NOT use these: lane resets
happen inside the fused prefill call via its fresh-slot mask, so a
released slot keeps its stale state until the next admission overwrites
it (no cross-request leakage — nothing ever reads a lane before that
reset).  The helpers serve the per-REQUEST paths instead: the prefix
cache (repro.serving.prefix_cache) restores a cached prefix state into
a slot with `write_slot` at admission and captures chunk-boundary
states with `read_slot` during prefill — plus tests, debugging, and
state migration/snapshot of individual requests.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SlotStatePool:
    """Preallocated `max_slots`-wide decode state + free-list admission.

    `shardings` (optional) is a NamedSharding tree matching the state —
    built by `ExecutionPlan.state_shardings` from the mesh's DP axes —
    applied once here so the pool buffers are BORN data-parallel: each
    device holds its `max_slots / dp` slots for the life of the engine,
    and the fused step's donated output keeps the placement.  Host-side
    slot bookkeeping (the free list) is sharding-oblivious: a slot index
    means the same lane wherever that lane's shard lives."""

    def __init__(self, model, max_slots: int, *, max_len: int = 0,
                 dtype=jnp.bfloat16, shardings=None):
        self.model = model
        self.max_slots = int(max_slots)
        self.state = model.init_slot_state(self.max_slots, max_len, dtype)
        if shardings is not None:
            self.state = jax.device_put(self.state, shardings)
        self._axes = model.decode_state_batch_axes()
        self._tdef = jax.tree_util.tree_structure(self.state)
        # fresh batch-1 template used by reset_slot
        self._fresh = model.init_slot_state(1, max_len, dtype)
        self._free = list(range(self.max_slots - 1, -1, -1))  # pop -> slot 0
        self._read, self._write, self._finite = self._build_ops()

    # -- device ops (jitted once; slot index is a traced scalar) -----------

    def _build_ops(self):
        axes, tdef = self._axes, self._tdef

        def read(state, slot):
            leaves = jax.tree_util.tree_leaves(state)
            out = [jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)
                   for leaf, ax in zip(leaves, axes)]
            return jax.tree_util.tree_unflatten(tdef, out)

        def write(state, lane, slot):
            leaves = jax.tree_util.tree_leaves(state)
            lanes = jax.tree_util.tree_leaves(lane)
            out = []
            for leaf, ln, ax in zip(leaves, lanes, axes):
                start = [jnp.int32(0)] * leaf.ndim
                start[ax] = slot
                out.append(jax.lax.dynamic_update_slice(
                    leaf, ln.astype(leaf.dtype), start))
            return jax.tree_util.tree_unflatten(tdef, out)

        def finite(state):
            # one (max_slots,) bool: lane i is True iff EVERY floating
            # element of every leaf's lane-i slice is finite.  Non-float
            # leaves can't go NaN and are skipped.  Each leaf reduces over
            # all axes except its slot axis, then the leaves AND together.
            ok = None
            for leaf, ax in zip(jax.tree_util.tree_leaves(state), axes):
                if not jnp.issubdtype(leaf.dtype, jnp.floating):
                    continue
                red = tuple(i for i in range(leaf.ndim) if i != ax)
                lane_ok = jnp.all(jnp.isfinite(
                    leaf.astype(jnp.float32)), axis=red)
                ok = lane_ok if ok is None else ok & lane_ok
            return ok

        return (jax.jit(read), jax.jit(write, donate_argnums=(0,)),
                jax.jit(finite))

    # -- slot lifecycle ----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.max_slots - len(self._free)

    def acquire(self) -> Optional[int]:
        """Claim a free slot (lowest-numbered first), or None if full."""
        return self._free.pop() if self._free else None

    def release(self, slot: int):
        if not 0 <= slot < self.max_slots:
            raise ValueError(f"slot {slot} out of range")
        if slot in self._free:
            raise ValueError(f"slot {slot} already free")
        self._free.append(slot)
        self._free.sort(reverse=True)  # keep pop() -> lowest slot

    def read_slot(self, slot: int) -> Any:
        """Copy slot `slot` out as a batch-1 state tree."""
        return self._read(self.state, jnp.int32(slot))

    def write_slot(self, slot: int, lane_state: Any):
        """Install a batch-1 state tree into slot `slot`."""
        self.state = self._write(self.state, lane_state, jnp.int32(slot))

    def reset_slot(self, slot: int):
        """Restore slot `slot` to the fresh (just-initialized) state."""
        self.write_slot(slot, self._fresh)

    def lane_finite(self):
        """Per-lane NaN/Inf sentinel: a (max_slots,) bool numpy array,
        True where every floating state element of that lane is finite.
        ONE jitted reduction over the whole pool (traced once), so a
        sentinel sweep costs a single device call regardless of slot
        count.  The scheduler's quarantine path consumes this
        (docs/operations.md §sentinels)."""
        return np.asarray(self._finite(self.state))

    def poison_slot(self, slot: int, value: float = float("nan")):
        """Overwrite every floating leaf of lane `slot` with `value` —
        the `corrupt_state_leaf` fault drill's hammer (and a debugging
        aid for the sentinel sweep).  Integer leaves are left alone."""
        lane = self.read_slot(slot)
        poisoned = jax.tree_util.tree_map(
            lambda x: jnp.full_like(x, value)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, lane)
        self.write_slot(slot, poisoned)

    def sync(self):
        """Block until every in-flight update to the pool buffers has
        landed.  The scheduler's prefix-cache path calls this after a
        hit-state `write_slot` so the state-copy wall time it reports is
        the real transfer, not just the async dispatch."""
        jax.block_until_ready(self.state)
