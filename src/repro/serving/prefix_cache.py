"""Recurrent-state prefix cache: near-zero TTFT for repeated prefixes.

RWKV collapses an arbitrarily long prompt prefix into ONE O(1) recurrent
state (the RWKV paper calls the final hidden state a "free sentence
embedding"), so a serving engine can replace most prefill work with a
state lookup — something paged-KV transformer engines need far more
machinery to approximate.  This module is that lookup:

  * CONTENT-HASH KEYING — prompts are hashed at PREFILL-CHUNK granularity
    with a rolling hash over token chunks (`digests`): the digest at
    boundary n covers tokens [0, n), and is derived from the digest at
    n - chunk, so any cached ancestor prefix of a new prompt hits without
    re-hashing shared tokens per candidate.  A digest is a lookup key,
    never a proof: every hit re-compares the actual prefix tokens, so a
    hash-equal-but-token-unequal chunk is rejected (and counted), not
    served.
  * VARIANT ISOLATION — entries are keyed by a `CacheVariant`
    (model arch, quant form, hw-numerics variant, prefill path, state
    dtype) alongside the chunk hash.  States from packed Δ-PoT and fp
    weights, rwkv4 and rwkv6, LUT and exact numerics, or per-op and
    chunked prefill are different bit patterns for the same tokens; the
    variant key makes aliasing between them structurally impossible
    (tests/test_prefix_cache.py sweeps the cross-products).  One cache
    instance may therefore be shared between engines, like a plan.
  * TWO TIERS — a device-side LRU (`device_slots` lane states, the
    arrays `SlotStatePool.read_slot` produced) over a host-memory spill
    tier (`host_slots`, numpy copies).  Device eviction spills to host;
    host eviction drops; a host hit is promoted back to device when room
    exists (bit-exact roundtrip — bf16 survives device_get/put).
  * WRITE-ONCE + REFCOUNTS — `insert` never overwrites (the first state
    computed for a key is the only one ever served), and `probe` returns
    a `StateLease` that pins its entry against eviction/spill until
    released, so an admitting request can never be handed a state that a
    concurrent insert's eviction sweep is tearing down.  `check_state`
    asserts the tier/refcount invariants; the churn tests call it every
    step, mirroring the state-pool fragmentation tests.

The scheduler wires this into admission (repro.serving.scheduler): probe
on admit, copy the longest-hit state into the request's slot via the
pool's existing per-lane write machinery, prefill only the uncached
suffix, and insert chunk-boundary states captured during prefill when
the request completes.  Cached-state resume is BIT-IDENTICAL to full
prefill — the cached state was committed by the same masked prefill
program at the same chunk boundary the scheduler would have stopped at
anyway (tests/test_prefix_cache.py pins the whole matrix).  Telemetry
(hits/misses/evictions/spills, cached-token accounting, probe/copy time)
flows through `runtime.monitor.ServingCounters`; docs/serving.md
§"Prefix cache" covers sizing and the CLI flags.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

DEVICE, HOST = "device", "host"


def default_chunk_hash(prev: bytes, tokens: tuple) -> bytes:
    """Rolling chunk hash: digest of (parent digest, this chunk's tokens).
    blake2b-128 over the int64 token bytes — stable across processes, so
    a persisted cache could be rehydrated.  Injectable (`hash_fn=`) so
    collision handling is testable."""
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.digest()


@dataclasses.dataclass(frozen=True)
class CacheVariant:
    """Everything that changes the BITS of a prefix state for the same
    tokens — the non-hash half of every cache key.  Two engines sharing a
    cache can only share entries when all five fields agree; `ExecutionPlan
    .cache_variant()` derives the engine's variant from the plan so the
    fields can never drift from what actually executes."""
    arch: str               # model config name ("rwkv4-169m-smoke", ...)
    quant: str              # "fp" | "dpot_w8"
    numerics: str           # "exact" | "hw_lut" (paper LUT/PWL units)
    prefill: str            # "per_op" | "chunked" (PathDescriptor name)
    state_dtype: str = "bfloat16"


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    """Sizing knobs (entries, not bytes — every entry is one fixed-size
    lane state).  `host_slots=0` disables the spill tier."""
    device_slots: int = 64
    host_slots: int = 256


@dataclasses.dataclass
class _Entry:
    key: tuple                      # (variant, n_tokens, digest)
    tokens: tuple                   # the full prefix — hash-collision guard
    n_tokens: int
    state: Any                      # device tree (DEVICE) / numpy (HOST)
    tier: str = DEVICE
    refcount: int = 0


class StateLease:
    """A refcount pin on one cache entry: between `probe` and `release`
    the entry cannot be evicted, spilled, or overwritten, so `state` is
    safe to copy into a pool slot no matter what insert/evict churn runs
    concurrently.  Release is idempotent."""

    def __init__(self, entry: _Entry):
        self._entry = entry
        entry.refcount += 1

    @property
    def n_tokens(self) -> int:
        return self._entry.n_tokens

    @property
    def tokens(self) -> tuple:
        return self._entry.tokens

    @property
    def state(self):
        """The cached lane state as a DEVICE tree (host-tier entries are
        materialized on the fly when promotion had no room)."""
        if self._entry.tier == HOST:
            return jax.tree_util.tree_map(jnp.asarray, self._entry.state)
        return self._entry.state

    def release(self):
        if self._entry is not None:
            self._entry.refcount -= 1
            self._entry = None


class PrefixCache:
    """Two-tier LRU of chunk-boundary lane states (see module docstring).

    chunk     — prefill-chunk granularity; boundaries are multiples of it
                and MUST equal the serving plan's `prefill_chunk` (the
                engine asserts this), or cached boundaries would not be
                tick boundaries and resume would lose bit parity
    config    — PrefixCacheConfig tier sizes
    counters  — optional runtime.monitor.ServingCounters receiving the
                eviction/spill/insert hooks (hits/misses are reported by
                the scheduler, which knows the request)
    hash_fn   — rolling chunk hash override (tests force collisions)
    """

    def __init__(self, chunk: int, *,
                 config: PrefixCacheConfig = PrefixCacheConfig(),
                 counters=None,
                 hash_fn: Callable[[bytes, tuple], bytes] =
                 default_chunk_hash):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.chunk = int(chunk)
        self.config = config
        self.counters = counters
        self._hash = hash_fn
        self._device: collections.OrderedDict = collections.OrderedDict()
        self._host: collections.OrderedDict = collections.OrderedDict()
        self.stats = collections.Counter(
            hits=0, host_hits=0, misses=0, inserts=0, rejects=0,
            collisions=0, evictions=0, spills=0, drops=0, insert_dropped=0)

    # -- keying ------------------------------------------------------------

    def digests(self, prompt) -> dict:
        """Rolling digests for every chunk boundary of `prompt`:
        {n: digest} for n = chunk, 2*chunk, ... <= len(prompt).  Computed
        once per request at admission and reused by probe/contains/insert
        so per-tick bookkeeping never re-hashes the prompt."""
        out, h = {}, b""
        for n in range(self.chunk, len(prompt) + 1, self.chunk):
            h = self._hash(h, tuple(prompt[n - self.chunk:n]))
            out[n] = h
        return out

    def _key(self, variant: CacheVariant, n: int, digest: bytes) -> tuple:
        return (variant, int(n), digest)

    def _tokens_match(self, entry: _Entry, prompt, n: int) -> bool:
        if entry.tokens == tuple(prompt[:n]):
            return True
        self.stats["collisions"] += 1       # full-key compare rejected it
        return False

    # -- probe -------------------------------------------------------------

    def probe(self, variant: CacheVariant, prompt,
              digests: Optional[dict] = None) -> Optional[StateLease]:
        """Longest cached ancestor prefix of `prompt` under `variant`, as
        a refcount lease — or None.  Only PROPER prefixes are served
        (n < len(prompt)): the scheduler always needs at least the last
        prompt token's logits to sample the first generated token, so a
        whole-prompt hit could not skip the final prefill call anyway."""
        if digests is None:
            digests = self.digests(prompt)
        for n in sorted(digests, reverse=True):
            if n >= len(prompt):
                continue
            key = self._key(variant, n, digests[n])
            entry = self._device.get(key)
            if entry is not None and self._tokens_match(entry, prompt, n):
                self._device.move_to_end(key)
                self.stats["hits"] += 1
                return StateLease(entry)
            entry = self._host.get(key)
            if entry is not None and self._tokens_match(entry, prompt, n):
                self.stats["hits"] += 1
                self.stats["host_hits"] += 1
                # pin BEFORE promoting: the promotion's own room-making
                # sweep only evicts refcount-0 entries, so the lease keeps
                # the hit itself from being the host-tier victim
                lease = StateLease(entry)
                self._promote(key, entry)
                return lease
        self.stats["misses"] += 1
        return None

    def hit_length(self, variant: CacheVariant, prompt,
                   digests: Optional[dict] = None) -> int:
        """Longest cached proper-ancestor boundary of `prompt` (token
        count; 0 = no hit) WITHOUT taking a lease, bumping LRU order, or
        touching hit/miss stats — the scheduler's admission-preference
        peek (`AdmissionPolicy.prefer_cache_hits`).  Side-effect-free so
        peeking at every queued request each tick cannot distort cache
        telemetry or eviction order; like `probe`, a digest match only
        counts after the full token compare (collision-proof)."""
        if digests is None:
            digests = self.digests(prompt)
        for n in sorted(digests, reverse=True):
            if n >= len(prompt):
                continue
            key = self._key(variant, n, digests[n])
            entry = self._device.get(key) or self._host.get(key)
            if entry is not None and entry.tokens == tuple(prompt[:n]):
                return int(n)
        return 0

    def contains(self, variant: CacheVariant, prompt, n: int,
                 digests: Optional[dict] = None) -> bool:
        """True when boundary `n` of `prompt` is already cached under
        `variant` (either tier) — the scheduler's capture-skip check."""
        if n % self.chunk or not 0 < n <= len(prompt):
            return False
        digest = (digests if digests is not None
                  else self.digests(prompt)).get(n)
        if digest is None:
            return False
        key = self._key(variant, n, digest)
        entry = self._device.get(key) or self._host.get(key)
        return entry is not None and entry.tokens == tuple(prompt[:n])

    # -- insert / evict ----------------------------------------------------

    def insert(self, variant: CacheVariant, prompt, n: int, state,
               digests: Optional[dict] = None) -> bool:
        """Insert the lane state holding exactly tokens [0, n) of `prompt`
        into the device tier.  WRITE-ONCE: a key already present in either
        tier is never overwritten (the first computed state wins — any
        later computation of the same key is bit-identical by the resume
        oracle, so there is nothing to update).  Returns False when
        rejected (present, misaligned, or no evictable room)."""
        if n % self.chunk or not 0 < n <= len(prompt):
            return False
        digest = (digests if digests is not None
                  else self.digests(prompt)).get(n)
        if digest is None:
            return False
        key = self._key(variant, n, digest)
        if key in self._device or key in self._host:
            self.stats["rejects"] += 1
            return False
        if not self._make_device_room():
            self.stats["insert_dropped"] += 1
            return False
        self._device[key] = _Entry(key=key, tokens=tuple(prompt[:n]),
                                   n_tokens=int(n), state=state)
        self.stats["inserts"] += 1
        if self.counters is not None:
            self.counters.on_cache_insert()
        return True

    def _make_device_room(self) -> bool:
        """Ensure one free device slot, spilling LRU unleased entries to
        host (or dropping them when the host tier is full of leased/none).
        False when every device entry is refcount-pinned."""
        while len(self._device) >= self.config.device_slots:
            victim_key = next((k for k, e in self._device.items()
                               if e.refcount == 0), None)
            if victim_key is None:
                return False
            entry = self._device.pop(victim_key)
            self.stats["evictions"] += 1
            if self.counters is not None:
                self.counters.on_cache_evict()
            if self._make_host_room():
                entry.state = jax.tree_util.tree_map(jax.device_get,
                                                     entry.state)
                entry.tier = HOST
                self._host[victim_key] = entry
                self.stats["spills"] += 1
                if self.counters is not None:
                    self.counters.on_cache_spill()
            else:
                self.stats["drops"] += 1
        return True

    def _make_host_room(self) -> bool:
        if self.config.host_slots < 1:
            return False
        while len(self._host) >= self.config.host_slots:
            victim_key = next((k for k, e in self._host.items()
                               if e.refcount == 0), None)
            if victim_key is None:
                return False
            del self._host[victim_key]
            self.stats["drops"] += 1
        return True

    def _promote(self, key: tuple, entry: _Entry):
        """Host hit -> device tier (MRU), when an unleased device slot can
        be made; otherwise the entry stays host-resident and the lease
        materializes a device copy per use."""
        if not self._make_device_room():
            return
        del self._host[key]
        entry.state = jax.tree_util.tree_map(jnp.asarray, entry.state)
        entry.tier = DEVICE
        self._device[key] = entry

    # -- snapshot/restore (repro.serving.snapshot) -------------------------

    def export_entries(self) -> list[tuple[dict, Any]]:
        """Every entry as (manifest record, state tree), oldest-first per
        tier (device tier first) — re-adopting the records in this order
        reproduces the LRU order exactly.  Leases are tick-scoped and the
        snapshot layer captures at a tick boundary, so refcounts are not
        exported (they are structurally zero there)."""
        out = []
        for store in (self._device, self._host):
            for e in store.values():
                out.append(({"tier": e.tier, "n_tokens": e.n_tokens,
                             "tokens": list(e.tokens),
                             "variant": dataclasses.asdict(e.key[0])},
                            e.state))
        return out

    def adopt_entries(self, entries):
        """Install exported entries into an EMPTY cache (the restore
        path), preserving tier placement and LRU order.  `entries` is a
        list of (record, state) pairs as `export_entries` produced —
        device-tier states as device trees, host-tier states as numpy.
        Keys are recomputed from the tokens, so a snapshot written by a
        different process (different hash seed would break this — the
        chunk hash is content-stable by construction) adopts cleanly."""
        if self._device or self._host:
            raise ValueError("adopt_entries needs an empty cache")
        for rec, state in entries:
            variant = CacheVariant(**rec["variant"])
            tokens = [int(t) for t in rec["tokens"]]
            n = int(rec["n_tokens"])
            digest = self.digests(tokens)[n]
            key = self._key(variant, n, digest)
            tier = rec["tier"]
            entry = _Entry(key=key, tokens=tuple(tokens), n_tokens=n,
                           state=state, tier=tier)
            if tier == DEVICE:
                entry.state = jax.tree_util.tree_map(jnp.asarray, state)
                self._device[key] = entry
            else:
                self._host[key] = entry
        self.check_state()

    # -- introspection -----------------------------------------------------

    @property
    def n_device(self) -> int:
        return len(self._device)

    @property
    def n_host(self) -> int:
        return len(self._host)

    def snapshot(self) -> dict:
        """Stats + occupancy as a plain dict (merged into the serve CLI's
        telemetry printout and the benchmark records)."""
        probes = self.stats["hits"] + self.stats["misses"]
        return {**self.stats,
                "device_entries": self.n_device,
                "host_entries": self.n_host,
                "hit_rate": self.stats["hits"] / probes if probes else 0.0}

    def check_state(self):
        """Assert the structural invariants the churn tests pin every
        step: tier capacities respected, no key in both tiers, refcounts
        non-negative, every entry's tier tag / tokens / boundary
        consistent with where it lives.  (A LEASED entry may sit in either
        tier — a host hit is pinned before promotion, and stays host-
        resident when every device slot is also leased — but room-making
        only ever victimizes refcount-0 entries, which eviction/spill
        churn under held leases exercises.)"""
        assert len(self._device) <= self.config.device_slots
        assert len(self._host) <= self.config.host_slots
        assert not set(self._device) & set(self._host), "key in both tiers"
        for store, tier in ((self._device, DEVICE), (self._host, HOST)):
            for key, e in store.items():
                assert e.key == key and e.tier == tier
                assert e.refcount >= 0, f"negative refcount on {key}"
                assert e.n_tokens == len(e.tokens) == key[1]
                assert e.n_tokens % self.chunk == 0 and e.n_tokens > 0
