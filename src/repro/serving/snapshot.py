"""Crash-safe serving: tick-boundary engine snapshots, bit-identical resume.

The HFRWKV serving translation keeps everything that matters on one
device buffer (the slot pool) plus cheap host bookkeeping — which makes
the whole engine SNAPSHOTTABLE at a scheduler tick boundary, where the
invariants are strongest:

  * no speculation is in flight (`Scheduler._spec_snapshot is None`,
    every `_Slot.drafted` is empty — cleared in a `finally` each tick),
  * no prefix-cache lease is held (probes release within `_cache_probe`),
  * every lane's state is committed (decode/prefill calls are complete).

So a snapshot is: the pool state tree, the prefix cache's entry states,
each slot's staged boundary states, and a JSON `meta` blob holding the
scheduler/engine host bookkeeping — per-slot request + RNG stream
(`numpy.random.Generator.bit_generator.state` is a JSON dict and restores
bit-exactly), queue order, SLO config, monotone counters (clock fields
rebased as seconds-before-capture), demoted paths, and the plan's
`build_config` so restore can rebuild the exact same compiled programs
from config alone.  Arrays ride the training checkpoint layer
(`repro.checkpoint.store`): atomic-by-rename commits, async writes so
decode never blocks on disk, exact-dtype roundtrips (bf16 pool leaves,
uint8 Δ-PoT planes), and torn-write refusal (`load_manifest` rejects
directories without their COMMIT marker).

Restore (`restore_engine` / `ServingEngine.restore`) rebuilds the plan
from `build_config` — `build_plan(params=None, seed=s)` re-derives
identical weights when the snapshot was seeded (`from_seed`), verified
either way by CRC32 checksums over every prepared-param plane
(`IntegrityError` on drift) — re-installs the pool, re-adopts the cache,
re-registers a `RequestHandle` per live request (pre-crash output in
`handle.resumed`), and continues every stream such that
`resumed + tokens` is BITWISE equal to a never-crashed run: greedy and
seeded-Gumbel sampling both replay deterministically from the restored
RNG states (tests/test_snapshot.py drives the oracle across arch ×
quant × path × speculation × prefix-cache).

See docs/operations.md for the runbook (supervisor loop, torn-write
behavior, sentinels, degraded mode) and docs/architecture.md for the
lifecycle edges.
"""
from __future__ import annotations

import dataclasses
import os
import time
import zlib
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.store import (AsyncCheckpointer, _flatten_with_keys,
                                    latest_step, load_manifest,
                                    restore_checkpoint)

SNAPSHOT_VERSION = 1


class IntegrityError(RuntimeError):
    """Checksum verification failed: a prepared-param plane (or the
    whole reference set) does not match what was recorded — bit rot,
    a wrong `params=` handed to restore, or in-memory corruption."""


# ---------------------------------------------------------------------------
# Integrity sentinels: CRC32 over every prepared-param plane
# ---------------------------------------------------------------------------


def tree_checksums(tree: Any) -> dict:
    """{leaf key: crc32} over a pytree — the integrity sentinel for
    prepared params.  Keys are the checkpoint store's path keys, so a
    mismatch names the exact plane.  Aliased leaves (the plan's placement
    cache shares buffers between prepared forms) hash once (id-dedup);
    python scalars hash their repr.  FusedLayerStack is a registered
    pytree node, so megakernel slabs are covered leaf-by-leaf."""
    flat, _ = _flatten_with_keys(tree)
    seen: dict = {}
    out = {}
    for key, leaf in flat:
        if isinstance(leaf, (bool, int, float)):
            out[key] = zlib.crc32(repr(leaf).encode())
            continue
        cid = id(leaf)
        if cid not in seen:
            arr = np.asarray(jax.device_get(leaf))
            seen[cid] = zlib.crc32(arr.tobytes())
        out[key] = seen[cid]
    return out


def param_checksums(prepared) -> dict:
    """Checksums over every form of a `PreparedParams` — raw, decode and
    prefill planes all verify, so a fused path's packed slabs are covered
    even when the raw tree is intact."""
    return tree_checksums({"raw": prepared.raw, "decode": prepared.decode,
                           "prefill": prepared.prefill})


def verify_param_checksums(prepared, reference: dict, *, counters=None,
                           where: str = "startup"):
    """Recompute and compare against `reference`; raises IntegrityError
    naming every mismatched plane (counted in
    `ServingCounters.checksum_failures`)."""
    current = param_checksums(prepared)
    bad = sorted(k for k in reference
                 if current.get(k) != reference[k])
    bad += sorted(k for k in current if k not in reference)
    if bad:
        if counters is not None:
            counters.on_checksum_failure(len(bad))
        raise IntegrityError(
            f"param checksum mismatch at {where}: "
            f"{len(bad)} plane(s) differ from the reference — "
            f"first offenders: {bad[:4]}")


# ---------------------------------------------------------------------------
# RNG stream serialization (bit-exact)
# ---------------------------------------------------------------------------


def rng_state(gen: Optional[np.random.Generator]):
    """A Generator's bit-generator state as a JSON-serializable dict
    (PCG64 state ints are python ints — arbitrary precision, exact)."""
    return None if gen is None else gen.bit_generator.state


def make_rng(state) -> Optional[np.random.Generator]:
    """Rebuild a Generator mid-stream: same bit generator class, same
    state — the next draw is the draw the saved stream would make."""
    if state is None:
        return None
    bg = getattr(np.random, state["bit_generator"])()
    bg.state = state
    return np.random.Generator(bg)


# ---------------------------------------------------------------------------
# Capture
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SnapshotConfig:
    """How the engine snapshots itself.

    directory     — snapshot root (checkpoint-store step layout)
    every         — snapshot every N scheduler ticks (0 disables the
                    automatic cadence; `SnapshotManager.save` still works)
    keep          — committed snapshots retained (older pruned post-commit)
    verify_params — re-checksum prepared params before saves, so a
                    snapshot of corrupted weights is refused rather than
                    written (IntegrityError)
    verify_interval_s — amortize that re-checksum: a save re-verifies only
                    if this many seconds passed since the last check
                    (0.0 = every save).  Full-plane crc32 per save would
                    dominate a fast tick; a time cadence bounds staleness
                    instead — startup and restore always verify."""
    directory: str
    every: int = 8
    keep: int = 3
    verify_params: bool = True
    verify_interval_s: float = 30.0


@dataclasses.dataclass
class EngineSnapshot:
    """One consistent engine image: `meta` (JSON host bookkeeping) +
    `arrays` (the device/host state trees, checkpoint-store keyed)."""
    meta: dict
    arrays: dict

    @classmethod
    def capture(cls, engine, tick: int, *, extra: Optional[dict] = None
                ) -> "EngineSnapshot":
        """Capture `engine` at the boundary of scheduler tick `tick`.
        Must be called between ticks (the `after_tick` hook): raises if
        speculation is in flight — a mid-tick image would need draft
        windows and rollback snapshots that the boundary invariants
        guarantee away."""
        sch, pool = engine.scheduler, engine.pool
        if sch._spec_snapshot is not None or sch._spec_inflight:
            raise RuntimeError(
                "EngineSnapshot.capture outside a tick boundary: "
                "speculation in flight")
        if engine.plan.build_config is None:
            raise RuntimeError(
                "plan has no build_config (hand-constructed ExecutionPlan) "
                "— snapshots need build_plan(...) provenance to restore")
        now = sch._now()
        arrays: dict = {"pool": pool.state, "cache": {}, "pending": {}}
        cache_meta = None
        if engine.prefix_cache is not None:
            ents = engine.prefix_cache.export_entries()
            cache_meta = {
                "config": dataclasses.asdict(engine.prefix_cache.config),
                "entries": [rec for rec, _ in ents]}
            arrays["cache"] = {f"e{i:04d}": st
                               for i, (_, st) in enumerate(ents)}
        slot_recs = []
        for slot, m in sorted(sch.slots.items()):
            if m.drafted:
                raise RuntimeError(
                    f"slot {slot} holds unverified drafts — not a tick "
                    "boundary")
            slot_recs.append({
                "slot": slot, "req": dataclasses.asdict(m.req),
                "phase": m.phase, "fresh": bool(m.fresh),
                "n_prefilled": int(m.n_prefilled),
                "next_token": int(m.next_token),
                "generated": [int(t) for t in m.generated],
                "rng_state": rng_state(m.rng),
                "cached_tokens": int(m.cached_tokens),
                "seq": int(m.seq),
                "deadline_remaining": (None if m.deadline_t is None
                                       else m.deadline_t - now),
                "pending": [int(n) for n, _ in m.pending_inserts]})
            for j, (_, st) in enumerate(m.pending_inserts):
                arrays["pending"][f"s{slot}_p{j}"] = st
        queue_recs = []
        for r in sch.queue:
            qm = sch._queued[r.rid]
            queue_recs.append({
                "req": dataclasses.asdict(r), "seq": int(qm.seq),
                "enqueue_tick": int(qm.enqueue_tick),
                "deadline_remaining": (None if qm.deadline_t is None
                                       else qm.deadline_t - now)})
        meta = {
            "version": SNAPSHOT_VERSION,
            "tick": int(tick),
            "next_rid": int(engine._next_rid),
            "next_seq": int(sch._seq),
            "progress": int(sch._progress),
            "plan": engine.plan.build_config,
            "max_batch": int(pool.max_slots),
            "slo": dataclasses.asdict(engine.slo),
            "sentinel_every": int(getattr(sch, "sentinel_every", 0)),
            "path_fault_limit": int(getattr(sch, "path_fault_limit", 2)),
            "demoted": sorted(getattr(sch, "_demoted", ())),
            "param_checksums": None,        # SnapshotManager fills this
            "snapshot": None,               # ... and this
            "slots": slot_recs,
            "queue": queue_recs,
            "cache": cache_meta,
            "counters": engine.counters.state_dict(),
        }
        if extra:
            meta.update(extra)
        return cls(meta=meta, arrays=arrays)


class SnapshotManager:
    """Owns the engine's snapshot cadence and integrity reference.

    Construction checksums every prepared-param plane ONCE (the startup
    reference).  `maybe_save(tick)` — wired as the scheduler's
    `after_tick` hook — captures and writes every `config.every` ticks:
    the capture plus the device→host copy are synchronous (that wall time
    is `ServingCounters.snapshot_wall_s`); the file I/O runs on the
    `AsyncCheckpointer`'s background thread, so decode never blocks on
    disk (at worst a save joins the PREVIOUS write first)."""

    def __init__(self, engine, config: SnapshotConfig):
        self.engine = engine
        self.config = config
        self.writer = AsyncCheckpointer(config.directory, keep=config.keep)
        self.reference_checksums = param_checksums(engine.plan.prepared)
        self._last_verify = time.monotonic()

    def verify(self, *, where: str = "snapshot"):
        """Re-checksum prepared params against the startup reference."""
        verify_param_checksums(self.engine.plan.prepared,
                               self.reference_checksums,
                               counters=self.engine.counters, where=where)
        self._last_verify = time.monotonic()

    def maybe_save(self, tick: int):
        if self.config.every and tick % self.config.every == 0:
            self.save(tick)

    def save(self, tick: int):
        t0 = time.perf_counter()
        if self.config.verify_params and (
                self.config.verify_interval_s == 0.0
                or time.monotonic() - self._last_verify
                >= self.config.verify_interval_s):
            self.verify()
        snap = EngineSnapshot.capture(self.engine, tick, extra={
            "param_checksums": self.reference_checksums,
            "snapshot": dataclasses.asdict(self.config)})
        self.writer.save(tick, snap.arrays, meta=snap.meta)
        self.engine.counters.on_snapshot(time.perf_counter() - t0)

    def write_torn(self, tick: int):
        """The `torn_snapshot_write` fault drill: leave exactly what a
        host crash mid-save leaves — a partial `.tmp-step_X` staging dir
        with some leaves and NO COMMIT marker.  `latest_step` skips it
        and restore falls back to the newest committed snapshot."""
        tmp = os.path.join(self.config.directory, f".tmp-step_{tick:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.save(os.path.join(tmp, "['pool']_partial.npy"), np.zeros(3))

    def wait(self):
        """Join the in-flight background write (surfaces its errors)."""
        self.writer.wait()


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------


def load_snapshot(directory: str, step: Optional[int] = None
                  ) -> tuple[int, dict]:
    """(step, meta) of the newest committed snapshot (or exactly `step`).
    Torn/uncommitted dirs are never candidates; an empty directory
    raises FileNotFoundError."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no committed snapshot under {directory!r}")
    manifest = load_manifest(directory, step)
    meta = manifest["meta"]
    if meta is None or meta.get("version") != SNAPSHOT_VERSION:
        raise ValueError(
            f"step {step} at {directory!r} is not a serving snapshot "
            f"(version {None if meta is None else meta.get('version')!r}; "
            f"expected {SNAPSHOT_VERSION})")
    return step, meta


def _slo_from_dict(d: dict):
    from repro.serving.slo import AdmissionPolicy, ServingSLO
    return ServingSLO(prefill_budget=d["prefill_budget"],
                      default_deadline_s=d["default_deadline_s"],
                      admission=AdmissionPolicy(**d["admission"]),
                      max_idle_ticks=d["max_idle_ticks"])


def _resolve_mesh(mesh, plan_meta: dict):
    """`mesh="auto"`: rebuild the recorded serving mesh when enough
    devices are visible, else run unsharded — the sharded and unsharded
    engines are bit-identical (tests/test_plan.py), so a restore onto a
    smaller host changes placement, never tokens."""
    if mesh != "auto":
        return mesh
    n = plan_meta.get("mesh_devices")
    if not n or len(jax.devices()) < n:
        return None
    from repro.launch.mesh import make_serving_mesh
    return make_serving_mesh(n)


def restore_engine(directory: str, *, params: Any = None,
                   step: Optional[int] = None, mesh="auto",
                   snapshot="same", fault_injector=None,
                   verify_params: bool = True):
    """Rebuild a ServingEngine from its newest committed snapshot such
    that every restored stream continues bit-identically (see module
    docstring; `ServingEngine.restore` is the public alias).

    params        — required iff the snapshot was built from
                    externally-supplied weights (`from_seed` False);
                    checksum-verified either way
    mesh          — "auto" (recorded topology when devices suffice, else
                    unsharded), an explicit Mesh, or None
    snapshot      — "same": keep snapshotting into `directory` with the
                    recorded cadence; None disables; or a SnapshotConfig
    """
    from repro.serving.engine import RequestHandle, ServingEngine
    from repro.serving.scheduler import Request, _Queued, _Slot

    step, meta = load_snapshot(directory, step)
    pc = meta["plan"]
    if params is None and not pc["from_seed"]:
        raise ValueError(
            "snapshot was built from externally-supplied weights "
            "(build_config.from_seed=False) — pass the same params= tree "
            "to restore; checksums will verify it")
    from repro.core.quant.policy import PlanePolicy
    from repro.serving.plan import build_plan
    plan = build_plan(pc["arch"], params, smoke=pc["smoke"],
                      mesh=_resolve_mesh(mesh, pc),
                      quantized=pc["quantized"],
                      # pre-plane snapshots have no key -> None -> all-W8,
                      # exactly what they were built with
                      plane_policy=PlanePolicy.from_config(
                          pc.get("plane_policy")),
                      # build_config records the normalized path name;
                      # build_plan spells the unfused path False
                      fused_decode=(False if pc["fused_decode"] == "per_op"
                                    else pc["fused_decode"]),
                      fused_prefill=pc["fused_prefill"],
                      prefill_chunk=pc["prefill_chunk"],
                      max_len=pc["max_len"],
                      state_dtype=pc["state_dtype"], seed=pc["seed"],
                      speculative=pc["speculative"],
                      draft_depth=pc["draft_depth"])

    counters_state = meta["counters"]
    from repro.runtime.monitor import ServingCounters
    counters = ServingCounters()
    counters.load_state(counters_state)

    if verify_params and meta.get("param_checksums"):
        verify_param_checksums(plan.prepared, meta["param_checksums"],
                               counters=counters, where="restore")

    # -- array restore (exact dtypes; host numpy until installed) ----------
    model, max_batch = plan.model, meta["max_batch"]
    pool_like = jax.eval_shape(lambda: model.init_slot_state(
        max_batch, plan.max_len, plan.state_dtype))
    lane_like = jax.eval_shape(lambda: model.init_slot_state(
        1, plan.max_len, plan.state_dtype))
    n_entries = 0 if meta["cache"] is None else len(
        meta["cache"]["entries"])
    like = {"pool": pool_like,
            "cache": {f"e{i:04d}": lane_like for i in range(n_entries)},
            "pending": {f"s{rec['slot']}_p{j}": lane_like
                        for rec in meta["slots"]
                        for j in range(len(rec["pending"]))}}
    restored = restore_checkpoint(directory, step, like)

    # -- prefix cache ------------------------------------------------------
    cache = None
    if meta["cache"] is not None:
        from repro.serving.prefix_cache import (PrefixCache,
                                                PrefixCacheConfig)
        cache = PrefixCache(plan.prefill_chunk, config=PrefixCacheConfig(
            **meta["cache"]["config"]))
        cache.adopt_entries(list(zip(
            meta["cache"]["entries"],
            (restored["cache"][f"e{i:04d}"] for i in range(n_entries)))))

    # -- engine shell (fresh pool, compiled programs, manager) -------------
    if snapshot == "same":
        snap_cfg = (None if meta["snapshot"] is None
                    else SnapshotConfig(**dict(meta["snapshot"],
                                               directory=directory)))
    else:
        snap_cfg = snapshot
    engine = ServingEngine(
        model, plan=plan, max_batch=max_batch, counters=counters,
        prefix_cache=cache, slo=_slo_from_dict(meta["slo"]),
        fault_injector=fault_injector, snapshot=snap_cfg,
        sentinel_every=meta["sentinel_every"],
        path_fault_limit=meta["path_fault_limit"])

    # -- pool state + free list --------------------------------------------
    state = jax.tree_util.tree_map(jnp.asarray, restored["pool"])
    shardings = plan.state_shardings(max_batch)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    engine.pool.state = state
    occupied = {rec["slot"] for rec in meta["slots"]}
    engine.pool._free = sorted(set(range(max_batch)) - occupied,
                               reverse=True)

    # -- scheduler bookkeeping ---------------------------------------------
    sch = engine.scheduler
    now = sch._now()
    sch._tick_no = meta["tick"]
    sch._seq = meta["next_seq"]
    sch._progress = meta["progress"]
    sch._demoted = set(meta["demoted"])
    for rec in meta["slots"]:
        req = Request(**rec["req"])
        m = _Slot(
            req=req, phase=rec["phase"], fresh=rec["fresh"],
            n_prefilled=rec["n_prefilled"], next_token=rec["next_token"],
            generated=list(rec["generated"]), rng=make_rng(rec["rng_state"]),
            cached_tokens=rec["cached_tokens"],
            digests=None if cache is None else cache.digests(req.prompt),
            seq=rec["seq"],
            deadline_t=(None if rec["deadline_remaining"] is None
                        else now + rec["deadline_remaining"]))
        m.pending_inserts = [
            (n, jax.tree_util.tree_map(
                jnp.asarray, restored["pending"][f"s{rec['slot']}_p{j}"]))
            for j, n in enumerate(rec["pending"])]
        if m.deadline_t is not None:
            sch._has_deadlines = True
        sch.slots[rec["slot"]] = m
    for rec in meta["queue"]:
        req = Request(**rec["req"])
        sch.queue.append(req)
        qm = _Queued(
            seq=rec["seq"], enqueue_tick=rec["enqueue_tick"],
            deadline_t=(None if rec["deadline_remaining"] is None
                        else now + rec["deadline_remaining"]),
            digests=None if cache is None else cache.digests(req.prompt))
        if qm.deadline_t is not None:
            sch._has_deadlines = True
        sch._queued[req.rid] = qm

    # -- engine bookkeeping: rid counter + handles with resumed output -----
    engine._next_rid = meta["next_rid"]
    for rec in meta["slots"]:
        h = RequestHandle(sch.slots[rec["slot"]].req)
        h.resumed = list(rec["generated"])
        engine._handles[h.rid] = h
    for req in sch.queue:
        engine._handles[req.rid] = RequestHandle(req)
    counters.on_restore(resumed_lanes=len(meta["slots"]))
    return engine
