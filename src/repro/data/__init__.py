"""Data pipeline: deterministic synthetic token streams, host-sharded."""
from repro.data.pipeline import (
    SyntheticLM, make_batch_iterator, batch_specs)

__all__ = ["SyntheticLM", "make_batch_iterator", "batch_specs"]
