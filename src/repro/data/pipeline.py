"""Deterministic synthetic LM data pipeline.

Design constraints of a real multi-host pipeline, kept here:
  * deterministic as a function of (seed, step, host) — restart-safe: after a
    checkpoint restore at step k every host regenerates exactly the batch it
    would have seen, no data-state checkpointing needed;
  * host-sharded — each host materializes only its slice of the global batch
    (`host_slice`), which is how a 512-chip pod feeds jax.make_array_from_
    process_local_data;
  * double-buffered — a background thread prefetches the next batch while the
    device computes (the host-side analogue of the paper's ping-pong buffers).

The token generator is a mixture of Zipf-distributed unigrams and a
repeated-motif process so the stream has learnable structure (a model that
memorizes motifs beats the unigram entropy — useful for example training
curves) while staying fully synthetic.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Deterministic synthetic token distribution."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64
    motif_prob: float = 0.5
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        if self.global_batch % self.n_hosts == 0:
            return self.global_batch // self.n_hosts
        # uneven host counts: first hosts take the remainder
        base, rem = divmod(self.global_batch, self.n_hosts)
        return base + (1 if self.host_id < rem else 0)

    def _motifs(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed ^ 0xA5A5)
        return rng.integers(0, self.vocab, (self.n_motifs, self.motif_len),
                            dtype=np.int32)

    def batch(self, step: int) -> dict:
        """The batch for `step`, this host's slice. {"tokens","labels","mask"}
        tokens/labels: (host_batch, seq_len) int32."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4_096 + self.host_id)
        B, S = self.host_batch, self.seq_len
        # Zipf-ish unigram floor (bounded to the vocab)
        ranks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        tokens = ((ranks - 1) % self.vocab).astype(np.int32)
        # overlay repeated motifs (skipped for sequences shorter than one)
        ml = self.motif_len
        if S + 1 > ml:
            motifs = self._motifs()
            n_spans = max(1, int((S + 1) * self.motif_prob) // ml)
            for b in range(B):
                starts = rng.integers(0, S + 1 - ml, size=n_spans)
                picks = rng.integers(0, self.n_motifs, size=n_spans)
                for s, p in zip(starts, picks):
                    tokens[b, s:s + ml] = motifs[p]
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:].astype(np.int32),
            "mask": np.ones((B, S), np.float32),
        }


def batch_specs(vocab: int, seq_len: int, global_batch: int,
                extra: Optional[dict] = None) -> dict:
    """ShapeDtypeStruct stand-ins for a training batch (dry-run input)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "mask": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.float32),
    }
    if extra:
        specs.update(extra)
    return specs


def make_batch_iterator(ds: SyntheticLM, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[dict]:
    """Background-thread prefetching iterator (host-side double buffering)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
