"""Logical-axis sharding: named-axis rules, mesh plumbing, and the
`constrain` helper models use to pin activation layouts (sharding.py)."""
from repro.parallel.sharding import (
    AXIS_RULES, spec_for_axes, sharding_for, tree_shardings,
    batch_spec, shard_divisible, with_sharding_constraint_tree,
    set_current_mesh, get_current_mesh, use_mesh, constrain,
)

__all__ = [
    "AXIS_RULES", "spec_for_axes", "sharding_for", "tree_shardings",
    "batch_spec", "shard_divisible", "with_sharding_constraint_tree",
    "set_current_mesh", "get_current_mesh", "use_mesh", "constrain",
]
