"""Logical-axis -> mesh-axis sharding rules.

Production mesh axes: ("pod", "data", "model") (multi-pod) or
("data", "model") (single pod).

Rules (see DESIGN.md §4):
  * batch dims of activations shard over ("pod", "data") jointly (pure DP
    across pods, DP within a pod).
  * "fsdp" param dims shard over "data" only — parameters are replicated
    across pods so cross-pod traffic is gradient all-reduce only, which is
    the right trade for the slow inter-pod links.
  * "tp" and "ep" shard over "model" (intra-pod high-bandwidth axis).
  * any dim whose size does not divide its mesh axis falls back to
    replication instead of erroring — this is how batch=1 long-context or
    kv_heads=8 < model=16 cases stay runnable.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AXIS_RULES: dict[str, str | tuple[str, ...]] = {
    "fsdp": "data",
    "tp": "model",
    "ep": "model",
    "batch": ("pod", "data"),
    # sequence axis of KV caches / long activations (SP): prefers "model"
    # (usually free during decode since kv_heads rarely divide it), falls
    # back per the divisibility rule
    "seq": ("model", "data"),
    "layers": None,
}


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([_mesh_axis_size(mesh, a) for a in axis]))
    return mesh.shape[axis] if axis in mesh.shape else 0


def _resolve(mesh: Mesh, logical: str | None):
    """logical axis -> mesh axis (or None), dropping axes absent from mesh."""
    if logical is None:
        return None
    rule = AXIS_RULES.get(logical, None)
    if rule is None:
        return None
    if isinstance(rule, tuple):
        present = tuple(a for a in rule if a in mesh.shape)
        if not present:
            return None
        return present if len(present) > 1 else present[0]
    return rule if rule in mesh.shape else None


def spec_for_axes(axes: Sequence[str | None], shape: Sequence[int],
                  mesh: Mesh) -> PartitionSpec:
    """Build a PartitionSpec, replicating any non-divisible dim and never
    reusing a mesh axis twice within one spec."""
    used: set[str] = set()
    out: list = [None] * len(tuple(axes))
    # two passes: "seq" (sequence parallelism) only claims mesh axes the
    # higher-priority logicals (tp/ep/batch/fsdp) left free — head-sharded
    # KV beats seq-sharded KV whenever kv_heads divide the model axis
    # (no per-step gather), so seq must not steal "model" from tp.
    order = sorted(range(len(out)),
                   key=lambda i: tuple(axes)[i] == "seq")
    for i in order:
        dim, logical = tuple(shape)[i], tuple(axes)[i]
        mesh_axis = _resolve(mesh, logical)
        if mesh_axis is None:
            continue
        flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        # drop axes already claimed by another dim of this tensor, then
        # take the longest available prefix that divides the dim
        avail = tuple(a for a in flat if a not in used)
        for k in range(len(avail), 0, -1):
            cand = avail[:k]
            size = _mesh_axis_size(mesh, cand)
            if size > 1 and dim % size == 0:
                used.update(cand)
                out[i] = cand if len(cand) > 1 else cand[0]
                break
    return PartitionSpec(*out)


def sharding_for(axes: Sequence[str | None], shape: Sequence[int],
                 mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for_axes(axes, shape, mesh))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh):
    """Map (axes tree, abstract-shape tree) -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda axes, sds: sharding_for(axes, sds.shape, mesh),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def batch_spec(shape: Sequence[int], mesh: Mesh,
               extra_axes: Sequence[str | None] | None = None
               ) -> PartitionSpec:
    """Shard dim 0 as the global batch; remaining dims per extra_axes."""
    axes = ["batch"] + list(extra_axes or [None] * (len(shape) - 1))
    return spec_for_axes(axes, shape, mesh)


def shard_divisible(dim: int, mesh: Mesh, axis: str) -> str | None:
    """The mesh axis if it divides dim, else None (replicate)."""
    if axis in mesh.shape and dim % mesh.shape[axis] == 0:
        return axis
    return None


# ---------------------------------------------------------------------------
# Serving-pool placement: data-parallel slot pools and per-tick batches
# ---------------------------------------------------------------------------
#
# The continuous-batching engine's state pool is a batch of INDEPENDENT
# sequences (one per slot), which makes data-parallel sharding free: the
# slot axis splits over the DP mesh axes, no step-time collectives appear
# (nothing contracts across slots), and every other axis replicates on a
# serving mesh (weights are replicated outright — `replicated_sharding` —
# so decode never pays a weight all-gather).  `repro.serving.plan` is the
# consumer: it places the pool and the per-tick token batch through these
# helpers once at startup.


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes a slot pool may shard over, in rule order."""
    rule = AXIS_RULES["batch"]
    return tuple(a for a in rule if a in mesh.shape)


def dp_size(mesh: Mesh) -> int:
    """Total data-parallel ways for slot sharding on this mesh."""
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)] or [1]))


def pool_shardings(axes_tree, state_tree, mesh: Mesh):
    """NamedSharding tree for a slot state pool: each leaf's slot
    ("batch") axis shards over the DP axes via the standard divisibility
    rules — a pool width that does not divide the mesh replicates instead
    of erroring, so any (max_slots, devices) combination stays runnable.
    `state_tree` may hold concrete arrays or ShapeDtypeStructs; the
    mapping itself is the generic `tree_shardings`."""
    return tree_shardings(axes_tree, state_tree, mesh)


def batch_sharding(shape: Sequence[int], mesh: Mesh) -> NamedSharding:
    """Per-tick batch placement (tokens (S, C), masks (S,)): dim 0 is the
    slot axis, sharded like the pool; trailing dims replicate."""
    return NamedSharding(mesh, batch_spec(shape, mesh))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement (serving weights: placed once, read
    locally by every DP shard — no per-step weight collectives)."""
    return NamedSharding(mesh, PartitionSpec())


# ---------------------------------------------------------------------------
# Current-mesh context: layer code calls `constrain(x, axes)` which becomes a
# no-op outside any mesh (CPU smoke tests) and a with_sharding_constraint
# under the production mesh (set by the launcher / dryrun).
# ---------------------------------------------------------------------------

_CURRENT_MESH: list[Mesh | None] = [None]


def set_current_mesh(mesh: Mesh | None):
    _CURRENT_MESH[0] = mesh


def get_current_mesh() -> Mesh | None:
    return _CURRENT_MESH[0]


class use_mesh:
    """Context manager: `with use_mesh(mesh): ...` activates both the JAX
    mesh context and the repro sharding-constraint context."""

    def __init__(self, mesh: Mesh | None):
        self.mesh = mesh

    def __enter__(self):
        self.prev = _CURRENT_MESH[0]
        _CURRENT_MESH[0] = self.mesh
        if self.mesh is not None:
            self._mesh_ctx = self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        _CURRENT_MESH[0] = self.prev
        if self.mesh is not None:
            self.mesh.__exit__(*exc)
        return False


def constrain(x, axes: Sequence[str | None]):
    """Sharding constraint by logical axes; no-op when no mesh is active."""
    mesh = _CURRENT_MESH[0]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for(axes, x.shape, mesh))


def with_sharding_constraint_tree(tree, axes_tree, mesh: Mesh):
    def cons(x, axes):
        return jax.lax.with_sharding_constraint(
            x, sharding_for(axes, x.shape, mesh))
    return jax.tree_util.tree_map(
        cons, tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
