"""Pallas TPU kernel: fused RWKV-4 WKV scan (paper C4 -> TPU).

The paper's headline systems idea: keep the recurrent state fully on-chip
and stream the sequence through.  TPU mapping: grid (B, C/bc); each cell
owns a bc-wide channel slice whose (a, b, o) state lives in VREGs/VMEM for
the WHOLE sequence — zero HBM state round-trips between timesteps (on GPU
each step is a kernel launch reading state from HBM; that gap is the
paper's motivation §1-(1)).  k/v stream in as one VMEM-resident block.

Numerics: the official stable running-max recurrence (never overflows),
identical to repro.core.wkv.wkv4 — which is this kernel's oracle.

Serving extensions (all optional, default off — the bare call keeps the
original pure-f32 unmasked semantics):

  valid        — (B, T) per-timestep commit mask: a masked-out step still
                 computes (fixed shapes) but its state update is discarded,
                 exactly the scheduler's `where(ok, stepped, old)`.  This is
                 what lets the fused chunked-prefill path run partial prompt
                 chunks bit-identically to the per-op scan.
  carry_dtype  — round-trip the carried state through this dtype every step
                 (e.g. "bfloat16").  The per-op decode oracle stores its
                 state in the pool dtype between steps, so bit-parity with
                 it requires the on-chip carry to snap to the same grid.
  exp_table /  — the paper's EXP / DIV LUT fraction tables as explicit
  div_table      (256,) operands, switching the recurrence to the hw
                 numerics (`core.approx.exp_lut` / `div_lut`).  Kernels
                 cannot capture array constants, so the tables travel as
                 VMEM-resident inputs — the paper's on-chip LUTs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.approx import div_lut, exp_lut
from repro.kernels.common import interpret_default


def _kernel(k_ref, v_ref, w_ref, u_ref, a0_ref, b0_ref, o0_ref, *refs,
            T: int, masked: bool, carry: str | None, luts: bool):
    refs = list(refs)
    valid_ref = refs.pop(0) if masked else None
    if luts:
        exp_t = refs.pop(0)[...].astype(jnp.float32)
        div_t = refs.pop(0)[...].astype(jnp.float32)
        exp_fn = lambda x: exp_lut(x, table=exp_t)
        div_fn = lambda x, y: div_lut(x, y, table=div_t)
    else:
        exp_fn = jnp.exp
        div_fn = lambda x, y: x / y
    y_ref, af_ref, bf_ref, of_ref = refs
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    snap = ((lambda x: x) if carry is None else
            (lambda x: x.astype(jnp.dtype(carry)).astype(jnp.float32)))

    def body(t, state):
        a, b, o = state
        tsl = (pl.dslice(0, 1), pl.dslice(t, 1), slice(None))
        kt = pl.load(k_ref, tsl)[0, 0]
        vt = pl.load(v_ref, tsl)[0, 0]
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        # output (includes the bonus u for the current token)
        no = jnp.maximum(o, u + kt)
        A = exp_fn(o - no)
        Bf = exp_fn(u + kt - no)
        y = div_fn(A * a + Bf * vt, A * b + Bf)
        pl.store(y_ref, tsl, y[None, None].astype(y_ref.dtype))
        # state update
        no2 = jnp.maximum(o - w, kt)
        A2 = exp_fn(o - w - no2)
        B2 = exp_fn(kt - no2)
        na, nb, no_ = A2 * a + B2 * vt, A2 * b + B2, no2
        if masked:
            ok = pl.load(valid_ref,
                         (pl.dslice(0, 1), pl.dslice(t, 1)))[0, 0] != 0
            na = jnp.where(ok, na, a)
            nb = jnp.where(ok, nb, b)
            no_ = jnp.where(ok, no_, o)
        return (snap(na), snap(nb), snap(no_))

    # int ref indices break jax 0.4.x interpret-mode discharge; use dslice
    ld = lambda ref: pl.load(
        ref, (pl.dslice(0, 1), slice(None)))[0].astype(jnp.float32)
    a, b, o = jax.lax.fori_loop(
        0, T, body, (ld(a0_ref), ld(b0_ref), ld(o0_ref)))
    st = lambda ref, x: pl.store(
        ref, (pl.dslice(0, 1), slice(None)), x[None])
    st(af_ref, a)
    st(bf_ref, b)
    st(of_ref, o)


@functools.partial(jax.jit,
                   static_argnames=("bc", "interpret", "carry_dtype"))
def wkv4_pallas(k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
                u: jnp.ndarray, a0=None, b0=None, o0=None, *,
                valid: jnp.ndarray | None = None,
                carry_dtype: str | None = None,
                exp_table: jnp.ndarray | None = None,
                div_table: jnp.ndarray | None = None,
                bc: int = 128, interpret: bool | None = None):
    """k, v: (B, T, C); w, u: (C,) -> (y (B,T,C) f32, (a,b,o) finals (B,C)).

    Optional serving operands (see module docstring): `valid` (B, T) commit
    mask, `carry_dtype` per-step state rounding, `exp_table`/`div_table`
    hw-numerics LUTs (supply both or neither)."""
    B, T, C = k.shape
    bc = min(bc, C)
    while C % bc != 0:
        bc //= 2
    if a0 is None:
        a0 = jnp.zeros((B, C), jnp.float32)
        b0 = jnp.zeros((B, C), jnp.float32)
        o0 = jnp.full((B, C), -1e38, jnp.float32)
    if (exp_table is None) != (div_table is None):
        raise ValueError("exp_table and div_table travel together")
    grid = (B, C // bc)
    seq_spec = pl.BlockSpec((1, T, bc), lambda b, c: (b, 0, c))
    vec_spec = pl.BlockSpec((bc,), lambda b, c: (c,))
    st_spec = pl.BlockSpec((1, bc), lambda b, c: (b, c))
    operands = [k, v, w, u, a0, b0, o0]
    in_specs = [seq_spec, seq_spec, vec_spec, vec_spec,
                st_spec, st_spec, st_spec]
    if valid is not None:
        operands.append(valid.astype(jnp.int32))
        in_specs.append(pl.BlockSpec((1, T), lambda b, c: (b, 0)))
    if exp_table is not None:
        tab_spec = pl.BlockSpec((256,), lambda b, c: (0,))
        operands += [exp_table, div_table]
        in_specs += [tab_spec, tab_spec]
    y, af, bf, of = pl.pallas_call(
        functools.partial(_kernel, T=T, masked=valid is not None,
                          carry=carry_dtype, luts=exp_table is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[seq_spec, st_spec, st_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
        ],
        interpret=interpret_default(interpret),
    )(*operands)
    return y, (af, bf, of)
