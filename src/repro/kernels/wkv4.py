"""Pallas TPU kernel: fused RWKV-4 WKV scan (paper C4 -> TPU).

The paper's headline systems idea: keep the recurrent state fully on-chip
and stream the sequence through.  TPU mapping: grid (B, C/bc); each cell
owns a bc-wide channel slice whose (a, b, o) state lives in VREGs/VMEM for
the WHOLE sequence — zero HBM state round-trips between timesteps (on GPU
each step is a kernel launch reading state from HBM; that gap is the
paper's motivation §1-(1)).  k/v stream in as one VMEM-resident block.

Numerics: the official stable running-max recurrence (never overflows),
identical to repro.core.wkv.wkv4 — which is this kernel's oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default


def _kernel(k_ref, v_ref, w_ref, u_ref, a0_ref, b0_ref, o0_ref,
            y_ref, af_ref, bf_ref, of_ref, *, T: int):
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)

    def body(t, carry):
        a, b, o = carry
        tsl = (pl.dslice(0, 1), pl.dslice(t, 1), slice(None))
        kt = pl.load(k_ref, tsl)[0, 0]
        vt = pl.load(v_ref, tsl)[0, 0]
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        # output (includes the bonus u for the current token)
        no = jnp.maximum(o, u + kt)
        A = jnp.exp(o - no)
        Bf = jnp.exp(u + kt - no)
        y = (A * a + Bf * vt) / (A * b + Bf)
        pl.store(y_ref, tsl, y[None, None].astype(y_ref.dtype))
        # state update
        no2 = jnp.maximum(o - w, kt)
        A2 = jnp.exp(o - w - no2)
        B2 = jnp.exp(kt - no2)
        return (A2 * a + B2 * vt, A2 * b + B2, no2)

    # int ref indices break jax 0.4.x interpret-mode discharge; use dslice
    ld = lambda ref: pl.load(
        ref, (pl.dslice(0, 1), slice(None)))[0].astype(jnp.float32)
    a, b, o = jax.lax.fori_loop(
        0, T, body, (ld(a0_ref), ld(b0_ref), ld(o0_ref)))
    st = lambda ref, x: pl.store(
        ref, (pl.dslice(0, 1), slice(None)), x[None])
    st(af_ref, a)
    st(bf_ref, b)
    st(of_ref, o)


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def wkv4_pallas(k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
                u: jnp.ndarray, a0=None, b0=None, o0=None, *,
                bc: int = 128, interpret: bool | None = None):
    """k, v: (B, T, C); w, u: (C,) -> (y (B,T,C) f32, (a,b,o) finals (B,C))."""
    B, T, C = k.shape
    bc = min(bc, C)
    while C % bc != 0:
        bc //= 2
    if a0 is None:
        a0 = jnp.zeros((B, C), jnp.float32)
        b0 = jnp.zeros((B, C), jnp.float32)
        o0 = jnp.full((B, C), -1e38, jnp.float32)
    grid = (B, C // bc)
    seq_spec = pl.BlockSpec((1, T, bc), lambda b, c: (b, 0, c))
    vec_spec = pl.BlockSpec((bc,), lambda b, c: (c,))
    st_spec = pl.BlockSpec((1, bc), lambda b, c: (b, c))
    y, af, bf, of = pl.pallas_call(
        functools.partial(_kernel, T=T),
        grid=grid,
        in_specs=[seq_spec, seq_spec, vec_spec, vec_spec,
                  st_spec, st_spec, st_spec],
        out_specs=[seq_spec, st_spec, st_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
        ],
        interpret=interpret_default(interpret),
    )(k, v, w, u, a0, b0, o0)
    return y, (af, bf, of)
