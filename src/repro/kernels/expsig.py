"""Pallas TPU kernel: the reusable EXP-σ unit (paper §4.4 -> TPU).

One kernel, two modes (the paper's shared datapath):
  mode=0  e^x   via  2^(x·log2e_hw) with the hardware constant
          log2e ≈ 1.0111₂ = 1.4375 (1 add + 1 sub + 2 shifts in the paper;
          a fused multiply here), integer part by exp2, fraction part from
          the 256-entry EXP-LUT resident in VMEM (1 KiB).
  mode=1  sigmoid via the 4-segment piecewise-linear approximation (Eq. 9)
          with dyadic slopes — pure VPU select/multiply-add, no table.

On TPU this unit is about *numerics fidelity* (the quantized model must see
the accelerator's approximation error), not speed — DESIGN.md §2-C3.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.approx.units import EXP_LUT_TABLE, _LOG2E_HW
from repro.kernels.common import interpret_default

_LUT = jnp.asarray(EXP_LUT_TABLE, jnp.float32)


def _kernel(x_ref, lut_ref, o_ref, *, mode: int):
    x = x_ref[...].astype(jnp.float32)
    if mode == 0:
        y = jnp.clip(x * _LOG2E_HW, -24.0, 24.0)
        u = jnp.floor(y)
        v = y - u
        idx = jnp.clip((v * 256.0).astype(jnp.int32), 0, 255)
        frac = lut_ref[...][idx]          # VMEM-resident 256-entry LUT
        o_ref[...] = (jnp.exp2(u) * frac).astype(o_ref.dtype)
    else:
        ax = jnp.abs(x)
        f = jnp.where(
            ax >= 5.0, 1.0,
            jnp.where(ax >= 2.375, 0.03125 * ax + 0.84375,
                      jnp.where(ax >= 1.0, 0.125 * ax + 0.625,
                                0.25 * ax + 0.5)))
        o_ref[...] = jnp.where(x >= 0, f, 1.0 - f).astype(o_ref.dtype)


def _call(x: jnp.ndarray, mode: int, block: int, interpret) -> jnp.ndarray:
    shape = x.shape
    xf = x.reshape(-1)
    n = xf.shape[0]
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        xf = jnp.pad(xf, (0, pad))
    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid=(xf.shape[0] // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                  pl.BlockSpec((256,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xf.shape[0],), x.dtype),
        interpret=interpret_default(interpret),
    )(xf, _LUT)
    if pad:
        out = out[:n]
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def exp_kernel(x: jnp.ndarray, *, block: int = 4096,
               interpret: bool | None = None) -> jnp.ndarray:
    return _call(x, 0, block, interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sigmoid_kernel(x: jnp.ndarray, *, block: int = 4096,
                   interpret: bool | None = None) -> jnp.ndarray:
    return _call(x, 1, block, interpret)
