"""Pallas TPU kernel: matmul with Δ-PoT-packed weights (paper C1 -> TPU).

The paper replaces DSP multipliers with shift-add over Δ-PoT codes; the TPU
translation (DESIGN.md §2-C1) is: weights live in HBM as *packed int8 codes*
(sign bit + ks=(3,4) differential exponents = 8 bits/weight vs 16 for bf16),
are streamed HBM->VMEM tile-by-tile by the pallas grid pipeline (the paper's
ping-pong URAM double-buffering — same mechanism, same purpose), decoded to
f32 *inside VMEM* with VPU integer ops + exp2 (the barrel-shifter analogue),
and fed to the MXU as dense tiles.  HBM weight traffic halves; the matmul
itself stays systolic.

    out[M, N] = x[M, K] @ decode(wq[K, N]) * scale[N]

Block tiling: (bm x bk) @ (bk x bn) -> (bm x bn), grid (M/bm, N/bn, K/bk)
with the K axis innermost so the f32 accumulator tile stays resident in VMEM
across the K sweep (revisiting semantics), initialized at k==0.

The fused chunked-prefill path reuses this design with one deliberate
change: `kernels.fused_prefill.dpot_chunk_matmul` keeps the SAME
streaming-codes/decode-in-VMEM mechanism but never splits K and decodes
via `core.quant.serving.unpack_leaf` (f32 -> bf16 -> compute dtype), so
its output is BITWISE equal to the per-op serving oracle's
`x @ unpack_leaf(w)` — the f32-accumulator K-sweep here trades that
exactness for scale, which training-sized matmuls want and prefill
cannot accept.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default

K0_BITS, K1_BITS = 3, 4  # FORMAT_W8 = sign + ks=(3,4) packed into int8


def _decode_w8(codes_u8: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """uint8 packed Δ-PoT -> f32, fully vectorized (VPU-friendly).

    bit 7 = sign; bits 2:0 = Δq0; bits 6:3 = Δq1.  Δq_i = 0 kills term i and
    all later terms (paper Eq. 6)."""
    c = codes_u8.astype(jnp.int32)
    sign = jnp.where((c >> 7) & 1, -1.0, 1.0)
    dq0 = c & ((1 << K0_BITS) - 1)
    dq1 = (c >> K0_BITS) & ((1 << K1_BITS) - 1)
    alive0 = dq0 > 0
    q0 = dq0.astype(jnp.float32)
    t0 = jnp.where(alive0, jnp.exp2(-q0), 0.0)
    alive1 = alive0 & (dq1 > 0)
    t1 = jnp.where(alive1, jnp.exp2(-(q0 + dq1.astype(jnp.float32))), 0.0)
    return sign * (t0 + t1) * scale


def _kernel(x_ref, wq_ref, scale_ref, o_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _decode_w8(wq_ref[...], scale_ref[...][None, :])
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def dpot_matmul(x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray, *,
                bm: int = 128, bn: int = 128, bk: int = 512,
                interpret: bool | None = None) -> jnp.ndarray:
    """x: (M, K) f32/bf16; wq: (K, N) uint8 packed; scale: (N,) f32."""
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2 and scale.shape == (N,)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret_default(interpret),
    )(x, wq, scale)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# W4 nibble variant: two sign+3-bit codes per uint8, paired along K (the
# FORMAT_W4 packing of core.quant.delta_pot.dpot_pack_nibbles).  Same
# K-blocked f32-accumulator structure as `dpot_matmul`, but each streamed
# uint8 tile is (bk/2, bn) — HALF the code bytes per contraction block.
# Nibble layout: bit 3 = sign, bits 2:0 = Δq (single term, level 2^-Δq).
# ---------------------------------------------------------------------------


def _decode_w4(packed_u8: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """(bk/2, bn) uint8 nibble pairs -> (bk, bn) f32, VPU-only."""
    p = packed_u8.astype(jnp.int32)
    words = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-2)
    words = words.reshape(2 * packed_u8.shape[-2], packed_u8.shape[-1])
    sign = jnp.where((words >> 3) & 1, -1.0, 1.0)
    dq = words & 0x7
    lvl = jnp.where(dq > 0, jnp.exp2(-dq.astype(jnp.float32)), 0.0)
    return sign * lvl * scale


def _kernel_w4(x_ref, wq_ref, scale_ref, o_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _decode_w4(wq_ref[...], scale_ref[...][None, :])
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def dpot_matmul_w4(x: jnp.ndarray, wq4: jnp.ndarray, scale: jnp.ndarray, *,
                   bm: int = 128, bn: int = 128, bk: int = 512,
                   interpret: bool | None = None) -> jnp.ndarray:
    """x: (M, K) f32/bf16; wq4: (K/2, N) uint8 nibble pairs; scale: (N,)."""
    M, K = x.shape
    Kh, N = wq4.shape
    assert K == 2 * Kh and scale.shape == (N,)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert bk % 2 == 0, f"K block {bk} must cover whole nibble pairs"
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)
    out = pl.pallas_call(
        functools.partial(_kernel_w4, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret_default(interpret),
    )(x, wq4, scale)
    return out.astype(x.dtype)
