"""Fused chunked-prefill building blocks: chunk matmuls + masked WKV scan.

Decode (PRs 2-3) collapsed the per-token step into single Pallas launches;
prefill — which gates time-to-first-token — was still a `lax.scan` of the
per-op `decode_step`: one D-wide MATVEC per prompt token, and (when
quantized) the whole Δ-PoT tree unpacked in HBM for every chunk.  This
module supplies the pieces the models' `prefill_chunk` entry points stitch
together, per the paper's computation reordering (§4.2): process a whole
prompt chunk per device program, with

  * the position-parallel work (token-shift mixes, layernorms, the r/k/v/
    receptance projections, the FFN) reshaped into (S·C, D) MATMULS over
    the chunk — MXU food instead of C matvecs (`chunk_matmul` below; the
    same tiling idea as `kernels.dpot_matmul`, here with the decode kept
    bit-exact to `core.quant.serving.unpack_leaf`), and
  * the genuinely sequential WKV recurrence running through the Pallas
    sequence kernels (`kernels.wkv4.wkv4_pallas` / `kernels.wkv6.
    wkv6_seq_pallas`), seeded from the pool state and keeping the
    per-channel state in VMEM across the chunk's timesteps, with a `valid`
    commit mask so partial chunks match the per-op scan bit-for-bit.

Packed weights flow to prefill WITHOUT `unpack_params`: the uint8 code
planes — scalar Δ-PoT W8, nibble-packed W4 (two codes per byte, half the
stream), VQ codebook indices — stream HBM->VMEM tile-by-tile and decode
inside the matching matmul kernel (`_mm_kernel` / `_mm_kernel_w4` /
`_mm_kernel_vq`), so uint8 codes are all that crosses HBM during the
whole prompt phase — the paper's bandwidth win, extended from decode to
prefill.  Bit-parity contract: `chunk_matmul(x, leaf, dt)` on a packed
leaf equals `x @ unpack_leaf(leaf).astype(dt)` exactly, because the kernel
body calls the very same `unpack_leaf` (tests/test_prefill.py).

The masking semantics live one level up (models' `block_prefill`): the
`valid` mask must be a per-slot PREFIX of the chunk (the scheduler only
emits prefix masks — a prompt chunk occupies positions [0, n)), which is
what makes the shifted-sequence token mix equal to the oracle's frozen
state carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant.serving import is_packed_leaf, leaf_plane, unpack_leaf
from repro.kernels.common import interpret_default


def _mm_kernel(x_ref, wq_ref, scale_ref, o_ref, *, dt):
    # decode THE SAME WAY the per-op oracle does (unpack_leaf -> bf16 ->
    # compute dtype) so the fused prefill is bit-identical, not merely close
    w = unpack_leaf({"packed": wq_ref[...],
                     "scale": scale_ref[...]}).astype(dt)
    o_ref[...] = x_ref[...] @ w


def _mm_kernel_w4(x_ref, wq_ref, scale_ref, o_ref, *, dt):
    # W4 nibble plane: the (K/2, bn) uint8 tile re-interleaves to (K, bn)
    # inside VMEM via the SAME unpack_leaf as the per-op oracle — half the
    # HBM code bytes of the W8 kernel above, identical bits out
    w = unpack_leaf({"packed4": wq_ref[...],
                     "scale": scale_ref[...]}).astype(dt)
    o_ref[...] = x_ref[...] @ w


def _mm_kernel_vq(x_ref, idx_ref, cb_ref, o_ref, *, dt):
    # VQ plane: uint8 indices stream per tile; the whole (C,) codebook
    # rides a constant index map and stays VMEM-resident (like the shared
    # Δ-PoT scales) — the gather decode never touches HBM-decoded weights
    w = unpack_leaf({"vq_idx": idx_ref[...],
                     "codebook": cb_ref[...]}).astype(dt)
    o_ref[...] = x_ref[...] @ w


def _fit(block: int, dim: int) -> int:
    block = min(block, dim)
    while dim % block != 0:
        block //= 2
    return block


@functools.partial(jax.jit, static_argnames=("dt", "bm", "bn", "interpret"))
def dpot_chunk_matmul(x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray,
                      *, dt, bm: int = 256, bn: int = 512,
                      interpret: bool | None = None) -> jnp.ndarray:
    """x: (M, K) @ packed wq: (K, N) with per-channel scale (..., N).

    Grid (M/bm, N/bn) with the FULL K per cell: the contraction is never
    split, so each output element accumulates in exactly the order the
    unfused `x @ w` does — the bit-parity requirement (`dpot_matmul`'s
    K-blocked f32 accumulator trades that for scale; prefill cannot).
    uint8 code tiles stream HBM->VMEM via the grid pipeline and decode
    on the VPU in-kernel; `dt` is the compute dtype the decoded weights
    are cast to (the oracle's `cast_params`)."""
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    scale = scale.reshape(1, N)
    bm, bn = _fit(bm, M), _fit(bn, N)
    out_dt = jnp.result_type(x.dtype, jnp.dtype(dt))
    return pl.pallas_call(
        functools.partial(_mm_kernel, dt=jnp.dtype(dt)),
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dt),
        interpret=interpret_default(interpret),
    )(x, wq, scale)


@functools.partial(jax.jit, static_argnames=("dt", "bm", "bn", "interpret"))
def w4_chunk_matmul(x: jnp.ndarray, wq4: jnp.ndarray, scale: jnp.ndarray,
                    *, dt, bm: int = 256, bn: int = 512,
                    interpret: bool | None = None) -> jnp.ndarray:
    """x: (M, K) @ nibble-packed wq4: (K/2, N) with per-channel scale.

    Same grid/bit-parity contract as `dpot_chunk_matmul` — full K per
    cell, decode via `unpack_leaf` in-kernel — at HALF the streamed code
    bytes: each uint8 tile carries two contraction rows."""
    M, K = x.shape
    Kh, N = wq4.shape
    assert K == 2 * Kh, (x.shape, wq4.shape)
    scale = scale.reshape(1, N)
    bm, bn = _fit(bm, M), _fit(bn, N)
    out_dt = jnp.result_type(x.dtype, jnp.dtype(dt))
    return pl.pallas_call(
        functools.partial(_mm_kernel_w4, dt=jnp.dtype(dt)),
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((Kh, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dt),
        interpret=interpret_default(interpret),
    )(x, wq4, scale)


@functools.partial(jax.jit, static_argnames=("dt", "bm", "bn", "interpret"))
def vq_chunk_matmul(x: jnp.ndarray, idx: jnp.ndarray, codebook: jnp.ndarray,
                    *, dt, bm: int = 256, bn: int = 512,
                    interpret: bool | None = None) -> jnp.ndarray:
    """x: (M, K) @ codebook[idx: (K, N)] — VQ plane chunk matmul.

    uint8 indices stream tile-by-tile; the flat (C,) bf16 codebook rides
    a CONSTANT index map (resident across the grid, like the shared Δ-PoT
    scales).  Decode is the oracle's `unpack_leaf` gather, in-kernel."""
    M, K = x.shape
    K2, N = idx.shape
    assert K == K2, (x.shape, idx.shape)
    cb = codebook.reshape(-1)
    C = cb.shape[0]
    bm, bn = _fit(bm, M), _fit(bn, N)
    out_dt = jnp.result_type(x.dtype, jnp.dtype(dt))
    return pl.pallas_call(
        functools.partial(_mm_kernel_vq, dt=jnp.dtype(dt)),
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dt),
        interpret=interpret_default(interpret),
    )(x, idx, cb)


def chunk_matmul(x: jnp.ndarray, leaf, dt, *,
                 interpret: bool | None = None) -> jnp.ndarray:
    """`x @ leaf` over a (..., K) chunk tensor, packed-leaf aware.

    Plain leaves take the jnp matmul (already in compute dtype via
    `cast_compute` — identical to the oracle by construction).  Quantized
    plane leaves — W8 `{"packed", "scale"}`, W4 `{"packed4", "scale"}`,
    VQ `{"vq_idx", "codebook"}` — flatten the chunk to (S·C, K) and run
    the matching in-kernel-decode matmul above: bitwise
    `x @ unpack_leaf(leaf).astype(dt)` with the codes/indices, not the
    decoded bf16, crossing HBM."""
    plane = leaf_plane(leaf)
    if plane is None:
        return x @ leaf
    lead, K = x.shape[:-1], x.shape[-1]
    xf = x.reshape(-1, K)
    dt_name = jnp.dtype(dt).name
    if plane == "w4":
        out = w4_chunk_matmul(xf, leaf["packed4"], leaf["scale"],
                              dt=dt_name, interpret=interpret)
    elif plane == "vq":
        out = vq_chunk_matmul(xf, leaf["vq_idx"], leaf["codebook"],
                              dt=dt_name, interpret=interpret)
    else:
        out = dpot_chunk_matmul(xf, leaf["packed"], leaf["scale"],
                                dt=dt_name, interpret=interpret)
    return out.reshape(*lead, out.shape[-1])


def shifted_prev(seq: jnp.ndarray, first: jnp.ndarray,
                 valid: jnp.ndarray) -> jnp.ndarray:
    """Token-shift previous-value sequence under a per-slot PREFIX mask.

    seq (B, C, D) are the per-position carry candidates (h, already rounded
    to the state dtype); first (B, D) is the incoming pool carry.  Position
    t sees seq_{t-1} while t-1 is inside the valid prefix, the LAST valid
    entry once the prefix ends (the oracle's per-step masking FREEZES the
    carry there — masked-out steps still compute, from the frozen value),
    and `first` at t=0 or on lanes with no valid tokens at all.  The frozen
    tail is what keeps even the DISCARDED positions' compute bitwise equal
    to the oracle's — which matters when numerics couple lanes (rwkv4's hw
    A9 activation fake-quant takes a per-(batch, features) max: a garbage
    lane with the wrong garbage would perturb every other lane's scale)."""
    B, C = valid.shape
    nv = jnp.sum(valid.astype(jnp.int32), axis=1)
    j = jnp.minimum(jnp.arange(C)[None, :], nv[:, None]) - 1     # (B, C)
    got = jnp.take_along_axis(seq, jnp.maximum(j, 0)[..., None], axis=1)
    return jnp.where((j >= 0)[..., None], got, first[:, None])


def gather_last_valid(seq: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """seq (B, C, ...) -> (B, ...) rows at per-slot position `idx` (B,).

    The chunk computes all C positions; the oracle's `where(ok, new, old)`
    per-step carry is recovered by selecting the LAST VALID position's
    value (callers clamp idx and fall back to the old state for all-invalid
    lanes)."""
    ix = idx.reshape((-1,) + (1,) * (seq.ndim - 1))
    return jnp.take_along_axis(seq, ix, axis=1)[:, 0]


def last_valid_select(seq: jnp.ndarray, old: jnp.ndarray,
                      n_valid: jnp.ndarray) -> jnp.ndarray:
    """Final-state helper: the last valid position of `seq`, cast to and
    falling back on `old` (the incoming pool state) for lanes whose chunk
    had no valid tokens — exactly the oracle's masked per-step carry after
    a full chunk under a prefix mask."""
    idx = jnp.maximum(n_valid - 1, 0)
    got = gather_last_valid(seq, idx).astype(old.dtype)
    anyv = (n_valid > 0).reshape((-1,) + (1,) * (old.ndim - 1))
    return jnp.where(anyv, got, old)
