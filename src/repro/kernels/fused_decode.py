"""Pallas kernel: one FULL RWKV block decode step in a single launch.

This is the repo's analogue of the paper's fully on-chip datapath (§4):
HFRWKV's central claim is that one token flows matrix-vector array ->
EXP/σ/div units -> WKV update without intermediates ever leaving the chip.
Here the whole per-layer decode step — layernorm, token-shift mix, the
(optionally Δ-PoT-packed) r/k/v matvecs, the LUT/PWL approximation units,
the WKV state update, and the output/FFN projections — runs inside ONE
`pallas_call`, so on TPU the recurrent state and every intermediate stay
resident in VMEM for the whole block; the only HBM traffic per launch is
the weight stream (uint8 Δ-PoT codes when quantized — the same packing
`dpot_matmul` streams), the incoming residual `x`, and the written-back
state.

The kernel is model-agnostic: `fused_block_decode(block_fn, x, lp, st)`
traces the caller-supplied per-block function *inside* the kernel body, so
`models/rwkv4.py` and `models/rwkv6.py` pass the exact same block math
their per-op `decode_step` uses — which is what makes the fused path
bit-exact against the per-op oracle (tests/test_fused_decode.py) instead
of merely close.  Quantized weights arrive as `{"packed", "scale"}` leaves
in `lp` and are decoded by `block_fn` itself (via
`core.quant.serving.unpack_leaf`), i.e. inside the launch: int8 codes are
all that crosses HBM, exactly like `dpot_matmul`.

Grid: one program per `bb`-slot tile of the batch (default: the whole
batch in one program — serving pools are small and the weights are shared
across slots).  Parameters use constant index maps, so the Pallas grid
pipeline streams each weight tile once per launch regardless of batch
tiling — the chunked double-buffering story.

VMEM budget note: with Δ-PoT W8 packing a full rwkv4-7b block's weights
are ~uint8(4·D² + 2·D·F) ≈ 6 MiB at D=4096 — resident; the bf16 path at
production sizes would need an `nf`-style feature grid, which smoke and
serving shapes here don't require (off-TPU the kernel runs in interpret
mode where VMEM is not modelled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant.serving import is_packed_leaf
from repro.kernels.common import interpret_default


def broadcast_packed_scales(blocks, n_layers: int):
    """Make a packed stacked-blocks tree scannable over the layer axis.

    `pack_params` gives a stacked weight (L, ...) one shared scale with a
    broadcast leading 1 (e.g. (1, 1, D)); `lax.scan` needs every xs leaf to
    carry the L axis, so the scale is broadcast to (L, ...) here.  The
    per-layer slice then multiplies element-for-element exactly as the
    whole-tree broadcast would, keeping the decode bit-identical."""
    def fix(leaf):
        if not is_packed_leaf(leaf):
            return leaf
        scale = leaf["scale"]
        return {"packed": leaf["packed"],
                "scale": jnp.broadcast_to(
                    scale, (n_layers,) + tuple(scale.shape[1:]))}
    return jax.tree_util.tree_map(fix, blocks, is_leaf=is_packed_leaf)


def _const_spec(shape):
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def _batch_spec(shape, bb):
    nd = len(shape)
    return pl.BlockSpec((bb,) + tuple(shape[1:]),
                        lambda i, _nd=nd: (i,) + (0,) * (_nd - 1))


def fused_block_decode(block_fn, x, lp, st, *, bb: int | None = None,
                       interpret: bool | None = None):
    """Run `block_fn(lp, st, x) -> (x2, new_st)` as ONE Pallas launch.

    block_fn — per-block decode step; traced inside the kernel body, so
               everything it does (weight decode, matvecs, approx units,
               WKV update) happens within the single launch.
    x        — (B, D) residual stream entering the block.
    lp       — per-layer parameter tree; leaves may be packed Δ-PoT dicts
               (block_fn is responsible for decoding those).
    st       — per-layer state tree; every leaf has the batch on axis 0.
    bb       — batch tile (grid dimension); defaults to the full batch.
    """
    B = x.shape[0]
    bb = B if bb is None else min(int(bb), B)
    if B % bb:
        raise ValueError(f"batch {B} not divisible by batch tile {bb}")

    lp_leaves, lp_tdef = jax.tree_util.tree_flatten(lp)
    st_leaves, st_tdef = jax.tree_util.tree_flatten(st)
    n_lp, n_st = len(lp_leaves), len(st_leaves)

    # Output shapes/dtypes come from the block function itself, so the
    # kernel signature tracks any model's state layout automatically.
    out_ab = jax.eval_shape(lambda l, s, xx: block_fn(l, s, xx), lp, st, x)
    x2_ab, new_st_ab = out_ab
    new_st_leaves_ab, new_st_tdef = jax.tree_util.tree_flatten(new_st_ab)

    def kernel(*refs):
        in_refs, out_refs = refs[:1 + n_lp + n_st], refs[1 + n_lp + n_st:]
        xx = in_refs[0][...]
        lp_v = jax.tree_util.tree_unflatten(
            lp_tdef, [r[...] for r in in_refs[1:1 + n_lp]])
        st_v = jax.tree_util.tree_unflatten(
            st_tdef, [r[...] for r in in_refs[1 + n_lp:]])
        x2, new_st = block_fn(lp_v, st_v, xx)
        out_refs[0][...] = x2
        for ref, leaf in zip(out_refs[1:],
                             jax.tree_util.tree_leaves(new_st)):
            ref[...] = leaf

    in_specs = ([_batch_spec(x.shape, bb)] +
                [_const_spec(l.shape) for l in lp_leaves] +
                [_batch_spec(l.shape, bb) for l in st_leaves])
    out_specs = ([_batch_spec(x2_ab.shape, bb)] +
                 [_batch_spec(l.shape, bb) for l in new_st_leaves_ab])
    out_shape = ([jax.ShapeDtypeStruct(x2_ab.shape, x2_ab.dtype)] +
                 [jax.ShapeDtypeStruct(l.shape, l.dtype)
                  for l in new_st_leaves_ab])

    outs = pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret_default(interpret),
    )(x, *lp_leaves, *st_leaves)
    x2 = outs[0]
    new_st = jax.tree_util.tree_unflatten(new_st_tdef, list(outs[1:]))
    return x2, new_st
