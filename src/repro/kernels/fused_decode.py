"""Pallas kernels: RWKV decode steps fused into single launches.

Two granularities, both built on the same caller-supplied block function:

  * `fused_block_decode` — one FULL RWKV block decode step per launch
    (PR 2; a model decode step issues L of these under `lax.scan`);
  * `fused_model_decode` — the WHOLE-MODEL megakernel: ONE launch whose
    grid iterates over layers, with the residual stream carried in VMEM
    scratch across grid steps and each layer's weights streamed via
    layer-indexed BlockSpecs, so the Pallas grid pipeline double-buffers
    layer l+1's weight tiles behind layer l's compute — the paper's
    chunked double buffering (§4.2), made literal.


This is the repo's analogue of the paper's fully on-chip datapath (§4):
HFRWKV's central claim is that one token flows matrix-vector array ->
EXP/σ/div units -> WKV update without intermediates ever leaving the chip.
Here the whole per-layer decode step — layernorm, token-shift mix, the
(optionally Δ-PoT-packed) r/k/v matvecs, the LUT/PWL approximation units,
the WKV state update, and the output/FFN projections — runs inside ONE
`pallas_call`, so on TPU the recurrent state and every intermediate stay
resident in VMEM for the whole block; the only HBM traffic per launch is
the weight stream (uint8 Δ-PoT codes when quantized — the same packing
`dpot_matmul` streams), the incoming residual `x`, and the written-back
state.

The kernel is model-agnostic: `fused_block_decode(block_fn, x, lp, st)`
traces the caller-supplied per-block function *inside* the kernel body, so
`models/rwkv4.py` and `models/rwkv6.py` pass the exact same block math
their per-op `decode_step` uses — which is what makes the fused path
bit-exact against the per-op oracle (tests/test_fused_decode.py) instead
of merely close.  Quantized weights arrive as plane leaves in `lp` —
scalar `{"packed", "scale"}` W8, nibble-packed `{"packed4", "scale"}` W4
(two codes per uint8), or `{"vq_idx", "codebook"}` VQ — and are decoded
by `block_fn` itself (via `core.quant.serving.unpack_leaf`), i.e. inside
the launch: uint8 codes/indices are all that crosses HBM, exactly like
`dpot_matmul`.

Grid: one program per `bb`-slot tile of the batch (default: the whole
batch in one program — serving pools are small and the weights are shared
across slots).  Parameters use constant index maps, so the Pallas grid
pipeline streams each weight tile once per launch regardless of batch
tiling — the chunked double-buffering story.

VMEM budget note: with Δ-PoT W8 packing a full rwkv4-7b block's weights
are ~uint8(4·D² + 2·D·F) ≈ 6 MiB at D=4096 — resident; the bf16 path at
production sizes would need an `nf`-style feature grid, which smoke and
serving shapes here don't require (off-TPU the kernel runs in interpret
mode where VMEM is not modelled).
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl

from jax.experimental.pallas import tpu as pltpu

# broadcast_packed_scales and the chunked-stream slab form live with the
# quant format; re-exported here as part of this kernel's operand contract.
from repro.core.quant.serving import (   # noqa: F401  (re-export)
    FusedLayerStack, broadcast_packed_scales, fuse_layer_stack,
    is_packed_leaf, unfuse_layer)
from repro.kernels.common import interpret_default


def _const_spec(shape):
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def _batch_spec(shape, bb):
    nd = len(shape)
    return pl.BlockSpec((bb,) + tuple(shape[1:]),
                        lambda i, _nd=nd: (i,) + (0,) * (_nd - 1))


def fused_block_decode(block_fn, x, lp, st, *, bb: int | None = None,
                       interpret: bool | None = None):
    """Run `block_fn(lp, st, x) -> (x2, new_st)` as ONE Pallas launch.

    block_fn — per-block decode step; traced inside the kernel body, so
               everything it does (weight decode, matvecs, approx units,
               WKV update) happens within the single launch.
    x        — (B, D) residual stream entering the block.
    lp       — per-layer parameter tree; leaves may be packed Δ-PoT dicts
               (block_fn is responsible for decoding those).
    st       — per-layer state tree; every leaf has the batch on axis 0.
    bb       — batch tile (grid dimension); defaults to the full batch.
    """
    B = x.shape[0]
    bb = B if bb is None else min(int(bb), B)
    if B % bb:
        raise ValueError(f"batch {B} not divisible by batch tile {bb}")

    lp_leaves, lp_tdef = jax.tree_util.tree_flatten(lp)
    st_leaves, st_tdef = jax.tree_util.tree_flatten(st)
    n_lp, n_st = len(lp_leaves), len(st_leaves)

    # Output shapes/dtypes come from the block function itself, so the
    # kernel signature tracks any model's state layout automatically.
    out_ab = jax.eval_shape(lambda l, s, xx: block_fn(l, s, xx), lp, st, x)
    x2_ab, new_st_ab = out_ab
    new_st_leaves_ab, new_st_tdef = jax.tree_util.tree_flatten(new_st_ab)

    def kernel(*refs):
        in_refs, out_refs = refs[:1 + n_lp + n_st], refs[1 + n_lp + n_st:]
        xx = in_refs[0][...]
        lp_v = jax.tree_util.tree_unflatten(
            lp_tdef, [r[...] for r in in_refs[1:1 + n_lp]])
        st_v = jax.tree_util.tree_unflatten(
            st_tdef, [r[...] for r in in_refs[1 + n_lp:]])
        x2, new_st = block_fn(lp_v, st_v, xx)
        out_refs[0][...] = x2
        for ref, leaf in zip(out_refs[1:],
                             jax.tree_util.tree_leaves(new_st)):
            ref[...] = leaf

    in_specs = ([_batch_spec(x.shape, bb)] +
                [_const_spec(l.shape) for l in lp_leaves] +
                [_batch_spec(l.shape, bb) for l in st_leaves])
    out_specs = ([_batch_spec(x2_ab.shape, bb)] +
                 [_batch_spec(l.shape, bb) for l in new_st_leaves_ab])
    out_shape = ([jax.ShapeDtypeStruct(x2_ab.shape, x2_ab.dtype)] +
                 [jax.ShapeDtypeStruct(l.shape, l.dtype)
                  for l in new_st_leaves_ab])

    outs = pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret_default(interpret),
    )(x, *lp_leaves, *st_leaves)
    x2 = outs[0]
    new_st = jax.tree_util.tree_unflatten(new_st_tdef, list(outs[1:]))
    return x2, new_st


# ---------------------------------------------------------------------------
# Whole-model megakernel: the layer stack as ONE launch, grid over layers
# ---------------------------------------------------------------------------


def _stacked_layer_spec(shape, n_layers: int):
    """BlockSpec for a stacked (L, ...) per-layer operand: grid step (i, l)
    fetches layer l's slice.  A leading-1 leaf (a shared Δ-PoT scale from
    `pack_params`, or a broadcast LUT) gets a CONSTANT index map instead:
    the grid pipeline then keeps that tile resident across all layers while
    only the layer-indexed leaves (the uint8 code planes) are re-streamed —
    stacked packed-leaf slicing without materializing L scale copies."""
    nd = len(shape)
    block = (1,) + tuple(shape[1:])
    if shape[0] == n_layers:
        return pl.BlockSpec(block, lambda i, l, _nd=nd: (l,) + (0,) * (_nd - 1))
    if shape[0] == 1:
        return pl.BlockSpec(block, lambda i, l, _nd=nd: (0,) * _nd)
    raise ValueError(
        f"stacked per-layer leaf has leading dim {shape[0]}, "
        f"expected n_layers={n_layers} or 1 (broadcast)")


def _stacked_state_spec(shape, bb: int):
    """BlockSpec for a stacked (L, B, ...) state operand: grid step (i, l)
    addresses layer l's slice of batch tile i."""
    nd = len(shape)
    return pl.BlockSpec((1, bb) + tuple(shape[2:]),
                        lambda i, l, _nd=nd: (l, i) + (0,) * (_nd - 2))


def _state_tile_spec(shape, bb: int):
    """BlockSpec for a stacked (L, B, ...) state operand blocked over batch
    tiles only (the resident megakernel's 1-D grid): the kernel sees all L
    layers of its tile and indexes the layer axis itself.  Its siblings
    `_batch_spec`/`_const_spec` (above) cover the batch-tiled and
    whole-bound operands of the same grid."""
    nd = len(shape)
    return pl.BlockSpec((shape[0], bb) + tuple(shape[2:]),
                        lambda i, _nd=nd: (0, i) + (0,) * (_nd - 2))


def fused_model_decode(block_fn, x, blocks, state, *, bb: int | None = None,
                       weights: str | None = None,
                       interpret: bool | None = None):
    """Run the ENTIRE stacked-layer decode step as ONE Pallas launch.

    Where `fused_block_decode` fuses one layer (a model step is still L
    launches under `lax.scan`, bouncing the residual and recurrent state
    through HBM between every pair), this megakernel runs the whole stack
    in one launch: the residual never touches HBM between layers, each
    layer's state slice is read and written exactly once, and only the
    final residual leaves the kernel.  Two execution structures, selected
    by `weights` (same math, same bits — pinned against each other and the
    per-op oracle in tests/test_fused_decode.py):

      * "stream" (default on TPU) — grid = (B // bb, L), layer axis
        innermost.  Layer-indexed BlockSpec index maps fetch layer l's
        weight tiles from the stacked (L, ...) operands at grid step
        (i, l); the Pallas grid pipeline prefetches step (i, l+1)'s tiles
        while step (i, l) computes — the paper's chunked double buffering
        of the weight stream (§4.2), for models whose full weights exceed
        VMEM.  Δ-PoT leaves stream as uint8 code planes; their shared
        scales ride a constant index map and stay resident.  The residual
        is carried across grid steps in a VMEM scratch buffer, initialized
        from `x` at l == 0 (TPU grids execute sequentially on a core,
        which is what makes the carry well-defined; interpret mode
        preserves the same semantics).
      * "resident" (default off-TPU) — grid = (B // bb,): stacked weights
        bind whole under constant index maps and the kernel unrolls the
        layer loop in-body with static layer indices — the paper's
        fully-on-chip regime for models that fit VMEM outright (§4.1 —
        nothing to double-buffer when nothing re-streams).  Off-TPU this
        is also much faster to execute: the interpreter re-materializes
        every layer-blocked operand once per grid step (a full-buffer
        write-back copy per layer, per operand), while constant maps and
        static slices compile to straight-line code.

    Both structures are pinned bit-identical to each other and to the
    per-op oracle in tests/test_fused_decode.py — the stream structure is
    exercised off-TPU by passing `weights="stream"` explicitly (interpret
    mode runs its grid sequentially with the same carry semantics).

    In BOTH structures the weight stream is chunked
    (`core.quant.serving.fuse_layer_stack`): layer l's weights arrive as
    one contiguous (1, N) slab row per dtype — the uint8 slab carries
    every code plane kind (W8 bytes, W4 nibble pairs at HALF the bytes,
    VQ indices) and the bf16 plane its floating leaves — and the
    per-layer tree is rebuilt in-kernel with STATIC slices
    (`unfuse_layer`), so each layer costs one memory stream per dtype
    instead of one gather per leaf.  Broadcast leading-1 leaves (shared
    packed scales, VQ codebooks, LUT tables) ride constant index maps and
    stay resident across the whole launch.

    block_fn — per-layer decode step `(lp, st, x) -> (x2, new_st)`, traced
               inside the kernel; `lp`/`st` arrive with the layer axis
               squeezed (exactly the slices `lax.scan` would feed it).
    x        — (B, D) residual entering the stack.
    blocks   — stacked per-layer parameter tree (every array leaf carries
               the layer axis (L, ...) or a broadcast leading 1; packed
               Δ-PoT `{"packed", "scale"}` dicts may appear as-is — no
               `broadcast_packed_scales` needed on this path), or an
               already-chunked `FusedLayerStack`.  Raw trees are chunked
               on entry, which repacks the weights EVERY call — serving
               paths should pre-fuse once
               (`Model.prepare_fused_model_params`; the engine does).
    state    — stacked per-layer state tree; leaves are (L, B, ...).
    bb       — batch tile; defaults to the whole batch (serving pools are
               small; weights are fetched once per tile, so bb=B minimizes
               the weight traffic).  Tiling is bit-transparent for any
               block_fn whose math is per-example; rwkv4's hw numerics are
               not (the A9 activation fake-quant scales over the whole
               batch), so hw parity requires bb=B.
    """
    B = x.shape[0]
    bb = B if bb is None else min(int(bb), B)
    if B % bb:
        raise ValueError(f"batch {B} not divisible by batch tile {bb}")
    interpret = interpret_default(interpret)
    weights = ("resident" if interpret else "stream") \
        if weights is None else weights
    if weights not in ("stream", "resident"):
        raise ValueError(f"weights={weights!r}: expected 'stream' or "
                         "'resident'")

    st_leaves, st_tdef = jax.tree_util.tree_flatten(state)
    if not st_leaves:
        raise ValueError("state tree is empty — need (L, B, ...) leaves")
    n_layers = st_leaves[0].shape[0]
    n_st = len(st_leaves)

    # Chunk the weight stream: per-dtype (L, N) slabs so layer l is ONE
    # contiguous fetch per dtype (uint8 code plane / bf16 plane), unpacked
    # in-kernel with static slices.  Callers on a hot path pre-fuse (the
    # engine / Model.prepare_fused_model_params); raw trees are fused here
    # for convenience, which repacks the weights on every call.
    if not isinstance(blocks, FusedLayerStack):
        blocks = fuse_layer_stack(blocks, n_layers)
    if blocks.n_layers != n_layers:
        raise ValueError(f"weight stack has {blocks.n_layers} layers, "
                         f"state has {n_layers}")
    slab_keys = tuple(sorted(blocks.slabs))
    slab_leaves = [blocks.slabs[k] for k in slab_keys]
    aux_leaves = list(blocks.aux)
    manifest, bl_tdef = blocks.manifest, blocks.tdef
    n_sl, n_aux = len(slab_leaves), len(aux_leaves)
    n_bl = n_sl + n_aux

    # Per-layer output shapes/dtypes from the block function itself, so the
    # kernel signature tracks any model's state layout automatically.
    lp0 = jax.eval_shape(
        lambda rows, aux: unfuse_layer(rows, aux, manifest, bl_tdef),
        {k: jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
         for k, a in zip(slab_keys, slab_leaves)},
        [jax.ShapeDtypeStruct(a.shape[1:], a.dtype) for a in aux_leaves])
    st0 = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct((bb,) + a.shape[2:], a.dtype), state)
    x0 = jax.ShapeDtypeStruct((bb,) + x.shape[1:], x.dtype)
    x2_ab, new_st_ab = jax.eval_shape(block_fn, lp0, st0, x0)
    new_st_leaves_ab, new_st_tdef = jax.tree_util.tree_flatten(new_st_ab)

    out_shape = (
        [jax.ShapeDtypeStruct((B,) + tuple(x2_ab.shape[1:]), x2_ab.dtype)] +
        [jax.ShapeDtypeStruct((n_layers, B) + tuple(a.shape[1:]), a.dtype)
         for a in new_st_leaves_ab])

    def layer_params(rows, aux_vals):
        return unfuse_layer(dict(zip(slab_keys, rows)), aux_vals,
                            manifest, bl_tdef)

    if weights == "stream":
        # -- grid over (batch tile, layer); residual carried in scratch --
        def kernel(*refs):
            in_refs = refs[:1 + n_bl + n_st]
            out_refs = refs[1 + n_bl + n_st:-1]
            x_scr = refs[-1]
            l = pl.program_id(1)

            @pl.when(l == 0)
            def _load_residual():   # new batch tile: residual enters once
                x_scr[...] = in_refs[0][...].astype(x_scr.dtype)

            lp = layer_params(
                [r[...][0] for r in in_refs[1:1 + n_sl]],
                [r[...][0] for r in in_refs[1 + n_sl:1 + n_bl]])
            st = jax.tree_util.tree_unflatten(
                st_tdef, [r[...][0] for r in in_refs[1 + n_bl:]])
            x2, new_st = block_fn(lp, st, x_scr[...])
            x_scr[...] = x2.astype(x_scr.dtype)
            out_refs[0][...] = x2.astype(out_refs[0].dtype)
            for ref, leaf in zip(out_refs[1:],
                                 jax.tree_util.tree_leaves(new_st)):
                ref[...] = leaf[None]

        in_specs = (
            [pl.BlockSpec((bb,) + tuple(x.shape[1:]),
                          lambda i, l, _nd=x.ndim:
                          (i,) + (0,) * (_nd - 1))] +
            [_stacked_layer_spec(a.shape, n_layers) for a in slab_leaves] +
            [_stacked_layer_spec(a.shape, n_layers) for a in aux_leaves] +
            [_stacked_state_spec(a.shape, bb) for a in st_leaves])
        out_specs = (
            [pl.BlockSpec((bb,) + tuple(x2_ab.shape[1:]),
                          lambda i, l, _nd=x2_ab.ndim:
                          (i,) + (0,) * (_nd - 1))] +
            [_stacked_state_spec((n_layers, B) + tuple(a.shape[1:]), bb)
             for a in new_st_leaves_ab])
        grid = (B // bb, n_layers)
        scratch = [pltpu.VMEM((bb,) + tuple(x2_ab.shape[1:]), x2_ab.dtype)]
    else:
        # -- grid over batch tiles only; the layer loop runs IN-body as a
        # fori_loop whose only carry is the residual: the whole-bound slab
        # refs are loop-invariant captures, each iteration fetches layer
        # l's slab row (one contiguous stream per dtype), rebuilds the
        # layer tree with static slices, and writes layer l's fresh state
        # in place --
        def kernel(*refs):
            in_refs = refs[:1 + n_bl + n_st]
            out_refs = refs[1 + n_bl + n_st:]

            def body(l, xx):
                lp = layer_params(
                    [r[l] for r in in_refs[1:1 + n_sl]],
                    [r[0] for r in in_refs[1 + n_sl:1 + n_bl]])
                st = jax.tree_util.tree_unflatten(
                    st_tdef, [r[l] for r in in_refs[1 + n_bl:]])
                x2, new_st = block_fn(lp, st, xx)
                for ref, leaf in zip(out_refs[1:],
                                     jax.tree_util.tree_leaves(new_st)):
                    ref[l] = leaf
                return x2.astype(xx.dtype)

            xx = jax.lax.fori_loop(0, n_layers, body, in_refs[0][...])
            out_refs[0][...] = xx.astype(out_refs[0].dtype)

        in_specs = (
            [_batch_spec(x.shape, bb)] +
            [_const_spec(a.shape) for a in slab_leaves] +
            [_const_spec(a.shape) for a in aux_leaves] +
            [_state_tile_spec(a.shape, bb) for a in st_leaves])
        out_specs = (
            [_batch_spec((B,) + tuple(x2_ab.shape[1:]), bb)] +
            [_state_tile_spec((n_layers, B) + tuple(a.shape[1:]), bb)
             for a in new_st_leaves_ab])
        grid = (B // bb,)
        scratch = []

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,   # resolved above (weights default needs it)
    )(x, *slab_leaves, *aux_leaves, *st_leaves)
    x2 = outs[0]
    new_st = jax.tree_util.tree_unflatten(new_st_tdef, list(outs[1:]))
    return x2, new_st
