"""Pallas TPU kernel: single-pass fused LayerNorm (paper §4.5 -> TPU).

The paper's LayerNorm module computes mean and E[x²] with parallel ATAC
(addition-tree + accumulator) units in ONE pass over the data (Eq. 12:
σ² = E[x²] − μ²) and normalizes as the blocks stream through.  The TPU
mapping: each grid step holds a (rows x D) tile in VMEM, the VPU reduces
sum(x) and sum(x²) simultaneously (two live registers — the two ATAC trees),
then normalizes in-place — one HBM read, one HBM write, zero intermediate
round-trips, which is exactly the bandwidth story of the paper's module.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    # the two ATAC trees: Σx and Σx² in the same pass
    mu = jnp.mean(x, axis=-1, keepdims=True)
    ex2 = jnp.mean(x * x, axis=-1, keepdims=True)
    var = ex2 - mu * mu
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...][None, :] +
                  b_ref[...][None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def fused_layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                    *, eps: float = 1e-5, block_rows: int = 256,
                    interpret: bool | None = None) -> jnp.ndarray:
    """x: (..., D) -> LayerNorm over the last dim."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(block_rows, R)
    while R % br != 0:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret_default(interpret),
    )(xf, gamma, beta)
    return out.reshape(orig_shape)
