"""Shared kernel utilities."""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def exact_jit(fn, donate_argnums=()):
    """jit with XLA's excess-precision folding DISABLED: every trace-level
    rounding (e.g. a bf16 op's output, or a `x.astype(bf16)`) is real in
    the compiled program instead of being elided into a wider consumer.

    Why this exists: two programs with the same per-element op semantics
    but different structure (a per-token scan vs a chunk-shaped
    restructuring of the same math) normally are NOT bitwise comparable,
    because XLA decides per fusion context which low-precision roundings
    to skip.  Pinning `xla_allow_excess_precision=False` makes the rounding
    behavior equal to the trace — structure-independent — which is what
    lets the fused chunked-prefill path be BIT-identical to the per-op
    scan-of-`decode_step` oracle (the serving engine compiles both its
    prefill programs through this; see docs/serving.md).  Compilation is
    AOT (`lower().compile()`) because compiler options only attach there;
    the wrapper lowers lazily on first call and caches the executable.
    """
    jitted = jax.jit(fn, donate_argnums=donate_argnums)
    cache = {}

    def call(*args):
        # keyed on the flattened avals so new shapes/dtypes recompile,
        # like jax.jit would (positional args only)
        key = tuple((leaf.shape, str(leaf.dtype)) if hasattr(leaf, "shape")
                    else leaf
                    for leaf in jax.tree_util.tree_leaves(args))
        if key not in cache:
            cache[key] = jitted.lower(*args).compile(
                compiler_options={"xla_allow_excess_precision": False})
        return cache[key](*args)
    return call


def interpret_default(interpret: bool | None) -> bool:
    """Pallas kernels target TPU; everywhere else (this CPU container)
    they run in interpret mode, which executes the kernel body in Python —
    the correctness-validation path required by the assignment."""
    if interpret is None:
        return not on_tpu()
    return interpret
