"""Shared kernel utilities."""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default(interpret: bool | None) -> bool:
    """Pallas kernels target TPU; everywhere else (this CPU container)
    they run in interpret mode, which executes the kernel body in Python —
    the correctness-validation path required by the assignment."""
    if interpret is None:
        return not on_tpu()
    return interpret
