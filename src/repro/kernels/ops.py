"""Public jit'd wrappers around the Pallas kernels (the `ops.py` contract).

These are what model code imports; each dispatches to the Pallas kernel on
TPU and to interpret mode elsewhere (repro.kernels.common).
"""
from repro.kernels.dpot_matmul import dpot_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_layernorm import fused_layernorm
from repro.kernels.wkv4 import wkv4_pallas
from repro.kernels.wkv6 import wkv6_pallas
from repro.kernels.expsig import exp_kernel, sigmoid_kernel
from repro.kernels.fused_ce import fused_cross_entropy

__all__ = ["dpot_matmul", "flash_attention", "fused_cross_entropy",
           "fused_layernorm", "wkv4_pallas", "wkv6_pallas", "exp_kernel",
           "sigmoid_kernel"]
