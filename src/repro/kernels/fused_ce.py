"""Pallas TPU kernel: fused cross-entropy over a large vocabulary.

Cell-A's residual memory term (EXPERIMENTS §Perf) is the loss head: XLA's
`log_softmax` materializes f32 logits + f32 log-probs (2 x N x V x 4 bytes)
before the label gather.  Fused version: stream vocab blocks through VMEM,
keep the online (max, sumexp, target-logit) state per row in scratch —
per-row loss comes out with ONE read of the logits and nothing else.

Backward (custom VJP): dlogits = (softmax(x) - onehot(label)) * g, computed
block-wise from the saved per-row logsumexp — again one logits read and one
dlogits write, no f32 intermediates.

    loss = fused_cross_entropy(logits (N,V), labels (N,)) -> (N,) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default

NEG_INF = -1e30


def _fwd_kernel(x_ref, lbl_ref, loss_ref, lse_ref, m_scr, l_scr, t_scr,
                *, bv: int):
    j = pl.program_id(1)
    n_v = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        t_scr[...] = jnp.zeros_like(t_scr)

    x = x_ref[...].astype(jnp.float32)                    # (bn, bv)
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(x - m_new[:, None]), axis=-1)
    m_scr[...], l_scr[...] = m_new, l_new
    # target logit if the label lands in this vocab block
    lbl = lbl_ref[...]                                    # (bn,)
    local = lbl - j * bv
    in_blk = (local >= 0) & (local < bv)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    hit = cols == local[:, None]
    t_scr[...] += jnp.sum(jnp.where(hit & in_blk[:, None], x, 0.0), axis=-1)

    @pl.when(j == n_v - 1)
    def _finish():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        lse_ref[...] = lse
        loss_ref[...] = lse - t_scr[...]


def _bwd_kernel(x_ref, lbl_ref, lse_ref, g_ref, dx_ref, *, bv: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    p = jnp.exp(x - lse_ref[...][:, None])
    local = lbl_ref[...] - j * bv
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    hit = (cols == local[:, None]) & \
        ((local >= 0) & (local < bv))[:, None]
    dx = (p - hit.astype(jnp.float32)) * g_ref[...][:, None]
    dx_ref[...] = dx.astype(dx_ref.dtype)


def _blocks(N, V, bn, bv):
    bn = min(bn, N)
    while N % bn != 0:
        bn //= 2
    bv = min(bv, V)
    while V % bv != 0:
        bv -= 128 if bv > 128 else 1
    return max(bn, 1), max(bv, 1)


def _fwd_call(x, labels, bn, bv, interpret):
    N, V = x.shape
    bn, bv = _blocks(N, V, bn, bv)
    loss, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, bv=bv),
        grid=(N // bn, V // bv),
        in_specs=[pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
                  pl.BlockSpec((bn,), lambda i, j: (i,))],
        out_specs=[pl.BlockSpec((bn,), lambda i, j: (i,)),
                   pl.BlockSpec((bn,), lambda i, j: (i,))],
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.float32),
                   jax.ShapeDtypeStruct((N,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32),
                        pltpu.VMEM((bn,), jnp.float32),
                        pltpu.VMEM((bn,), jnp.float32)],
        interpret=interpret_default(interpret),
    )(x, labels)
    return loss, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _ce_core(x, labels, bn, bv, interpret):
    loss, _ = _fwd_call(x, labels, bn, bv, interpret)
    return loss


def _ce_fwd(x, labels, bn, bv, interpret):
    loss, lse = _fwd_call(x, labels, bn, bv, interpret)
    return loss, (x, labels, lse)


def _ce_bwd(bn, bv, interpret, res, g):
    x, labels, lse = res
    N, V = x.shape
    bn, bv = _blocks(N, V, bn, bv)
    dx = pl.pallas_call(
        functools.partial(_bwd_kernel, bv=bv),
        grid=(N // bn, V // bv),
        in_specs=[pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
                  pl.BlockSpec((bn,), lambda i, j: (i,)),
                  pl.BlockSpec((bn,), lambda i, j: (i,)),
                  pl.BlockSpec((bn,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, V), x.dtype),
        interpret=interpret_default(interpret),
    )(x, labels, lse, g.astype(jnp.float32))
    return dx, None


_ce_core.defvjp(_ce_fwd, _ce_bwd)


@functools.partial(jax.jit, static_argnames=("bn", "bv", "interpret"))
def fused_cross_entropy(logits, labels, *, bn: int = 256, bv: int = 2048,
                        interpret: bool | None = None):
    """logits: (..., V); labels: (...) int32 -> per-example NLL (...) f32."""
    V = logits.shape[-1]
    lead = logits.shape[:-1]
    x = logits.reshape(-1, V)
    lbl = labels.reshape(-1).astype(jnp.int32)
    loss = _ce_core(x, lbl, bn, bv, interpret)
    return loss.reshape(lead)
