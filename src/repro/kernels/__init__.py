"""Pallas TPU kernels for the paper's compute hot-spots.

  dpot_matmul     — Δ-PoT-packed weight matmul: stream int8 codes HBM->VMEM,
                    decode on the VPU, feed the MXU (paper C1 on TPU)
  wkv4            — fused RWKV-4 WKV scan, state on-chip (paper C4)
  wkv6            — chunked RWKV-6 WKV, (N,N) state in VMEM scratch
  fused_layernorm — single-pass mean/E[x²] LayerNorm (paper §4.5 ATAC)
  expsig          — reusable EXP-σ unit: LUT exp + PWL sigmoid (paper §4.4)
  flash_attention — fused causal attention, scores stay in VMEM (the
                    paper's on-chip principle applied beyond RWKV — §Perf)
  fused_ce        — vocab-blocked cross-entropy: online logsumexp, no f32
                    log-prob materialization (§Perf Cell A, it-3)
  fused_decode    — ONE launch for a whole RWKV block decode step: ln,
                    token-shift mix, Δ-PoT matvecs, exp/σ units, WKV
                    update all on-chip (the paper's fully-on-chip
                    datapath — docs/kernels.md)

Each kernel file carries the pl.pallas_call + BlockSpec; ops.py is the jit'd
public surface; ref.py the pure-jnp oracles.
"""
from repro.kernels.ops import (
    dpot_matmul, flash_attention, fused_cross_entropy, fused_layernorm,
    wkv4_pallas, wkv6_pallas, exp_kernel, sigmoid_kernel)

__all__ = ["dpot_matmul", "flash_attention", "fused_cross_entropy",
           "fused_layernorm", "wkv4_pallas", "wkv6_pallas", "exp_kernel",
           "sigmoid_kernel"]
