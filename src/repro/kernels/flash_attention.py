"""Pallas TPU kernel: fused causal flash attention (beyond-paper opt #1).

Motivation from the roofline (EXPERIMENTS.md §Perf): in the XLA-lowered
attention the (B,H,Sq,Skv) score/softmax intermediates materialize to HBM —
at train_4k they are the DOMINANT memory-roofline term for every attention
arch.  The paper's fully-on-chip principle (C4) applied to attention: tile
Q into VMEM, stream KV blocks through VMEM, keep scores/softmax state in
registers — HBM traffic collapses to Q+K+V+O.

Grid (B*H, Sq/bq, Skv/bkv), KV innermost; the (m, l, acc) online-softmax
state lives in VMEM scratch across the KV sweep.  Causality: KV blocks
strictly above the diagonal are skipped via pl.when (their writes would be
masked anyway, this saves the compute).

GQA is handled by the wrapper (q heads grouped per kv head).  The oracle is
repro.models.layers._plain_attention via ref.flash_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bq: int, bkv: int, causal: bool):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)
    n_kv = pl.num_programs(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = q_idx * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 0)
            kpos = kv_idx * bkv + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    if causal:
        # skip KV blocks entirely above the diagonal
        pl.when(kv_idx * bkv <= q_idx * bq + bq - 1)(_block)
    else:
        _block()

    @pl.when(kv_idx == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _kernel_fwd(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale: float, bq: int, bkv: int, causal: bool):
    """Forward that also emits the log-sum-exp rows for the backward."""
    _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            scale=scale, bq=bq, bkv=bkv, causal=causal)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _emit_lse():
        lse_ref[0] = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))


def _blocks(Sq, Skv, bq, bkv):
    bq = min(bq, Sq)
    while Sq % bq != 0:
        bq //= 2
    bkv = min(bkv, Skv)
    while Skv % bkv != 0:
        bkv //= 2
    return bq, bkv


def _fwd_call(qh, kh, vh, *, causal, bq, bkv, interpret):
    BH, Sq, d = qh.shape
    Skv = kh.shape[1]
    bq, bkv = _blocks(Sq, Skv, bq, bkv)
    out, lse = pl.pallas_call(
        functools.partial(_kernel_fwd, scale=1.0 / math.sqrt(d), bq=bq,
                          bkv=bkv, causal=causal),
        grid=(BH, Sq // bq, Skv // bkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, d), qh.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m: running max
            pltpu.VMEM((bq,), jnp.float32),       # l: running denom
            pltpu.VMEM((bq, d), jnp.float32),     # acc: running numerator
        ],
        interpret=interpret_default(interpret),
    )(qh, kh, vh)
    return out, lse


# ---------------------------------------------------------------------------
# Backward kernels.  dS = P ∘ (dP − D) with P = exp(S − lse),
# D_i = rowsum(dO_i ∘ O_i):
#   dQ_i = scale · Σ_j dS_ij K_j      (grid: j innermost, dQ accumulates)
#   dK_j = scale · Σ_i dS_ij^T Q_i    (grid: i innermost, dK/dV accumulate)
#   dV_j = Σ_i P_ij^T dO_i
# ---------------------------------------------------------------------------


def _scores(q, k, scale, causal, q_idx, kv_idx, bq, bkv):
    s = jnp.dot(q.astype(jnp.float32) * scale, k.astype(jnp.float32).T,
                preferred_element_type=jnp.float32)
    if causal:
        qpos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = kv_idx * bkv + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bkv), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    return s


def _kernel_dq(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref, dq_ref,
               *, scale: float, bq: int, bkv: int, causal: bool):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dq_ref[0] = jnp.zeros_like(dq_ref[0])

    @pl.when(jnp.logical_not(causal) | (j * bkv <= i * bq + bq - 1))
    def _block():
        s = _scores(q_ref[0], k_ref[0], scale, causal, i, j, bq, bkv)
        p = jnp.exp(s - lse_ref[0][:, None])
        dp = jnp.dot(do_ref[0].astype(jnp.float32),
                     v_ref[0].astype(jnp.float32).T,
                     preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_ref[0][:, None])
        dq_ref[0] += (scale * jnp.dot(
            ds, k_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)).astype(dq_ref.dtype)


def _kernel_dkv(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref,
                dk_ref, dv_ref, *, scale: float, bq: int, bkv: int,
                causal: bool):
    j = pl.program_id(1)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        dk_ref[0] = jnp.zeros_like(dk_ref[0])
        dv_ref[0] = jnp.zeros_like(dv_ref[0])

    @pl.when(jnp.logical_not(causal) | (j * bkv <= i * bq + bq - 1))
    def _block():
        s = _scores(q_ref[0], k_ref[0], scale, causal, i, j, bq, bkv)
        p = jnp.exp(s - lse_ref[0][:, None])
        do = do_ref[0].astype(jnp.float32)
        dv_ref[0] += jnp.dot(p.T, do,
                             preferred_element_type=jnp.float32
                             ).astype(dv_ref.dtype)
        dp = jnp.dot(do, v_ref[0].astype(jnp.float32).T,
                     preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_ref[0][:, None])
        dk_ref[0] += (scale * jnp.dot(
            ds.T, q_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)).astype(dk_ref.dtype)


def _bwd_call(qh, kh, vh, oh, lse, doh, *, causal, bq, bkv, interpret):
    BH, Sq, d = qh.shape
    Skv = kh.shape[1]
    bq, bkv = _blocks(Sq, Skv, bq, bkv)
    delta = jnp.sum(doh.astype(jnp.float32) * oh.astype(jnp.float32),
                    axis=-1)                                  # (BH, Sq)
    q_spec = pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0))
    kv_spec = pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0))
    row_spec = pl.BlockSpec((1, bq), lambda b, i, j: (b, i))
    dq = pl.pallas_call(
        functools.partial(_kernel_dq, scale=1.0 / math.sqrt(d), bq=bq,
                          bkv=bkv, causal=causal),
        grid=(BH, Sq // bq, Skv // bkv),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), jnp.float32),
        interpret=interpret_default(interpret),
    )(qh, kh, vh, doh, lse, delta)
    # swapped grid: (b, j, i) so dk/dv accumulate over the innermost i
    q_spec2 = pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0))
    kv_spec2 = pl.BlockSpec((1, bkv, d), lambda b, j, i: (b, j, 0))
    row_spec2 = pl.BlockSpec((1, bq), lambda b, j, i: (b, i))
    dk, dv = pl.pallas_call(
        functools.partial(_kernel_dkv, scale=1.0 / math.sqrt(d), bq=bq,
                          bkv=bkv, causal=causal),
        grid=(BH, Skv // bkv, Sq // bq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2,
                  row_spec2],
        out_specs=[kv_spec2, kv_spec2],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Skv, d), jnp.float32),
            jax.ShapeDtypeStruct((BH, Skv, d), jnp.float32),
        ],
        interpret=interpret_default(interpret),
    )(qh, kh, vh, doh, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public API with custom VJP (forward + backward both fully fused)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_core(qh, kh, vh, causal, bq, bkv, interpret):
    out, _ = _fwd_call(qh, kh, vh, causal=causal, bq=bq, bkv=bkv,
                       interpret=interpret)
    return out


def _flash_core_fwd(qh, kh, vh, causal, bq, bkv, interpret):
    out, lse = _fwd_call(qh, kh, vh, causal=causal, bq=bq, bkv=bkv,
                         interpret=interpret)
    return out, (qh, kh, vh, out, lse)


def _flash_core_bwd(causal, bq, bkv, interpret, res, do):
    qh, kh, vh, out, lse = res
    dq, dk, dv = _bwd_call(qh, kh, vh, out, lse, do, causal=causal,
                           bq=bq, bkv=bkv, interpret=interpret)
    return dq.astype(qh.dtype), dk.astype(kh.dtype), dv.astype(vh.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def kernel_traffic(B: int, H: int, Sq: int, Skv: int, d: int, *,
                   bq: int = 512, bkv: int = 512, causal: bool = True,
                   train: bool = True, elem_bytes: int = 2) -> dict:
    """Analytic HBM traffic + flops of the fused kernels, derived directly
    from the BlockSpecs above (the assignment's structural-reasoning rule:
    the BlockSpec shapes ARE the traffic claim — interpret mode cannot
    measure this because its functional grid loop copies whole arrays).

    Per the index maps:
      fwd : Q block resident per row (read once);   K,V re-read per q-row
            -> Q + (Sq/bq)·(K+V) + O writes (+lse)
      dq  : same pattern, + dO reads, dQ f32 writes
      dkv : K,V resident per column; Q,dO re-read per kv-col
            -> (Skv/bkv)·(Q+dO) + K + V + dK,dV f32 writes
    Causality halves the streamed re-reads (blocks above the diagonal are
    skipped by pl.when).  Flops: 2·B·H·Sq·Skv·d per dot, dots counted from
    the kernel bodies (fwd 2; dq 3; dkv 4; remat re-runs fwd).
    """
    bq, bkv = _blocks(Sq, Skv, bq, bkv)
    half = 0.5 if causal else 1.0
    qb = B * H * Sq * d * elem_bytes
    kb = B * H * Skv * d * elem_bytes
    f32 = 2 * elem_bytes
    n_row = Sq // bq
    n_col = Skv // bkv
    fwd_bytes = qb + half * n_row * 2 * kb + qb  # Q in, KV stream, O out
    dot = 2.0 * B * H * Sq * Skv * d * half
    fwd_flops = 2 * dot
    if not train:
        return {"bytes": fwd_bytes, "flops": fwd_flops}
    dq_bytes = (qb + half * n_row * 2 * kb + qb          # Q, KV, dO reads
                + qb * 2)                                # dQ f32 out
    dkv_bytes = (2 * kb + half * n_col * 2 * qb          # KV + Q,dO stream
                 + 2 * kb * 2)                           # dK,dV f32 out
    total_bytes = 2 * fwd_bytes + dq_bytes + dkv_bytes   # fwd + remat fwd
    total_flops = 2 * fwd_flops + 3 * dot + 4 * dot
    return {"bytes": total_bytes, "flops": total_flops}


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bkv: int = 512, interpret: bool | None = None):
    """q: (B, Sq, H, d); k, v: (B, Skv, KVH, d), H % KVH == 0.
    Returns (B, Sq, H, d).  Scores/softmax never touch HBM, forward OR
    backward (custom VJP with fused dq / dkv kernels)."""
    B, Sq, H, d = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    rep = H // KVH
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # (B*H, S, d) layout: one grid row per (batch, head)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, d)
    vh = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, d)
    out = _flash_core(qh, kh, vh, causal, bq, bkv, interpret)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
