"""Pallas TPU kernel: chunked RWKV-6 WKV (data-dependent decay).

Grid (B, H): each cell owns one head's (N x N) state, resident in a VMEM
scratch across all chunks (the paper's on-chip state principle).  Per chunk
of C tokens the work is dense (C,N)x(N,N) and (C,C,N) contractions — MXU
food — with the exact per-pair decay tensor masked strictly-lower BEFORE the
exp, so every live exponent is <= 0: underflow-only stability (same scheme
as the ref's diagonal blocks, applied chunk-wide).

Oracle: repro.core.wkv.wkv6.wkv6_scan / wkv6_chunked.

`wkv6_seq_pallas` (below) is the SEQUENTIAL sibling used by the fused
chunked-prefill path: same grid, same on-chip (N x N) state residency, but
the recurrence advances with the exact per-step `wkv6_step` math (the
chunked form's log-space reassociation is NOT bit-identical to the step
scan, and prefill must be).  It adds the serving operands the prefill
masking semantics need: a (B, T) `valid` commit mask and a `carry_dtype`
that rounds the carried state through the pool's storage dtype every step,
exactly as the per-op decode oracle does between steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            y_ref, sf_ref, *, T: int, C: int, N: int):
    n_chunks = T // C
    u = u_ref[...].astype(jnp.float32)[0]                 # (1,N) -> (N,)
    mask = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)  # strict lower

    def chunk_body(g, S):
        # int ref indices break jax 0.4.x interpret-mode discharge; dslice
        sl = (pl.dslice(0, 1), pl.dslice(0, 1),
              pl.dslice(g * C, C), slice(None))
        rc = pl.load(r_ref, sl).astype(jnp.float32)[0, 0]  # (C,N)
        kc = pl.load(k_ref, sl).astype(jnp.float32)[0, 0]
        vc = pl.load(v_ref, sl).astype(jnp.float32)[0, 0]
        wc = pl.load(w_ref, sl).astype(jnp.float32)[0, 0]
        logw = jnp.log(jnp.maximum(wc, 1e-38))
        L = jnp.cumsum(logw, axis=0)                      # inclusive (C,N)
        Lprev = L - logw                                  # exclusive
        # inter-chunk: exponents Lprev <= 0
        y = jnp.dot(rc * jnp.exp(Lprev), S,
                    preferred_element_type=jnp.float32)   # (C,N)
        # intra-chunk: exact pairwise decay, strictly-lower masked pre-exp
        D = Lprev[:, None, :] - L[None, :, :]             # (C,C,N)
        D = jnp.where(mask[:, :, None] > 0, D, -1e30)
        att = jnp.einsum("sn,in,sin->si", rc, kc, jnp.exp(D))
        y = y + jnp.dot(att, vc, preferred_element_type=jnp.float32)
        # bonus (current token)
        y = y + jnp.sum(rc * u[None] * kc, axis=-1, keepdims=True) * vc
        pl.store(y_ref, sl, y[None, None].astype(y_ref.dtype))
        # state update: exponents Ltot - L <= 0 and Ltot <= 0
        Ltot = L[-1:, :]                                  # (1,N)
        k_fut = kc * jnp.exp(Ltot - L)
        return jnp.exp(Ltot[0])[:, None] * S + jnp.dot(
            k_fut.T, vc, preferred_element_type=jnp.float32)

    # int ref indices break jax 0.4.x interpret-mode discharge; use dslice
    s_sl = (pl.dslice(0, 1), pl.dslice(0, 1), slice(None), slice(None))
    S = jax.lax.fori_loop(0, n_chunks, chunk_body,
                          pl.load(s0_ref, s_sl)[0, 0].astype(jnp.float32))
    pl.store(sf_ref, s_sl, S[None, None])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, s0=None, *, chunk: int = 64,
                interpret: bool | None = None):
    """r,k,v,w: (B,T,H,N); u: (H,N) -> (y (B,T,H,N) f32, S (B,H,N,N))."""
    B, T, H, N = r.shape
    C = min(chunk, T)
    while T % C != 0:
        C //= 2
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)
    # head-major layout so each grid cell reads a contiguous (T, N) strip
    tr = lambda x: jnp.transpose(x, (0, 2, 1, 3))         # (B,H,T,N)
    seq_spec = pl.BlockSpec((1, 1, T, N), lambda b, h: (b, h, 0, 0))
    u_spec = pl.BlockSpec((1, N), lambda b, h: (h, 0))
    st_spec = pl.BlockSpec((1, 1, N, N), lambda b, h: (b, h, 0, 0))
    y, sf = pl.pallas_call(
        functools.partial(_kernel, T=T, C=C, N=N),
        grid=(B, H),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec, st_spec],
        out_specs=[seq_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        interpret=interpret_default(interpret),
    )(tr(r), tr(k), tr(v), tr(w), u, s0)
    return jnp.transpose(y, (0, 2, 1, 3)), sf


# ---------------------------------------------------------------------------
# Sequential form: exact per-step wkv6_step math, state on-chip, masked
# ---------------------------------------------------------------------------


def _seq_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, *refs,
                T: int, masked: bool, carry: str | None):
    refs = list(refs)
    valid_ref = refs.pop(0) if masked else None
    y_ref, sf_ref = refs
    u = u_ref[...].astype(jnp.float32)[0]                 # (1,N) -> (N,)
    snap = ((lambda x: x) if carry is None else
            (lambda x: x.astype(jnp.dtype(carry)).astype(jnp.float32)))

    def body(t, S):
        # int ref indices break jax 0.4.x interpret-mode discharge; dslice
        sl = (pl.dslice(0, 1), pl.dslice(0, 1), pl.dslice(t, 1), slice(None))
        rt = pl.load(r_ref, sl).astype(jnp.float32)[0, 0, 0]   # (N,)
        kt = pl.load(k_ref, sl).astype(jnp.float32)[0, 0, 0]
        vt = pl.load(v_ref, sl).astype(jnp.float32)[0, 0, 0]
        wt = pl.load(w_ref, sl).astype(jnp.float32)[0, 0, 0]
        # exact wkv6_step: y = r @ (S + diag(u) k⊗v); S' = diag(w) S + k⊗v
        kv = kt[:, None] * vt[None, :]                         # (N,N)
        y = jnp.einsum("n,nm->m", rt, S + u[:, None] * kv)
        pl.store(y_ref, sl, y[None, None, None].astype(y_ref.dtype))
        S_new = wt[:, None] * S + kv
        if masked:
            ok = pl.load(valid_ref,
                         (pl.dslice(0, 1), pl.dslice(t, 1)))[0, 0] != 0
            S_new = jnp.where(ok, S_new, S)
        return snap(S_new)

    s_sl = (pl.dslice(0, 1), pl.dslice(0, 1), slice(None), slice(None))
    S = jax.lax.fori_loop(0, T, body,
                          pl.load(s0_ref, s_sl)[0, 0].astype(jnp.float32))
    pl.store(sf_ref, s_sl, S[None, None])


@functools.partial(jax.jit, static_argnames=("interpret", "carry_dtype"))
def wkv6_seq_pallas(r, k, v, w, u, s0=None, *,
                    valid=None, carry_dtype: str | None = None,
                    interpret: bool | None = None):
    """Sequential WKV-6: r,k,v,w (B,T,H,N); u (H,N) -> (y (B,T,H,N) f32,
    S (B,H,N,N)).  Grid (B, H); each cell's (N, N) state stays in VMEM for
    the whole window, advanced with the exact `wkv6_step` ops so the result
    is BIT-identical to scanning the step (the prefill contract).  `valid`
    (B, T) discards masked steps' state updates; `carry_dtype` rounds the
    carry through the pool dtype every step, both as in the per-op oracle."""
    B, T, H, N = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)
    # head-major layout so each grid cell reads a contiguous (T, N) strip
    tr = lambda x: jnp.transpose(x, (0, 2, 1, 3))         # (B,H,T,N)
    seq_spec = pl.BlockSpec((1, 1, T, N), lambda b, h: (b, h, 0, 0))
    u_spec = pl.BlockSpec((1, N), lambda b, h: (h, 0))
    st_spec = pl.BlockSpec((1, 1, N, N), lambda b, h: (b, h, 0, 0))
    operands = [tr(r), tr(k), tr(v), tr(w), u, s0]
    in_specs = [seq_spec, seq_spec, seq_spec, seq_spec, u_spec, st_spec]
    if valid is not None:
        operands.append(valid.astype(jnp.int32))
        in_specs.append(pl.BlockSpec((1, T), lambda b, h: (b, 0)))
    y, sf = pl.pallas_call(
        functools.partial(_seq_kernel, T=T, masked=valid is not None,
                          carry=carry_dtype),
        grid=(B, H),
        in_specs=in_specs,
        out_specs=[seq_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        interpret=interpret_default(interpret),
    )(*operands)
    return jnp.transpose(y, (0, 2, 1, 3)), sf
