"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function computes exactly what the corresponding kernel computes, with
plain jax.numpy — used by tests/test_kernels_*.py for allclose sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.approx.units import exp_lut, sigmoid_pwl
from repro.core.quant.delta_pot import dpot_unpack_int8, dpot_dequantize
from repro.core.wkv.wkv4 import wkv4_scan, wkv4_init_state, WKV4State
from repro.core.wkv.wkv6 import wkv6_scan


def dpot_matmul_ref(x, wq, scale, ks=(3, 4)):
    """x (M,K) @ decode(wq (K,N) int8-packed) * scale (N,)."""
    q = dpot_unpack_int8(wq, scale[None, :], ks)
    w = dpot_dequantize(q)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def wkv4_ref(k, v, w, u, a0=None, b0=None, o0=None):
    state = None
    if a0 is not None:
        state = WKV4State(a=a0, b=b0, o=o0)
    y, final = wkv4_scan(k, v, w, u, state)
    return y.astype(jnp.float32), (final.a, final.b, final.o)


def wkv6_ref(r, k, v, w, u, s0=None):
    y, s = wkv6_scan(r, k, v, w, u, s0)
    return y.astype(jnp.float32), s


def fused_layernorm_ref(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    ex2 = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    var = ex2 - mu * mu
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return y.astype(x.dtype)


exp_ref = exp_lut
sigmoid_ref = sigmoid_pwl


def flash_attention_ref(q, k, v, causal=True):
    """GQA-aware full-score attention (oracle for the flash kernel)."""
    import jax.numpy as _jnp
    from repro.models.layers import _plain_attention
    H, KVH = q.shape[2], k.shape[2]
    if H != KVH:
        k = _jnp.repeat(k, H // KVH, axis=2)
        v = _jnp.repeat(v, H // KVH, axis=2)
    return _plain_attention(q, k, v, causal, 0)


def fused_cross_entropy_ref(logits, labels):
    """Per-example NLL via plain log_softmax (oracle for fused_ce)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    return -jnp.take_along_axis(lp, labels[..., None].astype(jnp.int32),
                                -1)[..., 0]
