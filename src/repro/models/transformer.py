"""Generic decoder-only LM covering the dense / MoE / MLA / VLM assigned
architectures (smollm, phi3, minitron, minicpm3, moonshot, llama4,
internvl2-backbone).

Layers are stacked with a leading "layers" axis and executed with
jax.lax.scan (optionally remat'd) — this keeps the compiled HLO small and
compile time bounded even for the 400B config, and is what a production
framework does anyway.

MoE interleaving: with moe_every = g, layers are grouped into n_layers/g
"super-blocks" of (g-1) dense layers + 1 MoE layer, scanned over groups.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import P
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------


def _stack(spec, n: int):
    """Prepend a stacked-layer axis to every P in a spec tree."""
    return jax.tree_util.tree_map(
        lambda p: P((n, *p.shape), ("layers", *p.axes), init=p.init,
                    scale=p.scale, const=p.const),
        spec, is_leaf=lambda x: isinstance(x, P))


def _block_spec(cfg: ModelConfig, moe: bool) -> dict:
    attn = L.spec_mla(cfg) if cfg.use_mla else L.spec_attention(cfg)
    d = {
        "ln1": L.spec_norm(cfg.d_model, cfg.norm),
        "attn": attn,
        "ln2": L.spec_norm(cfg.d_model, cfg.norm),
    }
    d["mlp"] = L.spec_moe(cfg) if moe else L.spec_mlp(cfg)
    return d


def spec(cfg: ModelConfig) -> dict:
    g = cfg.moe_every if cfg.is_moe else 1
    if cfg.n_layers % g != 0:
        raise ValueError(f"n_layers={cfg.n_layers} % moe_every={g} != 0")
    n_groups = cfg.n_layers // g
    group = {}
    if cfg.is_moe:
        if g > 1:
            group["dense"] = _stack(_block_spec(cfg, moe=False), g - 1)
        group["moe"] = _block_spec(cfg, moe=True)
    else:
        group["dense"] = _stack(_block_spec(cfg, moe=False), 1)
    sp = {
        "embed": P((cfg.vocab, cfg.d_model), ("tp", "fsdp"), scale=0.02),
        "blocks": _stack(group, n_groups),
        "ln_f": L.spec_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        sp["head"] = P((cfg.d_model, cfg.vocab), ("fsdp", "tp"))
    if cfg.n_patches:
        sp["patch_proj"] = P((cfg.d_model, cfg.d_model), ("fsdp", "tp"))
        sp["patch_norm"] = L.spec_norm(cfg.d_model, cfg.norm)
    return sp


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _apply_block(p, x, cfg, moe: bool, *, positions=None,
                 kv_cache=None, cache_pos=None):
    attn_fn = L.apply_mla if cfg.use_mla else L.apply_attention
    h, new_cache = attn_fn(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                           cfg, positions=positions,
                           kv_cache=kv_cache, cache_pos=cache_pos)
    x = x + h
    y = L.apply_norm(p["ln2"], x, cfg.norm)
    if moe:
        m, aux = L.apply_moe(p["mlp"], y, cfg)
    else:
        m, aux = L.apply_mlp(p["mlp"], y, cfg), jnp.zeros((), jnp.float32)
    return x + m, aux, new_cache


def _embed(params, tokens, cfg, patches=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.dtype))
    if patches is not None:
        pe = patches.astype(x.dtype) @ params["patch_proj"]
        pe = L.apply_norm(params["patch_norm"], pe, cfg.norm)
        x = jnp.concatenate([pe, x], axis=1)
    return constrain(x, ("batch", None, None))


def forward(params, batch: dict, cfg: ModelConfig):
    """batch: {"tokens": (B,S) int32, optional "patches": (B,P,D)}.
    Returns (logits over the full (possibly patch-prefixed) sequence, aux)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg, batch.get("patches"))
    S = x.shape[1]
    positions = jnp.arange(S)
    g = cfg.moe_every if cfg.is_moe else 1

    def group_body(carry, gp):
        x, aux = carry
        if "dense" in gp:
            def dense_body(x, lp):
                xo, a, _ = _apply_block(lp, x, cfg, moe=False,
                                        positions=positions)
                return xo, a
            body = jax.checkpoint(dense_body) if cfg.remat else dense_body
            x, _ = jax.lax.scan(body, x, gp["dense"])
        if "moe" in gp:
            def moe_body(x):
                return _apply_block(gp["moe"], x, cfg, moe=True,
                                    positions=positions)[:2]
            if cfg.remat:
                moe_body = jax.checkpoint(moe_body)
            x, a = moe_body(x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ head.astype(x.dtype)
    return constrain(logits, ("batch", None, "tp")), aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    mk = L.init_mla_cache if cfg.use_mla else L.init_kv_cache
    one = mk(cfg, batch, max_len, dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)).copy(),
        one)


def decode_state_axes(cfg: ModelConfig):
    """Logical axes for the decode state (per-leaf tuples)."""
    seq = "seq" if cfg.shard_kv_seq else None
    if cfg.use_mla:
        return {"c_kv": ("layers", "batch", seq, None),
                "k_rope": ("layers", "batch", seq, None)}
    return {"k": ("layers", "batch", seq, "tp", None),
            "v": ("layers", "batch", seq, "tp", None)}


def decode_step(params, state, tokens, pos, cfg: ModelConfig):
    """One decode step. tokens: (B,1); pos: scalar int32 (current write
    index). Returns (logits (B,1,V), new_state)."""
    x = _embed(params, tokens, cfg)
    positions = pos + jnp.arange(1)
    g = cfg.moe_every if cfg.is_moe else 1
    n_groups = cfg.n_layers // g

    # reshape stacked cache (L, ...) -> (n_groups, g, ...) to scan by group
    def regroup(c):
        return c.reshape(n_groups, g, *c.shape[1:])
    cache = jax.tree_util.tree_map(regroup, state)

    def group_body(x, xs):
        gp, gcache = xs
        new_parts = []
        if "dense" in gp:
            n_dense = g - 1 if cfg.is_moe and g > 1 else 1
            def dense_body(x, xs2):
                lp, lc = xs2
                xo, _, nc = _apply_block(lp, x, cfg, moe=False,
                                         positions=positions,
                                         kv_cache=lc, cache_pos=pos)
                return xo, nc
            dcache = jax.tree_util.tree_map(lambda c: c[:n_dense], gcache)
            x, ncache = jax.lax.scan(dense_body, x, (gp["dense"], dcache))
            new_parts.append(ncache)
        if "moe" in gp:
            mcache = jax.tree_util.tree_map(lambda c: c[-1], gcache)
            x, _, nc = _apply_block(gp["moe"], x, cfg, moe=True,
                                    positions=positions,
                                    kv_cache=mcache, cache_pos=pos)
            new_parts.append(jax.tree_util.tree_map(
                lambda a: a[None], nc))
        merged = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, 0), *new_parts) \
            if len(new_parts) > 1 else new_parts[0]
        return x, merged

    x, new_cache = jax.lax.scan(group_body, x, (params["blocks"], cache))
    new_state = jax.tree_util.tree_map(
        lambda c: c.reshape(cfg.n_layers, *c.shape[2:]), new_cache)
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ head.astype(x.dtype)
    return logits, new_state
