"""Shared layer library: norms, RoPE, attention (GQA / MLA / cross),
MLPs, and capacity-based MoE.  Functional style — every layer is a
`spec_*(cfg) -> {name: P}` plus an `apply_*` taking the materialized params.

Activation convention: (batch, seq, d_model). All math in f32 unless the
input dtype is wider; outputs cast back to the input dtype.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.param import P
from repro.parallel.sharding import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def spec_norm(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": P((d,), (None,), init="ones")}
    return {"scale": P((d,), (None,), init="ones"),
            "bias": P((d,), (None,), init="zeros")}


def apply_norm(p: dict, x: jnp.ndarray, kind: str,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        # single-pass form the paper's LayerNorm module uses (Eq. 12):
        # sigma^2 = E[x^2] - mu^2
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        ex2 = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        var = ex2 - mu * mu
        y = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (S,) or (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core: memory-efficient (online-softmax over KV blocks)
# ---------------------------------------------------------------------------


def _plain_attention(q, k, v, causal: bool, q_offset) -> jnp.ndarray:
    """q: (B,Sq,H,hd) k,v: (B,Skv,H,hd) — full score matrix (small seqs)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Skv)
        s = jnp.where((kpos[None, :] <= qpos[:, None])[None, None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _flash_attention(q, k, v, causal: bool, q_offset,
                     kv_block: int = 1024) -> jnp.ndarray:
    """Online-softmax over KV blocks via lax.scan — O(Sq·block) live memory.

    This is the pure-JAX oracle of the fused-attention idea; q stays
    resident (the paper's "activations on-chip"), k/v stream block-wise
    (the paper's chunked double-buffered weight streaming, applied to KV).
    """
    B, Sq, H, hd = q.shape
    dv = v.shape[-1]            # MLA: value head dim may differ from qk dim
    Skv = k.shape[1]
    blk = min(kv_block, Skv)
    while Skv % blk != 0:  # shapes here are powers of two or small
        blk //= 2
    nblk = Skv // blk
    scale = 1.0 / math.sqrt(hd)
    q32 = q.astype(jnp.float32) * scale
    qpos = jnp.arange(Sq) + q_offset

    kb = jnp.moveaxis(k.reshape(B, nblk, blk, H, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, blk, H, dv), 1, 0)

    def body(carry, kv_blk):
        m, l, acc, start = carry
        kblk, vblk = kv_blk
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kblk.astype(jnp.float32))
        if causal:
            kpos = start + jnp.arange(blk)
            mask = kpos[None, :] <= qpos[:, None]
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", pexp, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new, start + blk), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, 0), (kb, vb))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(o, 1, 2).astype(q.dtype)  # (B,Sq,H,hd)


def _flash_kernel_sharded(q, k, v, causal: bool) -> jnp.ndarray:
    """Route through the Pallas fused kernel, per-device via shard_map.

    The kernel is a per-device program (batch/head-parallel grid); under a
    production mesh each device runs it on its local (batch, head) shard —
    exactly how a Pallas kernel executes on a real pod.  Without a mesh
    (CPU smoke tests) it runs directly."""
    from repro.kernels.flash_attention import flash_attention
    from repro.parallel.sharding import get_current_mesh, spec_for_axes
    H, KVH = q.shape[2], k.shape[2]
    if H != KVH:
        k = jnp.repeat(k, H // KVH, axis=2)
        v = jnp.repeat(v, H // KVH, axis=2)
    mesh = get_current_mesh()
    if mesh is None:
        return flash_attention(q, k, v, causal=causal)
    spec = spec_for_axes(("batch", None, "tp", None), q.shape, mesh)
    fn = jax.shard_map(
        lambda a, b, c: flash_attention(a, b, c, causal=causal),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False)   # pallas_call out_shapes carry no vma info
    return fn(q, k, v)


def attention(q, k, v, *, causal: bool = True, q_offset=0,
              flash_threshold: int = 2048,
              use_flash_kernel: bool = False) -> jnp.ndarray:
    """GQA-aware attention: k/v may have fewer heads (H % KVH == 0).

    use_flash_kernel routes full-sequence attention through the Pallas
    fused kernel (scores stay in VMEM — EXPERIMENTS.md §Perf); the XLA
    paths below are the baseline and the oracle."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    if use_flash_kernel == "stub":
        # dry-run instrumentation: same output shape, ~zero flops/bytes
        vm = jnp.mean(v, axis=(1, 2), keepdims=True)      # (B,1,1,dv)
        return jnp.broadcast_to(vm, (B, Sq, H, v.shape[-1])).astype(q.dtype)
    if (use_flash_kernel and q_offset == 0 and Sq == k.shape[1]
            and Sq >= 512):
        return _flash_kernel_sharded(q, k, v, causal)
    if H != KVH:
        rep = H // KVH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if k.shape[1] <= flash_threshold:
        return _plain_attention(q, k, v, causal, q_offset)
    return _flash_attention(q, k, v, causal, q_offset)


# ---------------------------------------------------------------------------
# GQA attention layer (with KV-cache decode)
# ---------------------------------------------------------------------------


def spec_attention(cfg) -> dict:
    d, H, KVH = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    return {
        "wq": P((d, H, hd), ("fsdp", "tp", None)),
        "wk": P((d, KVH, hd), ("fsdp", "tp", None)),
        "wv": P((d, KVH, hd), ("fsdp", "tp", None)),
        "wo": P((H, hd, d), ("tp", None, "fsdp")),
    }


def apply_attention(p, x, cfg, *, positions=None, causal=True,
                    kv_cache=None, cache_pos=None, memory=None):
    """x: (B,S,D).  Modes:
      * training/prefill: kv_cache None — full-sequence attention
      * decode: kv_cache {"k","v"} (B,Smax,KVH,hd), cache_pos scalar —
        writes this step's K/V at cache_pos, attends to the prefix
      * cross-attention: memory = (B,Sm,D) (k/v from memory; no cache here —
        enc-dec decode precomputes memory K/V via precompute_cross_kv)
    """
    B, S, D = x.shape
    kv_src = memory if memory is not None else x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    q = constrain(q, ("batch", None, "tp", None))
    if positions is None:
        positions = jnp.arange(S)
    if memory is None and getattr(cfg, "rope_theta", 0):
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        kc = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": kc, "v": vc}
        Smax = kc.shape[1]
        # attend to [0, cache_pos]; causal mask via q_offset
        o = attention(q, kc, vc, causal=True, q_offset=cache_pos)
    else:
        ufk = ("stub" if getattr(cfg, "attn_stub", False)
               else getattr(cfg, "use_flash_kernel", False))
        o = attention(q, k, v, causal=causal and memory is None, q_offset=0,
                      use_flash_kernel=ufk)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return constrain(out, ("batch", None, None)), new_cache


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    KVH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    z = lambda: jnp.zeros((batch, max_len, KVH, hd), dtype)
    return {"k": z(), "v": z()}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek style)
# ---------------------------------------------------------------------------


def spec_mla(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": P((d, qr), ("fsdp", None)),
        "q_norm": spec_norm(qr, "rmsnorm"),
        "wq_b": P((qr, H, dn + dr), (None, "tp", None)),
        "wkv_a": P((d, kvr + dr), ("fsdp", None)),
        "kv_norm": spec_norm(kvr, "rmsnorm"),
        "wkv_b": P((kvr, H, dn + dv), (None, "tp", None)),
        "wo": P((H, dv, d), ("tp", None, "fsdp")),
    }


def apply_mla(p, x, cfg, *, positions=None, kv_cache=None, cache_pos=None):
    """MLA with the compressed-latent cache: what is cached is the kv_lora
    latent + the shared rope key (kvr + dr per token), NOT full K/V — the
    memory win that makes MiniCPM3's long-context decode cheap."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    if positions is None:
        positions = jnp.arange(S)

    q_lat = apply_norm(p["q_norm"], x @ p["wq_a"], "rmsnorm")
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])      # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]                                   # (B,S,kvr+dr)
    c_kv = apply_norm(p["kv_norm"], kv_a[..., :kvr], "rmsnorm")
    k_rope = apply_rope(kv_a[..., kvr:][:, :, None, :], positions,
                        cfg.rope_theta)                     # (B,S,1,dr)

    new_cache = None
    if kv_cache is not None:
        cc = jax.lax.dynamic_update_slice(
            kv_cache["c_kv"], c_kv.astype(kv_cache["c_kv"].dtype),
            (0, cache_pos, 0))
        rc = jax.lax.dynamic_update_slice(
            kv_cache["k_rope"], k_rope[:, :, 0].astype(
                kv_cache["k_rope"].dtype), (0, cache_pos, 0))
        new_cache = {"c_kv": cc, "k_rope": rc}
        c_kv, k_rope = cc, rc[:, :, None, :]
        q_offset = cache_pos
    else:
        q_offset = 0

    # expand latents to per-head K (nope part) and V
    kv = jnp.einsum("bsr,rhk->bshk", c_kv.astype(x.dtype), p["wkv_b"])
    k_nope, vv = kv[..., :dn], kv[..., dn:]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope.astype(x.dtype),
                                  (*k_nope.shape[:-1], dr))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    o = attention(q_full, k_full, vv, causal=True, q_offset=q_offset)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return constrain(out, ("batch", None, None)), new_cache


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def spec_mlp(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "swiglu":
        return {"wi": P((d, f), ("fsdp", "tp")),
                "wg": P((d, f), ("fsdp", "tp")),
                "wo": P((f, d), ("tp", "fsdp"))}
    return {"wi": P((d, f), ("fsdp", "tp")),
            "wo": P((f, d), ("tp", "fsdp"))}


def apply_mlp(p, x, cfg):
    h = x @ p["wi"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"])
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.act == "relu_sq":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.act)
    h = constrain(h, ("batch", None, "tp"))
    return constrain(h @ p["wo"], ("batch", None, None))


# ---------------------------------------------------------------------------
# MoE with capacity-based dispatch (Switch/T5X style)
# ---------------------------------------------------------------------------


def spec_moe(cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": P((d, E), ("fsdp", None), scale=0.02),
        "wi": P((E, d, f), ("ep", "fsdp", None)),
        "wg": P((E, d, f), ("ep", "fsdp", None)),
        "wo": P((E, f, d), ("ep", None, "fsdp")),
    }


def apply_moe_grouped(p, x, cfg):
    """Grouped-dispatch MoE (EXPERIMENTS.md §Perf, beyond-paper opt):
    each sequence is a dispatch group, so the position-in-expert cumsum and
    the capacity scatter are LOCAL to the data shard (no all-gather of the
    one-hot, no partial-sum all-reduce of the global buffer).  The only
    cross-device movement is the (B,E,C,D) buffer resharding
    data->model and back — which SPMD lowers to all-to-alls — and the
    payloads stay bf16 (gates applied in low precision at combine)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(int(math.ceil(S * K * cfg.capacity_factor / E)), 1)

    logits = (x @ p["router"]).astype(jnp.float32)          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (B * S * K))
    aux = E * jnp.sum(me * ce)

    # position within expert, per group (cumsum over the LOCAL S*K axis)
    e_flat = idx.reshape(B, S * K)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)         # (B,S*K,E)
    pos = jnp.sum(jnp.cumsum(oh, axis=1) * oh, axis=-1) - 1
    keep = pos < C
    e_safe = jnp.where(keep, e_flat, 0)
    pos_safe = jnp.where(keep, pos, 0)

    tok = jnp.arange(S * K) // K
    src = jnp.where(keep[..., None], x[:, tok], 0)          # (B,S*K,D) bf16
    bidx = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E, C, D), x.dtype).at[
        bidx, e_safe, pos_safe].add(src)
    buf = constrain(buf, ("batch", None, None, None))       # local dispatch
    # 2-D parallel expert compute: experts over "model" x groups over
    # "data" — the (E,B,C,D) buffer is sliced along BOTH axes, weights are
    # ep-sharded, so the FFN einsums are fully local (no reshape that
    # would defeat SPMD's all-to-all pattern matching)
    ebuf = jnp.transpose(buf, (1, 0, 2, 3))                 # (E,B,C,D)
    ebuf = constrain(ebuf, ("ep", "batch", None, None))
    h = jnp.einsum("ebcd,edf->ebcf", ebuf, p["wi"])
    h = jax.nn.silu(h) * jnp.einsum("ebcd,edf->ebcf", ebuf, p["wg"])
    out_ebuf = jnp.einsum("ebcf,efd->ebcd", h, p["wo"])
    out_ebuf = constrain(out_ebuf, ("ep", "batch", None, None))
    out_buf = jnp.transpose(out_ebuf, (1, 0, 2, 3))
    out_buf = constrain(out_buf, ("batch", None, None, None))
    gathered = out_buf[bidx, e_safe, pos_safe]              # (B,S*K,D)
    gathered = jnp.where(keep[..., None], gathered, 0)
    out = jnp.sum(
        (gathered * gate_vals.reshape(B, S * K, 1).astype(x.dtype)
         ).reshape(B, S, K, D), axis=2)
    return out.astype(x.dtype), aux


def apply_moe(p, x, cfg):
    """Returns (out, aux_loss). Top-k routing, per-expert capacity buffers,
    dropped-token overflow — experts shard over "model" (EP) so the
    dispatch/combine reshards become all-to-alls under SPMD."""
    if getattr(cfg, "moe_grouped", False):
        return apply_moe_grouped(p, x, cfg)
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = max(int(math.ceil(T * K * cfg.capacity_factor / E)), 1)
    xf = x.reshape(T, D)

    logits = (xf @ p["router"]).astype(jnp.float32)         # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                # (T,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    # position of each (token, choice) within its expert
    e_flat = idx.reshape(-1)                                # (T*K,)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1  # (T*K,)
    keep = pos < C
    e_safe = jnp.where(keep, e_flat, 0)
    pos_safe = jnp.where(keep, pos, 0)

    tok = jnp.arange(T * K) // K
    src = jnp.where(keep[:, None], xf[tok], 0).astype(x.dtype)
    buf = jnp.zeros((E, C, D), x.dtype).at[e_safe, pos_safe].add(src)
    buf = constrain(buf, ("ep", None, None))

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_buf = constrain(out_buf, ("ep", None, None))

    gathered = out_buf[e_safe, pos_safe]                    # (T*K,D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    # combine in the activation dtype: f32 gates would upcast every token
    # payload crossing the EP reshard collectives (§Perf: 2x wire bytes)
    gates = gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.sum((gathered * gates).reshape(T, K, D), axis=1)
    return out.reshape(B, S, D).astype(x.dtype), aux
