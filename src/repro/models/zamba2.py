"""Zamba2 (arXiv:2411.15242) — Mamba-2 backbone + ONE shared attention block.

The Zamba trick: a single transformer block (attention + MLP at width
2*d_model) is *weight-shared* across all its invocations; every
`shared_attn_every` mamba layers it runs on concat(hidden, embedding) and is
projected back to d_model by a per-invocation (unshared) linear.

Layout for n_layers = G*g + r (g = shared_attn_every):
  G groups of [g mamba layers  ->  shared block (with per-group down-proj)]
  followed by r trailing mamba layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.param import P
from repro.parallel.sharding import constrain


def _stack(spec, n: int):
    return jax.tree_util.tree_map(
        lambda p: P((n, *p.shape), ("layers", *p.axes), init=p.init,
                    scale=p.scale, const=p.const),
        spec, is_leaf=lambda x: isinstance(x, P))


def _mamba_layer_spec(cfg) -> dict:
    return {"ln": L.spec_norm(cfg.d_model, cfg.norm),
            "mixer": M.spec_mamba2(cfg)}


def _wide_cfg(cfg: ModelConfig) -> ModelConfig:
    """The shared block runs at width 2*d_model (concat trick)."""
    import dataclasses
    return dataclasses.replace(
        cfg, d_model=2 * cfg.d_model, d_ff=2 * cfg.d_ff,
        head_dim=2 * cfg.d_model // cfg.n_heads)


def _shared_block_spec(cfg: ModelConfig) -> dict:
    wide = _wide_cfg(cfg)
    return {
        "ln1": L.spec_norm(wide.d_model, cfg.norm),
        "attn": L.spec_attention(wide),
        "ln2": L.spec_norm(wide.d_model, cfg.norm),
        "mlp": L.spec_mlp(wide),
    }


def _layout(cfg: ModelConfig):
    g = cfg.shared_attn_every
    G, r = divmod(cfg.n_layers, g)
    return g, G, r


def spec(cfg: ModelConfig) -> dict:
    g, G, r = _layout(cfg)
    d = cfg.d_model
    sp = {
        "embed": P((cfg.vocab, d), ("tp", "fsdp"), scale=0.02),
        "groups": {
            "mamba": _stack(_stack(_mamba_layer_spec(cfg), g), G),
            "down_proj": P((G, 2 * d, d), ("layers", "fsdp", "tp")),
        },
        "shared": _shared_block_spec(cfg),       # weight-shared, not stacked
        "ln_f": L.spec_norm(d, cfg.norm),
        "head": P((d, cfg.vocab), ("fsdp", "tp")),
    }
    if r:
        sp["tail"] = _stack(_mamba_layer_spec(cfg), r)
    return sp


def _apply_shared(shared, down_proj, x, x0, cfg, *, kv_cache=None,
                  cache_pos=None, positions=None):
    """x, x0: (B,S,D) hidden + original embedding; runs the wide block."""
    wide = _wide_cfg(cfg)
    cat = jnp.concatenate([x, x0], axis=-1)
    h = L.apply_norm(shared["ln1"], cat, cfg.norm)
    att, new_cache = L.apply_attention(
        shared["attn"], h, wide, positions=positions,
        kv_cache=kv_cache, cache_pos=cache_pos)
    cat = cat + att
    h = L.apply_norm(shared["ln2"], cat, cfg.norm)
    cat = cat + L.apply_mlp(shared["mlp"], h, wide)
    return x + cat @ down_proj, new_cache


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def forward(params, batch: dict, cfg: ModelConfig):
    tokens = batch["tokens"]
    g, G, r = _layout(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", None, None))
    x0 = x
    S = x.shape[1]
    positions = jnp.arange(S)
    shared = params["shared"]

    def mamba_body(x, lp):
        h = L.apply_norm(lp["ln"], x, cfg.norm)
        return x + M.apply_mamba2_seq(lp["mixer"], h, cfg), None

    mb = jax.checkpoint(mamba_body) if cfg.remat else mamba_body

    def group_body(x, gp):
        x, _ = jax.lax.scan(mb, x, gp["mamba"])
        x, _ = _apply_shared(shared, gp["down_proj"], x, x0, cfg,
                             positions=positions)
        return x, None

    gb = jax.checkpoint(group_body) if cfg.remat else group_body
    x, _ = jax.lax.scan(gb, x, params["groups"])
    if r:
        x, _ = jax.lax.scan(mb, x, params["tail"])
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = x @ params["head"].astype(x.dtype)
    return constrain(logits, ("batch", None, "tp")), jnp.zeros(
        (), jnp.float32)


# ---------------------------------------------------------------------------
# Decode — mamba states are O(1); the shared block keeps a KV cache
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    g, G, r = _layout(cfg)
    one = M.init_mamba2_state(cfg, batch, jnp.float32)
    stackn = lambda st, n: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(), st)
    wide = _wide_cfg(cfg)
    kv = L.init_kv_cache(wide, batch, max_len, dtype)
    return {
        "mamba": stackn(stackn(one, g), G),
        "tail": stackn(one, max(r, 1)),
        "kv": jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (G, *a.shape)).copy(), kv),
    }


def decode_state_axes(cfg: ModelConfig):
    m = {k: ("layers", "layers") + v for k, v in M.mamba2_state_axes().items()}
    return {
        "mamba": m,
        "tail": {k: ("layers",) + v
                 for k, v in M.mamba2_state_axes().items()},
        "kv": {"k": ("layers", "batch", "seq", "tp", None),
               "v": ("layers", "batch", "seq", "tp", None)},
    }


def decode_step(params, state, tokens, pos, cfg: ModelConfig):
    g, G, r = _layout(cfg)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(
        jnp.dtype(cfg.dtype))
    x0 = x
    shared = params["shared"]
    positions = pos + jnp.arange(1)

    def mamba_body(x, xs):
        lp, st = xs
        h = L.apply_norm(lp["ln"], x, cfg.norm)
        y, new_st = M.apply_mamba2_step(lp["mixer"], h, st, cfg)
        return x + y, new_st

    def group_body(x, xs):
        gp, gst, kv = xs
        x, new_mamba = jax.lax.scan(mamba_body, x, (gp["mamba"], gst))
        x, new_kv = _apply_shared(
            shared, gp["down_proj"], x[:, None], x0[:, None], cfg,
            kv_cache=kv, cache_pos=pos, positions=positions)
        return x[:, 0], (new_mamba, new_kv)

    x, (new_mamba, new_kv) = jax.lax.scan(
        group_body, x, (params["groups"], state["mamba"], state["kv"]))
    new_tail = state["tail"]
    if r:
        x, new_tail = jax.lax.scan(mamba_body, x,
                                   (params["tail"], state["tail"]))
    x = L.apply_norm(params["ln_f"], x[:, None], cfg.norm)
    logits = x @ params["head"].astype(x.dtype)
    return logits, {"mamba": new_mamba, "tail": new_tail, "kv": new_kv}
