"""Whisper-medium style encoder-decoder (arXiv:2212.04356).

The audio (conv+mel) frontend is a STUB per the assignment: the input is
precomputed frame embeddings `frames: (B, enc_frames, d_model)`.

Encoder: sinusoid positions + enc_layers x (non-causal self-attn + MLP) + LN.
Decoder: learned positions + n_layers x (causal self-attn + cross-attn + MLP)
+ LN; head tied to the token embedding (Whisper ties).

Decode state = per-layer self-attn KV cache + per-layer *precomputed* cross
K/V over the fixed 1500-frame encoder memory (computed once at prefill by
`precompute_cross_kv`; the dry-run's serve_step takes them as inputs).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import P
from repro.parallel.sharding import constrain


def _stack(spec, n: int):
    return jax.tree_util.tree_map(
        lambda p: P((n, *p.shape), ("layers", *p.axes), init=p.init,
                    scale=p.scale, const=p.const),
        spec, is_leaf=lambda x: isinstance(x, P))


def _enc_block_spec(cfg) -> dict:
    return {"ln1": L.spec_norm(cfg.d_model, cfg.norm),
            "attn": L.spec_attention(cfg),
            "ln2": L.spec_norm(cfg.d_model, cfg.norm),
            "mlp": L.spec_mlp(cfg)}


def _dec_block_spec(cfg) -> dict:
    return {"ln1": L.spec_norm(cfg.d_model, cfg.norm),
            "self_attn": L.spec_attention(cfg),
            "ln_x": L.spec_norm(cfg.d_model, cfg.norm),
            "cross_attn": L.spec_attention(cfg),
            "ln2": L.spec_norm(cfg.d_model, cfg.norm),
            "mlp": L.spec_mlp(cfg)}


def spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "embed": P((cfg.vocab, d), ("tp", "fsdp"), scale=0.02),
        "pos_emb": P((32_768, d), (None, "fsdp"), scale=0.02),  # decoder ctx
        "enc_blocks": _stack(_enc_block_spec(cfg), cfg.enc_layers),
        "enc_ln": L.spec_norm(d, cfg.norm),
        "dec_blocks": _stack(_dec_block_spec(cfg), cfg.n_layers),
        "dec_ln": L.spec_norm(d, cfg.norm),
    }


def _sinusoids(length: int, channels: int) -> np.ndarray:
    lds = np.log(10_000) / (channels // 2 - 1)
    inv = np.exp(-lds * np.arange(channels // 2))
    ang = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1)


def encode(params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, F, D) stub frontend output -> encoder memory (B, F, D)."""
    F = frames.shape[1]
    pos = jnp.asarray(_sinusoids(F, cfg.d_model), frames.dtype)
    x = constrain(frames + pos, ("batch", None, None))

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        att, _ = L.apply_attention(lp["attn"], h, cfg, causal=False)
        x = x + att
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        return x + L.apply_mlp(lp["mlp"], h, cfg), None

    blk = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(blk, x, params["enc_blocks"])
    return L.apply_norm(params["enc_ln"], x, cfg.norm)


def _dec_block(lp, x, cfg, memory, *, positions=None, kv_cache=None,
               cache_pos=None, cross_kv=None):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    att, new_cache = L.apply_attention(
        lp["self_attn"], h, cfg, positions=positions,
        kv_cache=kv_cache, cache_pos=cache_pos)
    x = x + att
    h = L.apply_norm(lp["ln_x"], x, cfg.norm)
    if cross_kv is not None:   # decode: use precomputed memory K/V
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
        o = L.attention(q, cross_kv["k"].astype(q.dtype),
                        cross_kv["v"].astype(q.dtype), causal=False)
        catt = jnp.einsum("bshk,hkd->bsd", o, lp["cross_attn"]["wo"])
    else:
        catt, _ = L.apply_attention(lp["cross_attn"], h, cfg, memory=memory)
    x = x + catt
    h = L.apply_norm(lp["ln2"], x, cfg.norm)
    return x + L.apply_mlp(lp["mlp"], h, cfg), new_cache


def forward(params, batch: dict, cfg: ModelConfig):
    """batch: {"tokens": (B,S), "frames": (B,F,D)} -> (logits, aux)."""
    tokens = batch["tokens"]
    memory = encode(params, batch["frames"].astype(jnp.dtype(cfg.dtype)),
                    cfg)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = x + params["pos_emb"][:S].astype(x.dtype)
    x = constrain(x, ("batch", None, None))
    positions = jnp.arange(S)

    def body(x, lp):
        y, _ = _dec_block(lp, x, cfg, memory, positions=positions)
        return y, None

    blk = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(blk, x, params["dec_blocks"])
    x = L.apply_norm(params["dec_ln"], x, cfg.norm)
    logits = x @ params["embed"].T.astype(x.dtype)
    return constrain(logits, ("batch", None, "tp")), jnp.zeros(
        (), jnp.float32)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def precompute_cross_kv(params, memory: jnp.ndarray, cfg: ModelConfig):
    """memory (B,F,D) -> stacked per-layer cross K/V (L,B,F,KVH,hd)."""
    def per_layer(lp):
        k = jnp.einsum("bsd,dhk->bshk", memory, lp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, lp["cross_attn"]["wv"])
        return {"k": k, "v": v}
    return jax.vmap(per_layer)(params["dec_blocks"])


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    kv = L.init_kv_cache(cfg, batch, max_len, dtype)
    stack = lambda a: jnp.broadcast_to(
        a[None], (cfg.n_layers, *a.shape)).copy()
    KVH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cross = jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, KVH, hd), dtype)
    return {"k": stack(kv["k"]), "v": stack(kv["v"]),
            "cross_k": cross, "cross_v": cross}


def decode_state_axes(cfg: ModelConfig):
    ax = ("layers", "batch", "seq", "tp", None)
    return {k: ax for k in ("k", "v", "cross_k", "cross_v")}


def decode_step(params, state, tokens, pos, cfg: ModelConfig):
    """tokens (B,1); state carries self-KV cache + precomputed cross-KV."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_emb"], pos, 1, 0).astype(x.dtype)
    positions = pos + jnp.arange(1)

    def body(x, xs):
        lp, st = xs
        y, new_cache = _dec_block(
            lp, x, cfg, None, positions=positions,
            kv_cache={"k": st["k"], "v": st["v"]}, cache_pos=pos,
            cross_kv={"k": st["cross_k"], "v": st["cross_v"]})
        return y, {"k": new_cache["k"], "v": new_cache["v"],
                   "cross_k": st["cross_k"], "cross_v": st["cross_v"]}

    x, new_state = jax.lax.scan(body, x, (params["dec_blocks"], state))
    x = L.apply_norm(params["dec_ln"], x, cfg.norm)
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, new_state
