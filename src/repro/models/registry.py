"""Model registry: arch family -> module implementing the uniform model API.

Every model module exposes:
  spec(cfg)                         -> P tree
  forward(params, batch, cfg)       -> (logits, aux_loss)
  init_decode_state(cfg, B, maxlen) -> state tree            (decoders only)
  decode_state_axes(cfg)            -> logical-axes tree
  decode_step(params, state, tokens, pos, cfg) -> (logits, new_state)

`get_model(cfg)` dispatches on the config family / rwkv_version and returns
a Model handle bundling those functions with the config.
"""
from __future__ import annotations

import dataclasses
from types import ModuleType
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config, smoke_config
from repro.models import param as PM


def _is_axes_tuple(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)


@dataclasses.dataclass(frozen=True)
class PathDescriptor:
    """Declarative description of ONE executable serving path.

    The registry used to expose a matrix of boolean capability flags
    (`has_decode` / `has_fused_decode` / `has_fused_model_decode` /
    `has_fused_prefill`) that the serving engine cross-referenced with
    three separately-wired `prepare_*` transforms.  A PathDescriptor is
    that row of the matrix as data: which module attribute implements the
    step, which (if any) prepares its params, and whether packed Δ-PoT
    leaves decode in-kernel (`fused=True`: codes pass through the trace
    whole) or must be unpacked in-trace by the caller (`fused=False`, the
    per-op oracle).  `repro.serving.plan.build_plan` selects one decode
    and one prefill descriptor and builds programs from them; the old
    `has_*` properties survive as thin views over the descriptor tables.

    name    — plan key ("per_op" | "block" | "model" | "chunked")
    kind    — "decode" | "prefill"
    entry   — module attribute implementing the step
    prepare — module attribute for one-time host-side param prep (None:
              params pass through)
    fused   — packed leaves decode inside the kernels (no in-trace unpack)
    """
    name: str
    kind: str
    entry: str
    prepare: Optional[str] = None
    fused: bool = False


DECODE_PATHS = (
    PathDescriptor("per_op", "decode", "decode_step"),
    PathDescriptor("block", "decode", "decode_step_fused", fused=True),
    PathDescriptor("model", "decode", "decode_step_fused_model",
                   prepare="prepare_fused_model_params", fused=True),
)

PREFILL_PATHS = (
    # the per-op prefill is a scan of decode_step; the plan builds the scan
    PathDescriptor("per_op", "prefill", "decode_step"),
    PathDescriptor("chunked", "prefill", "prefill_chunk",
                   prepare="prepare_prefill_params", fused=True),
)


@dataclasses.dataclass(frozen=True)
class DraftDescriptor:
    """Declarative drafter contract for self-speculative decoding.

    A drafter is a CHEAP proposal model whose guesses a bit-exact verifier
    (the chunked-prefill path scoring the whole draft window in one call)
    either confirms or corrects — so the drafter's quality only moves the
    ACCEPTANCE RATE, never the output (repro.serving.plan.SpeculativePath).
    The "truncated" drafter is the first `depth` layers of the SAME model:
    because layer l's state transition depends only on layers below it, a
    truncated stack's recurrent state is exactly the full model's first
    `depth` state slices — the draft state is a (static) slice of the live
    pool state, never a second pool (`Model.truncate_state`).

    name    — plan key ("truncated")
    entry   — module attribute the draft loop chains per proposed token
              (the per-op `decode_step`, run on a depth-`n_layers` config)
    depth   — default layers kept when the plan does not pick one
              (None: half the stack, at least one layer)
    """
    name: str
    entry: str = "decode_step"
    depth: Optional[int] = None


DRAFT_PATHS = (
    DraftDescriptor("truncated"),
)


def _module_for(cfg: ModelConfig) -> ModuleType:
    if cfg.rwkv_version == 4:
        from repro.models import rwkv4
        return rwkv4
    if cfg.rwkv_version == 6:
        from repro.models import rwkv6
        return rwkv6
    if cfg.family == "hybrid":
        from repro.models import zamba2
        return zamba2
    if cfg.family == "audio":
        from repro.models import encdec
        return encdec
    from repro.models import transformer
    return transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    module: ModuleType

    # -- parameters --------------------------------------------------------
    def spec(self):
        return self.module.spec(self.cfg)

    def init_params(self, rng, dtype=jnp.float32):
        return PM.init_params(self.spec(), rng, dtype)

    def abstract_params(self, dtype=jnp.float32):
        return PM.abstract_params(self.spec(), dtype)

    def param_axes(self):
        return PM.logical_axes(self.spec())

    def param_count(self) -> int:
        return PM.param_count(self.spec())

    # -- compute -----------------------------------------------------------
    def forward(self, params, batch):
        return self.module.forward(self.cast_params(params), batch, self.cfg)

    def cast_params(self, params):
        """f32 master params -> compute dtype (standard mixed precision;
        grads flow back to the f32 masters through the cast). Leaves that
        must stay f32 are re-cast inside the model where it matters."""
        dt = jnp.dtype(self.cfg.dtype)

        def cast(a):
            if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
                return a.astype(dt)
            return a
        return jax.tree_util.tree_map(cast, params)

    # -- serving paths (plan descriptors) ----------------------------------
    def decode_paths(self) -> dict[str, PathDescriptor]:
        """The decode paths this model can execute, keyed by plan name —
        the declarative replacement for the has_* capability flags.  A
        path is present iff the module ships its entry point."""
        return {d.name: d for d in DECODE_PATHS
                if hasattr(self.module, d.entry)}

    def prefill_paths(self) -> dict[str, PathDescriptor]:
        """The prefill paths this model can execute, keyed by plan name.
        "per_op" (a scan of decode_step, built by the plan) is present for
        any decoder; "chunked" needs the fused `prefill_chunk` entry."""
        return {d.name: d for d in PREFILL_PATHS
                if hasattr(self.module, d.entry)}

    def draft_paths(self) -> dict[str, DraftDescriptor]:
        """The self-speculative drafters this model can run, keyed by plan
        name.  The "truncated" drafter needs (1) the per-op decode step on
        a position-free recurrent state, (2) a stacked `blocks` param tree
        whose leaves carry the layer axis first (so the first-`depth`
        slice IS the truncated model's weights), and (3) a `layers`-named
        axis in every decode-state leaf (so the draft state is a slice of
        the live pool state)."""
        if not (hasattr(self.module, "decode_step")
                and self.position_free_decode):
            return {}
        try:
            self.decode_state_layer_axes()
        except (ValueError, AttributeError):
            return {}
        if "blocks" not in self.spec():
            return {}
        return {d.name: d for d in DRAFT_PATHS}

    def truncated(self, depth: int) -> "Model":
        """The first-`depth`-layers model as a registry handle: same module,
        config with `n_layers=depth`.  Combined with `truncate_params` /
        `truncate_state` this IS the truncated-stack drafter — its
        decode_step runs the same per-op math over the shallow stack."""
        if not 1 <= depth <= self.cfg.n_layers:
            raise ValueError(
                f"draft depth {depth} outside [1, {self.cfg.n_layers}] "
                f"for {self.cfg.name}")
        return Model(cfg=dataclasses.replace(self.cfg, n_layers=depth),
                     module=self.module)

    def truncate_params(self, params, depth: int):
        """Truncated-stack drafter weights: the first `depth` layers of the
        stacked block tree; embedding, outer norms and head are SHARED with
        the full model (aliased leaves, no copy).  Works on packed Δ-PoT
        trees too — code and scale planes both carry the layer axis
        first."""
        blocks = jax.tree_util.tree_map(lambda leaf: leaf[:depth],
                                        params["blocks"])
        return {**params, "blocks": blocks}

    def decode_state_layer_axes(self) -> list[int]:
        """Position of the layer axis in every decode-state leaf, aligned
        with tree_leaves(state) — the truncation analog of
        `decode_state_batch_axes`."""
        axes = self.decode_state_axes()
        flat, _ = jax.tree_util.tree_flatten(axes, is_leaf=_is_axes_tuple)
        return [ax.index("layers") for ax in flat]

    def truncate_state(self, state, depth: int):
        """The first `depth` layer slices of a decode-state tree — exactly
        the truncated model's state, because layer l's transition depends
        only on layers below it.  Static slice; traceable (the plan's draft
        program slices the live pool state in-trace every tick)."""
        axes = self.decode_state_layer_axes()
        leaves = jax.tree_util.tree_leaves(state)
        tdef = jax.tree_util.tree_structure(state)
        out = [jax.lax.slice_in_dim(leaf, 0, depth, axis=ax)
               for leaf, ax in zip(leaves, axes)]
        return jax.tree_util.tree_unflatten(tdef, out)

    def prepare_path_params(self, desc: PathDescriptor, params, **kw):
        """One-time host-side param prep for one path, dispatched through
        its descriptor: runs the module's `desc.prepare` (identity when the
        descriptor or the module has none).  `kw` forwards model extras
        (rwkv4 megakernel: `hw=True` attaches the LUT operands)."""
        prep = getattr(self.module, desc.prepare, None) if desc.prepare \
            else None
        return params if prep is None else prep(params, self.cfg, **kw)

    @property
    def has_decode(self) -> bool:
        return "per_op" in self.decode_paths()

    def init_decode_state(self, batch: int, max_len: int,
                          dtype=jnp.bfloat16):
        return self.module.init_decode_state(self.cfg, batch, max_len, dtype)

    def decode_state_axes(self):
        return self.module.decode_state_axes(self.cfg)

    def decode_step(self, params, state, tokens, pos):
        return self.module.decode_step(self.cast_params(params), state,
                                       tokens, pos, self.cfg)

    @property
    def has_fused_decode(self) -> bool:
        """True when the model ships a single-launch Pallas decode step
        (`decode_step_fused`) alongside the per-op oracle."""
        return "block" in self.decode_paths()

    def decode_step_fused(self, params, state, tokens, pos):
        """Fused-kernel decode (kernels.fused_decode): one Pallas launch
        per block.  Params pass through UNcast — the model applies the
        packed-aware compute cast itself (core.quant.serving.cast_compute)
        so Δ-PoT `{"packed","scale"}` leaves reach the kernel intact."""
        return self.module.decode_step_fused(params, state, tokens, pos,
                                             self.cfg)

    @property
    def has_fused_model_decode(self) -> bool:
        """True when the model ships the whole-model megakernel
        (`decode_step_fused_model`): ONE Pallas launch per decode step,
        grid over layers, residual carried in VMEM scratch."""
        return "model" in self.decode_paths()

    def decode_step_fused_model(self, params, state, tokens, pos):
        """Megakernel decode (kernels.fused_decode.fused_model_decode):
        the entire layer stack in one launch.  Params pass through UNcast,
        as in `decode_step_fused` — or pre-prepared via
        `prepare_fused_model_params` (the serving hot path)."""
        return self.module.decode_step_fused_model(params, state, tokens,
                                                   pos, self.cfg)

    def prepare_fused_model_params(self, params, **kw):
        """One-time host-side prep for the megakernel: compute-dtype cast +
        per-layer weight chunking (core.quant.serving.fuse_layer_stack).
        Run OUTSIDE the step; the result feeds decode_step_fused_model
        without per-token repacking.  `kw` forwards model extras (rwkv4:
        `hw=True` attaches the LUT operands — the decode's `hw` flag must
        match the prepared form)."""
        return self.prepare_path_params(self.decode_paths()["model"],
                                        params, **kw)

    @property
    def has_fused_prefill(self) -> bool:
        """True when the model ships the fused chunked-prefill entry
        (`prefill_chunk`): a whole prompt chunk per device program —
        chunk-shaped matmuls + the masked on-chip WKV sequence kernel —
        bit-identical to scanning `decode_step` over the chunk."""
        return "chunked" in self.prefill_paths()

    def prefill_chunk(self, params, state, tokens, valid):
        """Fused chunked prefill (kernels.fused_prefill): tokens (B, C)
        with a per-slot PREFIX validity mask -> (new_state, last-valid
        logits).  Params pass through UNcast, as in `decode_step_fused` —
        the model applies the packed-aware compute cast itself so Δ-PoT
        `{"packed","scale"}` leaves reach the matmul kernels intact."""
        return self.module.prefill_chunk(params, state, tokens, valid,
                                         jnp.int32(0), self.cfg)

    def prefill_chunk_logits(self, params, state, tokens, valid):
        """All-position variant of `prefill_chunk` for the speculative
        VERIFIER: tokens (B, K) with a prefix validity mask -> (new_state,
        logits (B, K, V)) where row k scores token k+1 — the same program
        the plain decode path would run on each position, so greedy
        acceptance against it is lossless by construction.  Invalid
        positions return zero logits and leave state untouched."""
        return self.module.prefill_chunk(params, state, tokens, valid,
                                         jnp.int32(0), self.cfg,
                                         all_logits=True)

    def prepare_prefill_params(self, params):
        """One-time host-side prep for the fused prefill: pre-decode any
        packed leaves the chunk datapath consumes element-wise (rwkv6's
        time_maa / maa_w2 / time_faaaa; rwkv4 needs nothing).  Run OUTSIDE
        the step, like `prepare_fused_model_params`."""
        desc = self.prefill_paths().get("chunked")
        return params if desc is None else \
            self.prepare_path_params(desc, params)

    # -- per-slot decode-state contract (serving engine) -------------------
    @property
    def position_free_decode(self) -> bool:
        """True when decode_step ignores `pos` (pure recurrent state, no
        KV write index) — the property the slotted serving pool relies on
        to run many requests at unrelated sequence offsets in one step."""
        return bool(getattr(self.module, "DECODE_POS_FREE", False))

    def init_slot_state(self, n_slots: int = 1, max_len: int = 0,
                        dtype=jnp.bfloat16):
        """Decode state sized for a slot pool: the batch axis is the slot
        axis (one independent sequence per slot)."""
        return self.module.init_decode_state(self.cfg, n_slots, max_len,
                                             dtype)

    def decode_state_batch_axes(self) -> list[int]:
        """Position of the batch (slot) axis in every decode-state leaf,
        as a flat list aligned with jax.tree_util.tree_leaves(state).
        Derived from decode_state_axes(), so any model that names its
        state axes gets slot addressing for free."""
        axes = self.decode_state_axes()
        flat, _ = jax.tree_util.tree_flatten(axes, is_leaf=_is_axes_tuple)
        return [ax.index("batch") for ax in flat]


def get_model(cfg_or_id: ModelConfig | str, *, smoke: bool = False) -> Model:
    if isinstance(cfg_or_id, str):
        cfg = smoke_config(cfg_or_id) if smoke else get_config(cfg_or_id)
    else:
        cfg = cfg_or_id
    return Model(cfg=cfg, module=_module_for(cfg))


# ---------------------------------------------------------------------------
# Loss / step builders shared by the launcher, examples and dry-run
# ---------------------------------------------------------------------------


def loss_fn(model: Model, params, batch):
    """Causal-LM cross-entropy (mean over non-masked tokens) + MoE aux."""
    logits, aux = model.forward(params, batch)
    labels = batch["labels"]
    # VLM: logits cover [patches + tokens]; labels align to the text tail
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}
