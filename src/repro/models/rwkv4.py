"""RWKV-4 — the paper's model (BlinkDL RWKV-4, faithful block structure).

Block = TimeMix (token-shift → r/k/v projections → WKV recurrence →
sigmoid(r)-gated output) + ChannelMix (token-shift → squared-ReLU FFN with
sigmoid(r) gate), each preceded by LayerNorm, plus the pre-block ln0.

Two numerics modes:
  * standard  — f32/bf16 math (training + FP baseline)
  * hw        — the accelerator's numerics (paper §3–4): Δ-PoT-dequantized
    weights are supplied by the caller; activations fake-quantized to 9-bit;
    exp/sigmoid/division via the LUT/PWL units (repro.core.approx).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx import exp_lut, sigmoid_pwl, div_lut
from repro.core.quant.uniform import uniform_fake_quant
from repro.core.wkv.wkv4 import wkv4_scan, wkv4_step, WKV4State
from repro.models import layers as L
from repro.models.param import P
from repro.parallel.sharding import constrain


def _stack(spec, n: int):
    return jax.tree_util.tree_map(
        lambda p: P((n, *p.shape), ("layers", *p.axes), init=p.init,
                    scale=p.scale, const=p.const),
        spec, is_leaf=lambda x: isinstance(x, P))


def _block_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln1": L.spec_norm(d, "layernorm"),
        "ln2": L.spec_norm(d, "layernorm"),
        "att": {
            "time_mix_r": P((d,), (None,), init="uniform", scale=0.5),
            "time_mix_k": P((d,), (None,), init="uniform", scale=0.5),
            "time_mix_v": P((d,), (None,), init="uniform", scale=0.5),
            "time_decay": P((d,), (None,), init="zeros"),   # w = exp(·)
            "time_first": P((d,), (None,), init="zeros"),   # bonus u
            "wr": P((d, d), ("fsdp", "tp")),
            "wk": P((d, d), ("fsdp", "tp")),
            "wv": P((d, d), ("fsdp", "tp")),
            "wo": P((d, d), ("tp", "fsdp")),
        },
        "ffn": {
            "time_mix_r": P((d,), (None,), init="uniform", scale=0.5),
            "time_mix_k": P((d,), (None,), init="uniform", scale=0.5),
            "wr": P((d, d), ("fsdp", "tp")),
            "wk": P((d, f), ("fsdp", "tp")),
            "wv": P((f, d), ("tp", "fsdp")),
        },
    }


def spec(cfg: ModelConfig) -> dict:
    return {
        "embed": P((cfg.vocab, cfg.d_model), ("tp", "fsdp"), scale=0.02),
        "ln0": L.spec_norm(cfg.d_model, "layernorm"),
        "blocks": _stack(_block_spec(cfg), cfg.n_layers),
        "ln_f": L.spec_norm(cfg.d_model, "layernorm"),
        "head": P((cfg.d_model, cfg.vocab), ("fsdp", "tp")),
    }


# ---------------------------------------------------------------------------
# Numerics contexts
# ---------------------------------------------------------------------------


class _Std:
    exp = staticmethod(jnp.exp)
    sigmoid = staticmethod(jax.nn.sigmoid)
    div = staticmethod(lambda a, b: a / b)
    act_q = staticmethod(lambda x: x)


class _Hw:
    """Paper numerics: LUT exp, PWL sigmoid, LUT division, A9 activations."""
    exp = staticmethod(exp_lut)
    sigmoid = staticmethod(sigmoid_pwl)
    div = staticmethod(div_lut)
    act_q = staticmethod(lambda x: uniform_fake_quant(x, 9, None))


def _numerics(hw: bool):
    return _Hw if hw else _Std


def _hw_numerics_with_tables(exp_table, div_table):
    """_Hw with the LUTs bound as explicit arrays: the fused Pallas kernel
    cannot capture array constants, so the tables travel as kernel operands
    (VMEM-resident, like the paper's on-chip LUTs)."""
    class _HwTabled:
        exp = staticmethod(lambda x: exp_lut(x, table=exp_table))
        sigmoid = staticmethod(sigmoid_pwl)
        div = staticmethod(
            lambda a, b: div_lut(a, b, table=div_table))
        act_q = _Hw.act_q
    return _HwTabled


# ---------------------------------------------------------------------------
# Block application — sequence mode
# ---------------------------------------------------------------------------


def _token_shift_seq(x, prev):
    """(B,S,D) -> previous-token tensor; prev (B,D) is x_{-1}."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _time_mix_seq(p, x, prev, cfg, nm):
    xx = _token_shift_seq(x, prev)
    mix = lambda m: nm.act_q(x * p[m] + xx * (1.0 - p[m]))
    r = mix("time_mix_r") @ p["wr"]
    k = mix("time_mix_k") @ p["wk"]
    v = mix("time_mix_v") @ p["wv"]
    r = constrain(r, ("batch", None, "tp"))
    w = jnp.exp(p["time_decay"].astype(jnp.float32))
    if getattr(cfg, "wkv_stub", False):
        out = v          # dry-run instrumentation: zero-cost recurrence
    else:
        out, _ = wkv4_scan(k, v, w, p["time_first"].astype(jnp.float32),
                           exp=nm.exp, div=nm.div)
    out = nm.act_q(nm.sigmoid(r) * out.astype(r.dtype))
    return constrain(out @ p["wo"], ("batch", None, None)), x[:, -1]


def _channel_mix_seq(p, x, prev, cfg, nm):
    xx = _token_shift_seq(x, prev)
    mix = lambda m: nm.act_q(x * p[m] + xx * (1.0 - p[m]))
    r = nm.sigmoid(mix("time_mix_r") @ p["wr"])
    k = mix("time_mix_k") @ p["wk"]
    k = constrain(k, ("batch", None, "tp"))
    k = jnp.square(jax.nn.relu(k))
    out = nm.act_q(r * (nm.act_q(k) @ p["wv"]))
    return constrain(out, ("batch", None, None)), x[:, -1]


def forward(params, batch: dict, cfg: ModelConfig, *, hw: bool = False):
    nm = _numerics(hw)
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", None, None))
    x = L.apply_norm(params["ln0"], x, "layernorm")
    zeros_prev = jnp.zeros((B, cfg.d_model), x.dtype)

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, "layernorm")
        att, _ = _time_mix_seq(lp["att"], h, zeros_prev, cfg, nm)
        x = x + att.astype(x.dtype)   # hw-numerics units emit f32
        h = L.apply_norm(lp["ln2"], x, "layernorm")
        ffn, _ = _channel_mix_seq(lp["ffn"], h, zeros_prev, cfg, nm)
        return x + ffn.astype(x.dtype), jnp.zeros((), jnp.float32)

    blk = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(blk, x, params["blocks"])
    x = L.apply_norm(params["ln_f"], x, "layernorm")
    logits = x @ params["head"].astype(x.dtype)
    return constrain(logits, ("batch", None, "tp")), jnp.zeros(
        (), jnp.float32)


# ---------------------------------------------------------------------------
# Decode — the paper's serving mode (token-by-token, state carried)
# ---------------------------------------------------------------------------

# decode_step ignores `pos` entirely, so slots in a serving pool may sit at
# unrelated sequence offsets within one fused step (repro.serving).
DECODE_POS_FREE = True


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int = 0,
                      dtype=jnp.float32):
    """State per layer: att token-shift x, ffn token-shift x, wkv (a,b,o).
    max_len is ignored (O(1) state — the paper's linear-memory claim)."""
    Lc, D = cfg.n_layers, cfg.d_model
    z = lambda: jnp.zeros((Lc, batch, D), dtype)
    return {
        "att_x": z(), "ffn_x": z(),
        "wkv_a": z(), "wkv_b": z(),
        "wkv_o": jnp.full((Lc, batch, D), -1e38, dtype),
    }


def decode_state_axes(cfg: ModelConfig):
    ax = ("layers", "batch", None)
    return {k: ax for k in ("att_x", "ffn_x", "wkv_a", "wkv_b", "wkv_o")}


def block_decode(lp, st, x, cfg: ModelConfig, nm=_Std):
    """One layer's FULL decode-step datapath: ln1 -> token-shift mix ->
    r/k/v matvecs -> WKV update -> gated output, then ln2 -> channel mix.

    x: (B, D) residual entering the block; st: this layer's state slice.
    Shared verbatim by the per-op scan (`decode_step`, the oracle) and the
    fused Pallas kernel (`decode_step_fused`), which is what makes the two
    paths bit-identical."""
    att_x, ffn_x = st["att_x"], st["ffn_x"]
    wkv = WKV4State(st["wkv_a"].astype(jnp.float32),
                    st["wkv_b"].astype(jnp.float32),
                    st["wkv_o"].astype(jnp.float32))
    h = L.apply_norm(lp["ln1"], x[:, None], "layernorm")[:, 0]
    p = lp["att"]
    mix = lambda m: nm.act_q(h * p[m] + att_x * (1.0 - p[m]))
    r = mix("time_mix_r") @ p["wr"]
    k = mix("time_mix_k") @ p["wk"]
    v = mix("time_mix_v") @ p["wv"]
    w = jnp.exp(p["time_decay"].astype(jnp.float32))
    new_wkv, out = wkv4_step(wkv, k.astype(jnp.float32),
                             v.astype(jnp.float32), w,
                             p["time_first"].astype(jnp.float32),
                             exp=nm.exp, div=nm.div)
    att = nm.act_q(nm.sigmoid(r) * out.astype(r.dtype)) @ p["wo"]
    x2 = x + att.astype(x.dtype)
    h2 = L.apply_norm(lp["ln2"], x2[:, None], "layernorm")[:, 0]
    p = lp["ffn"]
    mix2 = lambda m: nm.act_q(h2 * p[m] + ffn_x * (1.0 - p[m]))
    rr = nm.sigmoid(mix2("time_mix_r") @ p["wr"])
    kk = jnp.square(jax.nn.relu(mix2("time_mix_k") @ p["wk"]))
    ffn = nm.act_q(rr * (nm.act_q(kk) @ p["wv"]))
    new_st = {"att_x": h.astype(att_x.dtype),
              "ffn_x": h2.astype(ffn_x.dtype),
              "wkv_a": new_wkv.a.astype(st["wkv_a"].dtype),
              "wkv_b": new_wkv.b.astype(st["wkv_b"].dtype),
              "wkv_o": new_wkv.o.astype(st["wkv_o"].dtype)}
    return x2 + ffn.astype(x2.dtype), new_st


def _chunk_numerics(hw: bool):
    """Chunk-shaped variant of the decode numerics: identical elementwise
    units, with the A9 activation fake-quant scoped PER TOKEN POSITION
    (axis=1 of a (B, C, ...) chunk tensor) — each position then sees
    exactly the (B, features) scaling grain the per-step oracle applies,
    which is what keeps hw-numerics prefill bit-identical."""
    if not hw:
        return _Std

    class _HwChunk(_Hw):
        act_q = staticmethod(lambda x: uniform_fake_quant(x, 9, 1))
    return _HwChunk


def block_prefill(lp, st, x, valid, cfg: ModelConfig, nm=_Std, *,
                  hw: bool = False, interpret: bool | None = None):
    """One layer's chunked-prefill datapath over a (B, C, D) token window:
    ln1 -> shifted-sequence token mixes -> CHUNK-shaPED r/k/v matmuls
    (packed Δ-PoT leaves decode inside `kernels.fused_prefill.chunk_matmul`)
    -> the masked sequential WKV Pallas kernel (per-channel state in VMEM
    across the window, seeded from the pool state and snapped to its dtype
    every step) -> gated output -> ln2 -> chunk-shaped channel mix.

    Bit-identical to scanning `block_decode` over the window with the
    engine's per-step state masking, for any per-slot PREFIX validity mask
    (the scheduler only emits prefix masks: a prompt's chunk occupies
    positions [0, n)).  Factored the same way `block_decode` was: the
    models' `prefill_chunk` entry points and the tests share it verbatim."""
    from repro.kernels.fused_prefill import (
        chunk_matmul, last_valid_select, shifted_prev)
    from repro.kernels.wkv4 import wkv4_pallas
    dt = x.dtype
    att_x, ffn_x = st["att_x"], st["ffn_x"]
    h = L.apply_norm(lp["ln1"], x, "layernorm")
    p = lp["att"]
    # shifted sequence: position 0 mixes with the carried state, position t
    # with h_{t-1} ROUNDED THROUGH THE STATE DTYPE (the oracle stores the
    # carry as `h.astype(att_x.dtype)` between steps); past the valid
    # prefix the carry freezes, exactly like the oracle's masked commits
    hx = shifted_prev(h.astype(att_x.dtype), att_x, valid)
    mm = lambda a, w_: chunk_matmul(a, w_, dt, interpret=interpret)
    mix = lambda m: nm.act_q(h * p[m] + hx * (1.0 - p[m]))
    r = mm(mix("time_mix_r"), p["wr"])
    k = mm(mix("time_mix_k"), p["wk"])
    v = mm(mix("time_mix_v"), p["wv"])
    w = jnp.exp(p["time_decay"].astype(jnp.float32))
    tables = {}
    if hw:
        from repro.core.approx.units import DIV_LUT_TABLE, EXP_LUT_TABLE
        tables = {
            "exp_table": jnp.asarray(
                np.reshape(EXP_LUT_TABLE, -1), jnp.float32),
            "div_table": jnp.asarray(
                np.reshape(DIV_LUT_TABLE, -1), jnp.float32)}
    out, (af, bf, of) = wkv4_pallas(
        k.astype(jnp.float32), v.astype(jnp.float32), w,
        p["time_first"].astype(jnp.float32),
        st["wkv_a"].astype(jnp.float32), st["wkv_b"].astype(jnp.float32),
        st["wkv_o"].astype(jnp.float32),
        valid=valid, carry_dtype=jnp.dtype(st["wkv_a"].dtype).name,
        interpret=interpret, **tables)
    att = mm(nm.act_q(nm.sigmoid(r) * out.astype(r.dtype)), p["wo"])
    x2 = x + att.astype(x.dtype)
    h2 = L.apply_norm(lp["ln2"], x2, "layernorm")
    p = lp["ffn"]
    h2x = shifted_prev(h2.astype(ffn_x.dtype), ffn_x, valid)
    mix2 = lambda m: nm.act_q(h2 * p[m] + h2x * (1.0 - p[m]))
    rr = nm.sigmoid(mm(mix2("time_mix_r"), p["wr"]))
    kk = jnp.square(jax.nn.relu(mm(mix2("time_mix_k"), p["wk"])))
    ffn = nm.act_q(rr * mm(nm.act_q(kk), p["wv"]))
    n_valid = jnp.sum(valid.astype(jnp.int32), axis=1)
    new_st = {"att_x": last_valid_select(h, att_x, n_valid),
              "ffn_x": last_valid_select(h2, ffn_x, n_valid),
              # WKV finals are masked + dtype-snapped inside the kernel
              "wkv_a": af.astype(st["wkv_a"].dtype),
              "wkv_b": bf.astype(st["wkv_b"].dtype),
              "wkv_o": of.astype(st["wkv_o"].dtype)}
    return x2 + ffn.astype(x2.dtype), new_st


def prefill_chunk(params, state, tokens, valid, pos, cfg: ModelConfig, *,
                  hw: bool = False, interpret: bool | None = None,
                  all_logits: bool = False):
    """Fused chunked prefill: tokens (B, C) with a per-slot PREFIX validity
    mask (B, C) -> (new_state, last-valid logits (B, 1, V)).

    `all_logits=True` is the speculative VERIFIER variant: the head scores
    EVERY position -> (new_state, (B, C, V)), row k holding the logits the
    plain decode tick would produce after consuming token k.  Row-wise
    bit-identical to the last-valid gather (the (B·C, D) head matmul
    computes each row independently); invalid positions return zeros.

    Bit-identical to the engine's per-op prefill oracle — a `lax.scan` of
    `decode_step` with per-step masked state commits — while restructuring
    the chunk per the paper's §4 reordering: position-parallel work becomes
    (B·C, D) matmuls, the WKV recurrence runs on-chip through the Pallas
    sequence kernel, and packed Δ-PoT weights are decoded INSIDE the
    matmul kernels (no `unpack_params` anywhere in this trace — uint8
    codes are what crosses HBM for the whole prompt phase).  Lanes with no
    valid tokens keep their state and return zero logits, exactly like the
    oracle's untouched carry."""
    del pos
    from repro.core.quant.serving import broadcast_packed_scales, \
        cast_compute
    from repro.kernels.fused_prefill import chunk_matmul, gather_last_valid
    nm = _chunk_numerics(hw)
    dt = jnp.dtype(cfg.dtype)
    params = cast_compute(params, dt)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)     # (B,C,D)
    x = L.apply_norm(params["ln0"], x, "layernorm")
    blocks = broadcast_packed_scales(params["blocks"], cfg.n_layers)

    def body(x, xs):
        lp, st = xs
        return block_prefill(lp, st, x, valid, cfg, nm, hw=hw,
                             interpret=interpret)

    x, new_state = jax.lax.scan(body, x, (blocks, state))
    n_valid = jnp.sum(valid.astype(jnp.int32), axis=1)
    if all_logits:
        xf = L.apply_norm(params["ln_f"], x, "layernorm")
        logits = chunk_matmul(xf, params["head"], xf.dtype,
                              interpret=interpret)
        return new_state, jnp.where(valid[:, :, None], logits,
                                    jnp.zeros_like(logits))
    xl = gather_last_valid(x, jnp.maximum(n_valid - 1, 0))[:, None]
    xl = L.apply_norm(params["ln_f"], xl, "layernorm")
    logits = chunk_matmul(xl, params["head"], xl.dtype, interpret=interpret)
    return new_state, jnp.where((n_valid > 0)[:, None, None], logits,
                                jnp.zeros_like(logits))


# rwkv4 ships no `prepare_prefill_params`: its packed Δ-PoT leaves are ALL
# consumed by chunk matmuls (r/k/v/wo, the FFN pair, the head), so nothing
# needs pre-decoding — the registry's "chunked" prefill descriptor has no
# module prep and passes the tree through (rwkv6 pre-decodes its few
# elementwise-consumed packed leaves; see its PREFILL_PLAIN_LEAVES).


def decode_step(params, state, tokens, pos, cfg: ModelConfig, *,
                hw: bool = False):
    """tokens: (B,1). Returns (logits (B,1,V), new_state)."""
    del pos  # RWKV state is position-free
    nm = _numerics(hw)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(
        jnp.dtype(cfg.dtype))                              # (B,D)
    x = L.apply_norm(params["ln0"], x[:, None], "layernorm")[:, 0]

    def body(x, xs):
        lp, st = xs
        return block_decode(lp, st, x, cfg, nm)

    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    x = L.apply_norm(params["ln_f"], x[:, None], "layernorm")
    logits = x @ params["head"].astype(x.dtype)
    return logits, new_state


def _fused_kernel_block(cfg: ModelConfig, nm, dt):
    """Per-layer body traced INSIDE a fused Pallas launch (shared by the
    per-block kernel and the whole-model megakernel): pops the optional LUT
    operands (hw numerics needs the tables as explicit VMEM inputs),
    decodes packed Δ-PoT leaves in-VMEM, then runs the same `block_decode`
    the per-op oracle uses."""
    from repro.core.quant.serving import is_packed_leaf, unpack_leaf

    def kernel_block(lp, st, xx):
        lp = dict(lp)
        luts = lp.pop("_luts", None)
        nm_k = nm if luts is None else _hw_numerics_with_tables(
            luts["exp"], luts["div"])
        lp = jax.tree_util.tree_map(
            lambda l: unpack_leaf(l).astype(dt) if is_packed_leaf(l) else l,
            lp, is_leaf=is_packed_leaf)
        return block_decode(lp, st, xx, cfg, nm_k)
    return kernel_block


def _lut_operands(n_layers: int):
    """The EXP/DIV fraction tables as stacked kernel operands: (L, 256)
    broadcast views — a scan (or layer-indexed BlockSpec) slices one (256,)
    copy per layer; a leading-1 form stays resident under the megakernel's
    constant index map."""
    from repro.core.approx.units import DIV_LUT_TABLE, EXP_LUT_TABLE
    tab = lambda t: jnp.broadcast_to(
        jnp.asarray(np.reshape(t, -1), jnp.float32), (n_layers, 256))
    return {"exp": tab(EXP_LUT_TABLE), "div": tab(DIV_LUT_TABLE)}


def decode_step_fused(params, state, tokens, pos, cfg: ModelConfig, *,
                      hw: bool = False, interpret: bool | None = None):
    """Fused-kernel decode: same math as `decode_step`, but each block runs
    as ONE Pallas launch (`kernels.fused_decode`) — layernorms, token-shift
    mixes, matvecs, exp/σ units, and the WKV update never leave the chip,
    and Δ-PoT-packed weights (`{"packed","scale"}` leaves from
    `core.quant.serving.pack_params`) are decoded *inside* the launch so
    uint8 codes are all that crosses HBM.  Accepts packed or plain trees;
    bit-identical to the per-op path either way
    (tests/test_fused_decode.py)."""
    del pos
    from repro.core.quant.serving import cast_compute, unpack_leaf
    from repro.kernels.fused_decode import (
        broadcast_packed_scales, fused_block_decode)
    nm = _numerics(hw)
    dt = jnp.dtype(cfg.dtype)
    params = cast_compute(params, dt)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(dt)
    x = L.apply_norm(params["ln0"], x[:, None], "layernorm")[:, 0]

    kernel_block = _fused_kernel_block(cfg, nm, dt)
    blocks = broadcast_packed_scales(params["blocks"], cfg.n_layers)
    if hw:
        # LUTs as scanned operands (per-layer slices are identical views)
        blocks = {**blocks, "_luts": _lut_operands(cfg.n_layers)}

    def body(x, xs):
        lp, st = xs
        return fused_block_decode(kernel_block, x, lp, st,
                                  interpret=interpret)

    x, new_state = jax.lax.scan(body, x, (blocks, state))
    x = L.apply_norm(params["ln_f"], x[:, None], "layernorm")
    logits = x @ unpack_leaf(params["head"]).astype(x.dtype)
    return logits, new_state


def prepare_fused_model_params(params, cfg: ModelConfig, *,
                               hw: bool = False):
    """One-time host-side prep for the megakernel serving path — the
    generic `core.quant.serving.prepare_layer_stack_params` (compute cast
    + per-layer slab chunking), with the hw LUT operands attached as extra
    block operands when requested.  `decode_step_fused_model` accepts the
    result directly; raw trees also work but repack the slab every step."""
    from repro.core.quant.serving import prepare_layer_stack_params
    return prepare_layer_stack_params(
        params, cfg, {"_luts": _lut_operands(1)} if hw else None)


def _stack_has_luts(stack) -> bool:
    """Whether a prepared FusedLayerStack was built with the hw LUT
    operands attached (prepare_fused_model_params(hw=True))."""
    probe = jax.tree_util.tree_unflatten(
        stack.tdef, [None] * stack.tdef.num_leaves)
    return "_luts" in probe


def decode_step_fused_model(params, state, tokens, pos, cfg: ModelConfig, *,
                            hw: bool = False, bb: int | None = None,
                            weights: str | None = None,
                            interpret: bool | None = None):
    """Megakernel decode: the ENTIRE layer stack as ONE Pallas launch
    (`kernels.fused_decode.fused_model_decode`).  Where `decode_step_fused`
    still issues L launches under `lax.scan` — the residual and each
    layer's state round-tripping HBM between them — here the whole stack
    runs in one launch: the residual stays on-chip across layers, and each
    layer's weights arrive as one contiguous chunk per dtype (uint8 Δ-PoT
    code planes when packed), double-buffered behind the previous layer's
    compute in the streaming binding, while shared packed scales / hw LUTs
    stay VMEM-resident under constant index maps.  Same `block_decode`
    body, so bit-identical to the per-op oracle
    (tests/test_fused_decode.py).  `params` may be a plain tree or the
    output of `prepare_fused_model_params` (pre-cast, weights pre-chunked
    — the serving path)."""
    del pos
    from repro.core.quant.serving import (
        FusedLayerStack, cast_compute, unpack_leaf)
    from repro.kernels.fused_decode import fused_model_decode
    nm = _numerics(hw)
    dt = jnp.dtype(cfg.dtype)
    prepared = isinstance(params.get("blocks"), FusedLayerStack)
    if prepared and _stack_has_luts(params["blocks"]) != hw:
        raise ValueError(
            f"prepared params were built with hw={not hw} but decode was "
            f"called with hw={hw}; rebuild them via "
            "prepare_fused_model_params(params, hw=...) — without the LUT "
            "operands the hw numerics would capture the tables as kernel "
            "constants, which Pallas cannot lower")
    if not prepared:
        params = cast_compute(params, dt)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(dt)
    x = L.apply_norm(params["ln0"], x[:, None], "layernorm")[:, 0]

    blocks = params["blocks"]   # packed scales keep their broadcast form
    if hw and not prepared:
        luts = _lut_operands(1)   # leading-1: resident across the grid
        blocks = {**blocks, "_luts": luts}
    x, new_state = fused_model_decode(
        _fused_kernel_block(cfg, nm, dt), x, blocks, state, bb=bb,
        weights=weights, interpret=interpret)
    x = L.apply_norm(params["ln_f"], x[:, None], "layernorm")
    logits = x @ unpack_leaf(params["head"]).astype(x.dtype)
    return logits, new_state
