"""Tiny parameter-spec system.

Every model defines `spec(cfg) -> nested dict of P`; from that single source
we derive:
  * materialized parameters  (init_params — works under jax.eval_shape)
  * abstract parameters      (abstract_params — ShapeDtypeStruct tree)
  * logical sharding axes    (logical_axes — tree of tuples)

Logical axis vocabulary (mapped to mesh axes by repro.parallel.sharding):
  "fsdp"   — fully-sharded-data-parallel dim (usually the embed/input dim)
  "tp"     — tensor-parallel dim (heads / ffn hidden / vocab)
  "ep"     — expert-parallel dim (MoE expert axis)
  "layers" — stacked-layer leading axis (scan dim; never sharded)
  None     — replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Declarative parameter spec."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | uniform | const
    scale: float | None = None    # stddev override (default fan-in)
    const: float = 0.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")


def _init_leaf(p: P, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "const":
        return jnp.full(p.shape, p.const, dtype)
    if p.init == "uniform":
        s = p.scale if p.scale is not None else 1.0
        return jax.random.uniform(key, p.shape, dtype, -s, s)
    # default: truncated-normal, fan-in scaled over the non-output dims
    if p.scale is not None:
        std = p.scale
    else:
        fan_in = p.shape[0] if len(p.shape) == 1 else int(
            np.prod(p.shape[:-1]))
        # stacked-layer tensors: exclude the leading layer axis from fan-in
        if p.axes and p.axes[0] == "layers" and len(p.shape) > 2:
            fan_in = int(np.prod(p.shape[1:-1]))
        std = 1.0 / max(fan_in, 1) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, p.shape, jnp.float32)
            * std).astype(dtype)


def _is_p(x) -> bool:
    return isinstance(x, P)


def init_params(spec, rng, dtype=jnp.float32):
    """Materialize a spec tree; deterministic per-leaf keys via fold_in."""
    leaves, treedef = jax.tree_util.tree_flatten(spec, is_leaf=_is_p)
    out = []
    for i, p in enumerate(leaves):
        key = jax.random.fold_in(rng, i)
        out.append(_init_leaf(p, key, dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(spec, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec, is_leaf=_is_p)


def logical_axes(spec):
    return jax.tree_util.tree_map(lambda p: p.axes, spec, is_leaf=_is_p)


def param_count(spec) -> int:
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=_is_p)
    return int(sum(np.prod(p.shape) for p in leaves))


def param_bytes(spec, bytes_per_elem: int = 2) -> int:
    return param_count(spec) * bytes_per_elem
