"""Mamba-2 block (arXiv:2405.21060) — used by the zamba2 hybrid.

Block: in_proj -> (z | x | B | C | dt), short causal depthwise conv over
(x,B,C), softplus(dt)-scaled SSD recurrence with scalar-per-head decay,
D-skip, gated RMSNorm, out_proj.  The recurrence (scan / chunked / step)
lives in repro.core.wkv.ssd.

Shapes: d_inner = ssm_expand * d_model; H = d_inner / ssm_head_dim heads,
state dim N = ssm_state, n_groups = 1 (B/C shared across heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.wkv.ssd import ssd_chunked, ssd_init_state, ssd_scan, ssd_step
from repro.models import layers as L
from repro.models.param import P
from repro.parallel.sharding import constrain

CONV_K = 4  # causal conv kernel width (mamba2 default)


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N  # x, B, C share the conv
    return d_inner, H, N, conv_dim


def spec_mamba2(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, H, N, conv_dim = _dims(cfg)
    proj_out = 2 * d_inner + 2 * N + H  # z | x | B | C | dt
    return {
        "in_proj": P((d, proj_out), ("fsdp", "tp")),
        "conv_w": P((CONV_K, conv_dim), (None, None), scale=0.2),
        "conv_b": P((conv_dim,), (None,), init="zeros"),
        "a_log": P((H,), (None,), init="uniform", scale=1.0),
        "dt_bias": P((H,), (None,), init="zeros"),
        "d_skip": P((H,), (None,), init="ones"),
        "out_norm": {"scale": P((d_inner,), (None,), init="ones")},
        "out_proj": P((d_inner, d), ("tp", "fsdp")),
    }


def _split(zxbcdt, cfg):
    d_inner, H, N, _ = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xc = zxbcdt[..., d_inner:2 * d_inner + 2 * N]   # conv input: x|B|C
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xc, dt


def _split_conv(xc, cfg):
    d_inner, _, N, _ = _dims(cfg)
    return (xc[..., :d_inner], xc[..., d_inner:d_inner + N],
            xc[..., d_inner + N:])


def _gated_norm(p, y, z, eps=1e-5):
    """RMSNorm(y * silu(z)) — the mamba2 gated output norm."""
    y32 = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * p["scale"]).astype(y.dtype)


def _ssm_inputs(p, xc, dt, cfg):
    """Post-conv tensors -> SSD inputs (x (B,T,H,P), a (B,T,H), Bc, Cc)."""
    d_inner, H, N, _ = _dims(cfg)
    Pd = cfg.ssm_head_dim
    x, Bc, Cc = _split_conv(jax.nn.silu(xc), cfg)
    lead = x.shape[:-1]
    xh = x.reshape(*lead, H, Pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (...,H)
    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32)) * dt)    # (...,H)
    xdt = xh * dt[..., None]
    return xdt, a, Bc, Cc, xh, dt


def apply_mamba2_seq(p, x, cfg: ModelConfig, *, chunk: int = 64):
    """x: (B,S,D) -> (B,S,D).  Chunked SSD when S divides the chunk."""
    Bsz, S, D = x.shape
    d_inner, H, N, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xc, dt = _split(zxbcdt, cfg)
    z = constrain(z, ("batch", None, "tp"))
    # causal depthwise conv along S (kernel CONV_K)
    pad = jnp.zeros((Bsz, CONV_K - 1, conv_dim), xc.dtype)
    xp = jnp.concatenate([pad, xc], axis=1)
    xconv = sum(xp[:, i:i + S] * p["conv_w"][i] for i in range(CONV_K))
    xconv = xconv + p["conv_b"]
    xdt, a, Bc, Cc, xh, _ = _ssm_inputs(p, xconv, dt, cfg)
    Bc = jnp.broadcast_to(Bc[..., None, :], (Bsz, S, H, N))
    Cc = jnp.broadcast_to(Cc[..., None, :], (Bsz, S, H, N))
    ssd = (lambda *args: ssd_chunked(*args, chunk=chunk)
           ) if S % chunk == 0 and S > chunk else ssd_scan
    y, _ = ssd(xdt, a, Bc, Cc)
    y = y.astype(x.dtype) + xh * p["d_skip"][:, None]
    y = _gated_norm(p["out_norm"], y.reshape(Bsz, S, d_inner), z)
    return constrain(y @ p["out_proj"], ("batch", None, None))


# ---------------------------------------------------------------------------
# Decode — conv ring state + SSD state
# ---------------------------------------------------------------------------


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, H, N, conv_dim = _dims(cfg)
    Pd = cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, H, N, Pd), dtype),
    }


def mamba2_state_axes():
    return {"conv": ("batch", None, None),
            "ssd": ("batch", "tp", None, None)}


def apply_mamba2_step(p, x, state, cfg: ModelConfig):
    """x: (B,D) one token; state {"conv","ssd"} -> (y (B,D), new_state)."""
    Bsz, D = x.shape
    d_inner, H, N, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xc, dt = _split(zxbcdt, cfg)
    hist = state["conv"].astype(xc.dtype)               # (B, K-1, conv)
    window = jnp.concatenate([hist, xc[:, None]], axis=1)  # (B, K, conv)
    xconv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    new_conv = window[:, 1:]
    xdt, a, Bc, Cc, xh, _ = _ssm_inputs(p, xconv, dt, cfg)
    h, y = ssd_step(state["ssd"].astype(jnp.float32),
                    xdt.astype(jnp.float32), a,
                    Bc.astype(jnp.float32)[..., None, :].repeat(H, -2),
                    Cc.astype(jnp.float32)[..., None, :].repeat(H, -2))
    y = y.astype(x.dtype) + xh * p["d_skip"][:, None]
    y = _gated_norm(p["out_norm"], y.reshape(Bsz, d_inner), z)
    new_state = {"conv": new_conv.astype(state["conv"].dtype),
                 "ssd": h.astype(state["ssd"].dtype)}
    return (y @ p["out_proj"]).astype(x.dtype), new_state
