"""RWKV-6 "Finch" (arXiv:2404.05892) — data-dependent decay linear attention.

Block = TimeMix (ddlerp token-shift -> r/k/v/w/g projections -> multi-head
WKV-6 recurrence -> GroupNorm -> SiLU(g) gate) + ChannelMix (same squared-ReLU
gated FFN as RWKV-4), each preceded by LayerNorm, plus the pre-block ln0.

The data-dependent parts follow the published formulation:
  ddlerp: xxx = x + dx * mu_x;  d = tanh(xxx @ maa_w1) @ maa_w2 -> 5 deltas
          x_s  = x + dx * (mu_s + d_s)        for s in {w,k,v,r,g}
  decay:  w_t  = exp(-exp(time_decay + tanh(x_w @ td_w1) @ td_w2))
The recurrence itself lives in repro.core.wkv.wkv6 (scan / chunked / step);
training & prefill use the chunked sub-quadratic form, decode the O(1) step —
this model is the closest assigned architecture to the paper's RWKV-4 and is
the primary target of its technique.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.wkv.wkv6 import (
    wkv6_chunked, wkv6_init_state, wkv6_scan, wkv6_step)
from repro.models import layers as L
from repro.models.param import P
from repro.parallel.sharding import constrain

_MAA_RANK = 32   # low-rank dims of the data-dependent mixes (HF config: 32)
_TD_RANK = 64    # low-rank dim of the data-dependent decay


def _stack(spec, n: int):
    return jax.tree_util.tree_map(
        lambda p: P((n, *p.shape), ("layers", *p.axes), init=p.init,
                    scale=p.scale, const=p.const),
        spec, is_leaf=lambda x: isinstance(x, P))


def _block_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    H = cfg.n_heads
    N = cfg.rwkv_head_dim
    assert H * N == d, f"heads {H} x head_dim {N} != d_model {d}"
    return {
        "ln1": L.spec_norm(d, "layernorm"),
        "ln2": L.spec_norm(d, "layernorm"),
        "att": {
            "time_maa_x": P((d,), (None,), init="uniform", scale=0.5),
            # per-stream mus: w, k, v, r, g
            "time_maa": P((5, d), (None, None), init="uniform", scale=0.5),
            "maa_w1": P((d, 5 * _MAA_RANK), (None, None), scale=0.01),
            "maa_w2": P((5, _MAA_RANK, d), (None, None, None), scale=0.01),
            "time_decay": P((d,), (None,), init="zeros"),
            "td_w1": P((d, _TD_RANK), (None, None), scale=0.01),
            "td_w2": P((_TD_RANK, d), (None, None), scale=0.01),
            "time_faaaa": P((H, N), (None, None), init="zeros"),  # bonus u
            "wr": P((d, d), ("fsdp", "tp")),
            "wk": P((d, d), ("fsdp", "tp")),
            "wv": P((d, d), ("fsdp", "tp")),
            "wg": P((d, d), ("fsdp", "tp")),
            "wo": P((d, d), ("tp", "fsdp")),
            "ln_x": {"scale": P((d,), (None,), init="ones"),
                     "bias": P((d,), (None,), init="zeros")},
        },
        "ffn": {
            "time_mix_r": P((d,), (None,), init="uniform", scale=0.5),
            "time_mix_k": P((d,), (None,), init="uniform", scale=0.5),
            "wr": P((d, d), ("fsdp", "tp")),
            "wk": P((d, f), ("fsdp", "tp")),
            "wv": P((f, d), ("tp", "fsdp")),
        },
    }


def spec(cfg: ModelConfig) -> dict:
    return {
        "embed": P((cfg.vocab, cfg.d_model), ("tp", "fsdp"), scale=0.02),
        "ln0": L.spec_norm(cfg.d_model, "layernorm"),
        "blocks": _stack(_block_spec(cfg), cfg.n_layers),
        "ln_f": L.spec_norm(cfg.d_model, "layernorm"),
        "head": P((cfg.d_model, cfg.vocab), ("fsdp", "tp")),
    }


# ---------------------------------------------------------------------------
# TimeMix internals (shared between sequence and step forms)
# ---------------------------------------------------------------------------


def _ddlerp(p, x, dx):
    """Data-dependent token-shift mixes.  x, dx: (..., D).
    Returns (xw, xk, xv, xr, xg)."""
    xxx = x + dx * p["time_maa_x"]
    lead = xxx.shape[:-1]
    dmix = jnp.tanh(xxx @ p["maa_w1"])                 # (..., 5R)
    dmix = dmix.reshape(*lead, 5, _MAA_RANK)
    deltas = jnp.einsum("...sr,srd->...sd", dmix, p["maa_w2"])  # (...,5,D)
    mus = p["time_maa"] + deltas                       # (...,5,D)
    return tuple(x + dx * mus[..., i, :] for i in range(5))


def _decay(p, xw):
    """w_t in (0,1): exp(-exp(time_decay + lora(x_w)))."""
    dd = p["time_decay"] + jnp.tanh(xw @ p["td_w1"]) @ p["td_w2"]
    return jnp.exp(-jnp.exp(dd.astype(jnp.float32)))


def _group_norm(p, y, H, eps=64e-5):
    """Per-head LayerNorm (the official ln_x GroupNorm(H))."""
    lead = y.shape[:-1]
    yh = y.reshape(*lead, H, -1).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    out = yh.reshape(*lead, -1) * p["scale"] + p["bias"]
    return out.astype(y.dtype)


def _time_mix_seq(p, x, prev, cfg, wkv_fn):
    """x: (B,S,D); prev: (B,D) token-shift carry."""
    B, S, D = x.shape
    H, N = cfg.n_heads, cfg.rwkv_head_dim
    dx = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1) - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, dx)
    r = (xr @ p["wr"]).reshape(B, S, H, N)
    k = (xk @ p["wk"]).reshape(B, S, H, N)
    v = (xv @ p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(B, S, H, N)
    r = constrain(r, ("batch", None, "tp", None))
    y, _ = wkv_fn(r, k, v, w, p["time_faaaa"].astype(jnp.float32))
    y = _group_norm(p["ln_x"], y.reshape(B, S, D), H)
    out = (y * g) @ p["wo"]
    return constrain(out, ("batch", None, None)), x[:, -1]


def _channel_mix_seq(p, x, prev):
    xx = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    mix = lambda m: x * p[m] + xx * (1.0 - p[m])
    r = jax.nn.sigmoid(mix("time_mix_r") @ p["wr"])
    k = constrain(mix("time_mix_k") @ p["wk"], ("batch", None, "tp"))
    k = jnp.square(jax.nn.relu(k))
    return constrain(r * (k @ p["wv"]), ("batch", None, None)), x[:, -1]


# ---------------------------------------------------------------------------
# Forward (training / prefill): chunked sub-quadratic WKV
# ---------------------------------------------------------------------------


def forward(params, batch: dict, cfg: ModelConfig, *, chunk: int = 64):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", None, None))
    x = L.apply_norm(params["ln0"], x, "layernorm")
    zeros_prev = jnp.zeros((B, cfg.d_model), x.dtype)
    wkv_fn = (lambda r, k, v, w, u: wkv6_chunked(r, k, v, w, u, chunk=chunk)
              ) if S % chunk == 0 and S > chunk else wkv6_scan

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, "layernorm")
        att, _ = _time_mix_seq(lp["att"], h, zeros_prev, cfg, wkv_fn)
        x = x + att
        h = L.apply_norm(lp["ln2"], x, "layernorm")
        ffn, _ = _channel_mix_seq(lp["ffn"], h, zeros_prev)
        return x + ffn, jnp.zeros((), jnp.float32)

    blk = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(blk, x, params["blocks"])
    x = L.apply_norm(params["ln_f"], x, "layernorm")
    logits = x @ params["head"].astype(x.dtype)
    return constrain(logits, ("batch", None, "tp")), jnp.zeros(
        (), jnp.float32)


# ---------------------------------------------------------------------------
# Decode — O(1) state per token (the linear-inference story)
# ---------------------------------------------------------------------------

# decode_step ignores `pos` entirely, so slots in a serving pool may sit at
# unrelated sequence offsets within one fused step (repro.serving).
DECODE_POS_FREE = True


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int = 0,
                      dtype=jnp.float32):
    del max_len  # O(1) state
    Lc, D = cfg.n_layers, cfg.d_model
    H, N = cfg.n_heads, cfg.rwkv_head_dim
    return {
        "att_x": jnp.zeros((Lc, batch, D), dtype),
        "ffn_x": jnp.zeros((Lc, batch, D), dtype),
        "wkv_s": jnp.zeros((Lc, batch, H, N, N), dtype),
    }


def decode_state_axes(cfg: ModelConfig):
    return {"att_x": ("layers", "batch", None),
            "ffn_x": ("layers", "batch", None),
            "wkv_s": ("layers", "batch", "tp", None, None)}


def block_decode(lp, st, x, cfg: ModelConfig):
    """One layer's FULL decode-step datapath: ln1 -> ddlerp mixes ->
    r/k/v/w/g projections -> multi-head WKV-6 update -> GroupNorm ->
    SiLU-gated output, then ln2 -> channel mix.

    x: (B, D) residual entering the block; st: this layer's state slice.
    Shared verbatim by the per-op scan (`decode_step`, the oracle) and the
    fused Pallas kernel (`decode_step_fused`), which is what makes the two
    paths bit-identical."""
    B = x.shape[0]
    H, N, D = cfg.n_heads, cfg.rwkv_head_dim, cfg.d_model
    h = L.apply_norm(lp["ln1"], x[:, None], "layernorm")[:, 0]
    p = lp["att"]
    dx = st["att_x"].astype(h.dtype) - h
    xw, xk, xv, xr, xg = _ddlerp(p, h, dx)
    r = (xr @ p["wr"]).reshape(B, H, N)
    k = (xk @ p["wk"]).reshape(B, H, N)
    v = (xv @ p["wv"]).reshape(B, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(B, H, N)
    S_new, y = wkv6_step(st["wkv_s"].astype(jnp.float32),
                         r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), w,
                         p["time_faaaa"].astype(jnp.float32))
    y = _group_norm(p["ln_x"], y.reshape(B, D).astype(h.dtype), H)
    x2 = x + (y * g) @ p["wo"]
    h2 = L.apply_norm(lp["ln2"], x2[:, None], "layernorm")[:, 0]
    p2 = lp["ffn"]
    ffn_x = st["ffn_x"].astype(h2.dtype)
    mix = lambda m: h2 * p2[m] + ffn_x * (1.0 - p2[m])
    rr = jax.nn.sigmoid(mix("time_mix_r") @ p2["wr"])
    kk = jnp.square(jax.nn.relu(mix("time_mix_k") @ p2["wk"]))
    ffn = rr * (kk @ p2["wv"])
    new_st = {"att_x": h.astype(st["att_x"].dtype),
              "ffn_x": h2.astype(st["ffn_x"].dtype),
              "wkv_s": S_new.astype(st["wkv_s"].dtype)}
    return x2 + ffn, new_st


def block_prefill(lp, st, x, valid, cfg: ModelConfig, *,
                  interpret: bool | None = None):
    """One layer's chunked-prefill datapath over a (B, C, D) token window:
    ln1 -> shifted-sequence ddlerp mixes -> CHUNK-shaped r/k/v/w/g
    projections (packed Δ-PoT leaves decode inside
    `kernels.fused_prefill.chunk_matmul`) -> the masked SEQUENTIAL WKV-6
    Pallas kernel (each head's (N, N) state in VMEM across the window,
    advanced with the exact `wkv6_step` math and snapped to the pool dtype
    every step) -> GroupNorm -> SiLU-gated output, then ln2 -> chunk-shaped
    channel mix.

    Bit-identical to scanning `block_decode` over the window with the
    engine's per-step state masking, for any per-slot PREFIX validity mask
    (the scheduler only emits prefix masks).  Factored the same way
    `block_decode` was; `lp` must carry time_maa / maa_w2 / time_faaaa as
    PLAIN leaves (they are consumed element-wise, not by a matmul —
    `prepare_prefill_params` pre-decodes them once at startup)."""
    from repro.kernels.fused_prefill import (
        chunk_matmul, last_valid_select, shifted_prev)
    from repro.kernels.wkv6 import wkv6_seq_pallas
    B, C, D = x.shape
    H, N = cfg.n_heads, cfg.rwkv_head_dim
    dt = x.dtype
    h = L.apply_norm(lp["ln1"], x, "layernorm")
    p = lp["att"]
    mm = lambda a, w_: chunk_matmul(a, w_, dt, interpret=interpret)
    # shifted sequence: position t mixes with h_{t-1} rounded through the
    # state dtype (the oracle's `h.astype(att_x.dtype)` carry); past the
    # valid prefix the carry freezes, like the oracle's masked commits
    prev = shifted_prev(h.astype(st["att_x"].dtype), st["att_x"], valid)
    dx = prev.astype(h.dtype) - h
    # ddlerp with the low-rank matmuls chunk-shaped
    xxx = h + dx * p["time_maa_x"]
    dmix = jnp.tanh(mm(xxx, p["maa_w1"])).reshape(B, C, 5, _MAA_RANK)
    deltas = jnp.einsum("...sr,srd->...sd", dmix, p["maa_w2"])
    mus = p["time_maa"] + deltas
    xw, xk, xv, xr, xg = (h + dx * mus[..., i, :] for i in range(5))
    r = mm(xr, p["wr"]).reshape(B, C, H, N)
    k = mm(xk, p["wk"]).reshape(B, C, H, N)
    v = mm(xv, p["wv"]).reshape(B, C, H, N)
    g = jax.nn.silu(mm(xg, p["wg"]))
    dd = p["time_decay"] + mm(jnp.tanh(mm(xw, p["td_w1"])), p["td_w2"])
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32))).reshape(B, C, H, N)
    y, S_new = wkv6_seq_pallas(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w, p["time_faaaa"].astype(jnp.float32),
        st["wkv_s"].astype(jnp.float32), valid=valid,
        carry_dtype=jnp.dtype(st["wkv_s"].dtype).name, interpret=interpret)
    y = _group_norm(p["ln_x"], y.reshape(B, C, D).astype(h.dtype), H)
    x2 = x + mm(y * g, p["wo"])
    h2 = L.apply_norm(lp["ln2"], x2, "layernorm")
    p2 = lp["ffn"]
    prev2 = shifted_prev(h2.astype(st["ffn_x"].dtype), st["ffn_x"], valid)
    ffn_x = prev2.astype(h2.dtype)
    mix = lambda m: h2 * p2[m] + ffn_x * (1.0 - p2[m])
    rr = jax.nn.sigmoid(mm(mix("time_mix_r"), p2["wr"]))
    kk = jnp.square(jax.nn.relu(mm(mix("time_mix_k"), p2["wk"])))
    ffn = rr * mm(kk, p2["wv"])
    n_valid = jnp.sum(valid.astype(jnp.int32), axis=1)
    new_st = {"att_x": last_valid_select(h, st["att_x"], n_valid),
              "ffn_x": last_valid_select(h2, st["ffn_x"], n_valid),
              # masked + dtype-snapped inside the kernel
              "wkv_s": S_new.astype(st["wkv_s"].dtype)}
    return x2 + ffn, new_st


def prefill_chunk(params, state, tokens, valid, pos, cfg: ModelConfig, *,
                  interpret: bool | None = None, all_logits: bool = False):
    """Fused chunked prefill: tokens (B, C) with a per-slot PREFIX validity
    mask (B, C) -> (new_state, last-valid logits (B, 1, V)).  Bit-identical
    to the engine's scan-of-`decode_step` prefill oracle; packed Δ-PoT
    projection weights decode inside the chunk-matmul kernels (run
    `prepare_prefill_params` once first so the few element-wise-consumed
    packed leaves arrive plain).  See models/rwkv4.py `prefill_chunk` for
    the shared contract and the `all_logits=True` verifier variant
    (-> (new_state, (B, C, V)), one logits row per valid position)."""
    del pos
    from repro.core.quant.serving import broadcast_packed_scales, \
        cast_compute
    from repro.kernels.fused_prefill import chunk_matmul, gather_last_valid
    dt = jnp.dtype(cfg.dtype)
    params = cast_compute(params, dt)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)     # (B,C,D)
    x = L.apply_norm(params["ln0"], x, "layernorm")
    blocks = broadcast_packed_scales(params["blocks"], cfg.n_layers)

    def body(x, xs):
        lp, st = xs
        return block_prefill(lp, st, x, valid, cfg, interpret=interpret)

    x, new_state = jax.lax.scan(body, x, (blocks, state))
    n_valid = jnp.sum(valid.astype(jnp.int32), axis=1)
    if all_logits:
        xf = L.apply_norm(params["ln_f"], x, "layernorm")
        logits = chunk_matmul(xf, params["head"], xf.dtype,
                              interpret=interpret)
        return new_state, jnp.where(valid[:, :, None], logits,
                                    jnp.zeros_like(logits))
    xl = gather_last_valid(x, jnp.maximum(n_valid - 1, 0))[:, None]
    xl = L.apply_norm(params["ln_f"], xl, "layernorm")
    logits = chunk_matmul(xl, params["head"], xl.dtype, interpret=interpret)
    return new_state, jnp.where((n_valid > 0)[:, None, None], logits,
                                jnp.zeros_like(logits))


# packed leaves block_prefill consumes OUTSIDE a matmul: element-wise mixes,
# the einsum'd low-rank delta table, and the WKV bonus
PREFILL_PLAIN_LEAVES = tuple(
    ("blocks", "att", k)
    for k in ("time_maa_x", "time_maa", "maa_w2", "time_faaaa"))


def prepare_prefill_params(params, cfg: ModelConfig):
    """One-time host-side prep for the fused prefill path: pre-decode the
    few packed leaves the chunk datapath consumes element-wise (they're
    additive-sized — decoding them once at startup costs nothing), so the
    prefill TRACE never unpacks anything: every remaining packed leaf
    streams its uint8 codes straight into a chunk-matmul kernel.  The
    generic `core.quant.serving.predecode_packed_leaves` does the work
    (same `unpack_leaf` as the per-op oracle, so bits match)."""
    del cfg
    from repro.core.quant.serving import predecode_packed_leaves
    return predecode_packed_leaves(params, PREFILL_PLAIN_LEAVES)


def decode_step(params, state, tokens, pos, cfg: ModelConfig):
    """tokens: (B,1) -> (logits (B,1,V), new_state)."""
    del pos
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(
        jnp.dtype(cfg.dtype))
    x = L.apply_norm(params["ln0"], x[:, None], "layernorm")[:, 0]

    def body(x, xs):
        lp, st = xs
        return block_decode(lp, st, x, cfg)

    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    x = L.apply_norm(params["ln_f"], x[:, None], "layernorm")
    logits = x @ params["head"].astype(x.dtype)
    return logits, new_state


def _fused_kernel_block(cfg: ModelConfig, dt):
    """Per-layer body traced INSIDE a fused Pallas launch (shared by the
    per-block kernel and the whole-model megakernel): decodes packed Δ-PoT
    leaves in-VMEM, then runs the same `block_decode` the per-op oracle
    uses."""
    from repro.core.quant.serving import is_packed_leaf, unpack_leaf

    def kernel_block(lp, st, xx):
        lp = jax.tree_util.tree_map(
            lambda l: unpack_leaf(l).astype(dt) if is_packed_leaf(l) else l,
            lp, is_leaf=is_packed_leaf)
        return block_decode(lp, st, xx, cfg)
    return kernel_block


def decode_step_fused(params, state, tokens, pos, cfg: ModelConfig, *,
                      interpret: bool | None = None):
    """Fused-kernel decode: same math as `decode_step`, but each block runs
    as ONE Pallas launch (`kernels.fused_decode`) with the (H, N, N) WKV
    state resident for the whole block and Δ-PoT-packed weights decoded
    inside the launch.  Accepts packed or plain trees; bit-identical to the
    per-op path either way (tests/test_fused_decode.py)."""
    del pos
    from repro.core.quant.serving import cast_compute, unpack_leaf
    from repro.kernels.fused_decode import (
        broadcast_packed_scales, fused_block_decode)
    dt = jnp.dtype(cfg.dtype)
    params = cast_compute(params, dt)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(dt)
    x = L.apply_norm(params["ln0"], x[:, None], "layernorm")[:, 0]

    kernel_block = _fused_kernel_block(cfg, dt)
    blocks = broadcast_packed_scales(params["blocks"], cfg.n_layers)

    def body(x, xs):
        lp, st = xs
        return fused_block_decode(kernel_block, x, lp, st,
                                  interpret=interpret)

    x, new_state = jax.lax.scan(body, x, (blocks, state))
    x = L.apply_norm(params["ln_f"], x[:, None], "layernorm")
    logits = x @ unpack_leaf(params["head"]).astype(x.dtype)
    return logits, new_state


def prepare_fused_model_params(params, cfg: ModelConfig):
    """One-time host-side prep for the megakernel serving path — the
    generic `core.quant.serving.prepare_layer_stack_params` (compute cast
    + per-dtype per-layer slab chunking): one weight stream per layer
    instead of one gather per leaf."""
    from repro.core.quant.serving import prepare_layer_stack_params
    return prepare_layer_stack_params(params, cfg)


def decode_step_fused_model(params, state, tokens, pos, cfg: ModelConfig, *,
                            bb: int | None = None,
                            weights: str | None = None,
                            interpret: bool | None = None):
    """Megakernel decode: the ENTIRE layer stack as ONE Pallas launch
    (`kernels.fused_decode.fused_model_decode`) — the residual stays
    on-chip across layers, each layer's weights arrive as one contiguous
    chunk per dtype (uint8 Δ-PoT code planes when packed) double-buffered
    behind the previous layer's compute in the streaming binding, and the
    (H, N, N) WKV state is read and written once per layer.  Same
    `block_decode` body as the per-op oracle, so bit-identical
    (tests/test_fused_decode.py).  `params` may be a plain tree or the
    output of `prepare_fused_model_params` (pre-cast, weights pre-chunked
    — the serving path)."""
    del pos
    from repro.core.quant.serving import (
        FusedLayerStack, cast_compute, unpack_leaf)
    from repro.kernels.fused_decode import fused_model_decode
    dt = jnp.dtype(cfg.dtype)
    if not isinstance(params.get("blocks"), FusedLayerStack):
        params = cast_compute(params, dt)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(dt)
    x = L.apply_norm(params["ln0"], x[:, None], "layernorm")[:, 0]
    # packed scales keep their broadcast (1, ...) form: the megakernel
    # binds them with a constant index map (no per-layer copies)
    x, new_state = fused_model_decode(
        _fused_kernel_block(cfg, dt), x, params["blocks"], state, bb=bb,
        weights=weights, interpret=interpret)
    x = L.apply_norm(params["ln_f"], x[:, None], "layernorm")
    logits = x @ unpack_leaf(params["head"]).astype(x.dtype)
    return logits, new_state
