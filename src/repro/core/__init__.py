"""Core — the paper's contributions: quantization (C1/C2), complex-op
approximation units (C3), and the WKV/SSD recurrences that the fused
on-chip pipeline (C4) is built around."""
