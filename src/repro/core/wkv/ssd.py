"""Mamba-2 SSD recurrence (for the zamba2 hybrid blocks).

Per head with state dim N and head (value) dim P:

    h_t = a_t · h_{t-1} + B_t ⊗ x_t          a_t ∈ (0,1) scalar per head
    y_t = C_t @ h_t  (+ D ⊙ x_t skip)

Shapes:
    x : (B, T, H, P)    a : (B, T, H)    Bc, Cc : (B, T, H, N)
    h : (B, H, N, P)

The scalar-per-head decay (vs. RWKV-6's vector decay) is what makes the
chunked "state-space duality" form a plain masked attention matmul.

Oracle/consumer: `ssd_scan` is the exact reference that `ssd_chunked`
(training/prefill) and `ssd_step` (decode) are tested against in
`tests/test_wkv.py`; the consumer is `models.mamba2` (and through it the
zamba2 hybrid blocks), which picks the form per phase exactly like the
RWKV models pick between wkv scan/chunked/step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_init_state(batch: int, heads: int, state_dim: int, head_dim: int,
                   dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((batch, heads, state_dim, head_dim), dtype)


def ssd_step(h, x, a, Bc, Cc):
    """Decode step. x:(B,H,P) a:(B,H) Bc,Cc:(B,H,N); h:(B,H,N,P)."""
    h = a[..., None, None] * h + Bc[..., :, None] * x[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Cc, h)
    return h, y


def ssd_scan(x, a, Bc, Cc, state=None):
    """Reference scan. x:(B,T,H,P) a:(B,T,H) Bc,Cc:(B,T,H,N)."""
    B, T, H, P = x.shape
    N = Bc.shape[-1]
    if state is None:
        state = ssd_init_state(B, H, N, P, jnp.float32)
    f32 = lambda z: z.astype(jnp.float32)

    def body(h, inp):
        xt, at, bt, ct = inp
        h, y = ssd_step(h, xt, at, bt, ct)
        return h, y

    xs = jnp.moveaxis(f32(x), 1, 0)
    as_ = jnp.moveaxis(f32(a), 1, 0)
    bs = jnp.moveaxis(f32(Bc), 1, 0)
    cs = jnp.moveaxis(f32(Cc), 1, 0)
    final, ys = jax.lax.scan(body, state, (xs, as_, bs, cs))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssd_chunked(x, a, Bc, Cc, state=None, *, chunk: int = 64):
    """Chunked SSD: y_t = C_t Σ_{i<=t} (Π_{j=i+1..t} a_j) B_i x_i^T.

    With scalar decay, Π a_j = e^{La_t - La_i} where La = cumsum(log a); the
    intra-chunk part is a (C×C)-masked matmul and the inter-chunk part a
    state bmm — the MXU-friendly "dual" form of the scan.
    """
    B, T, H, P = x.shape
    N = Bc.shape[-1]
    C = chunk
    if T % C != 0:
        raise ValueError(f"T={T} not divisible by chunk={chunk}")
    G = T // C
    if state is None:
        state = ssd_init_state(B, H, N, P, jnp.float32)
    f32 = lambda z: z.astype(jnp.float32)
    resh4 = lambda z: jnp.moveaxis(f32(z).reshape(B, G, C, *z.shape[2:]), 1, 0)
    xs, as_, bs, cs = resh4(x), resh4(a), resh4(Bc), resh4(Cc)
    mask = jnp.tril(jnp.ones((C, C), jnp.float32))  # inclusive i <= t

    def body(h, inp):
        xc, ac, bc, cc = inp                         # (B,C,H,·)
        la = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-38)), axis=1)  # (B,C,H)
        # inter-chunk: decay from chunk start to t inclusive = e^{la_t}
        y = jnp.einsum("bchn,bhnp->bchp", cc * jnp.exp(la)[..., None], h)
        # intra-chunk masked attention: the decay is SCALAR per head, so the
        # exact pair-ratio matrix e^{la_t - la_i} is only (B,C,C,H).  Mask
        # (i <= t) BEFORE the exp so every live exponent is <= 0 — this can
        # only underflow (the true limit), never overflow.
        D = la[:, :, None, :] - la[:, None, :, :]    # (B,C,C,H) = la_t - la_i
        D = jnp.where(mask[None, :, :, None].astype(bool), D, -1e30)
        att = jnp.einsum("bchn,bdhn->bcdh", cc, bc) * jnp.exp(D)
        y = y + jnp.einsum("bcdh,bdhp->bchp", att, xc)
        # state update: h' = e^{la_C} h + Σ_i e^{la_C - la_i} B_i x_i^T
        ltot = la[:, -1, :]                          # (B,H)
        b_fut = bc * jnp.exp(ltot[:, None, :] - la)[..., None]
        h = jnp.exp(ltot)[..., None, None] * h + jnp.einsum(
            "bchn,bchp->bhnp", b_fut, xc)
        return h, y

    final, ys = jax.lax.scan(body, state, (xs, as_, bs, cs))
    return jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P).astype(x.dtype), final
