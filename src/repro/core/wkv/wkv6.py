"""RWKV-6 "Finch" WKV: linear attention with data-dependent per-channel decay.

Per head with head dim N (key) / N (value):

    y_t = r_t @ (S_{t-1} + diag(u) (k_t ⊗ v_t))
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t            w_t ∈ (0,1)^N per token

Shapes:
    r, k, w : (B, T, H, N)    v : (B, T, H, N)    u : (H, N)
    state S : (B, H, N, N)    output : (B, T, H, N)

Three evaluation forms:
  * wkv6_step    — O(N²) per token (decode)
  * wkv6_scan    — scan over T (reference; exact)
  * wkv6_chunked — chunked sub-quadratic form used for long prefill/training;
    intra-chunk work is dense matmul (MXU-friendly) with log-space decay
    ratios for stability, inter-chunk state is carried like the scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_init_state(batch: int, heads: int, head_dim: int,
                    dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((batch, heads, head_dim, head_dim), dtype)


def wkv6_step(state: jnp.ndarray, r, k, v, w, u):
    """One decode step. r,k,v,w: (B,H,N); u: (H,N); state: (B,H,N,N)."""
    kv = k[..., :, None] * v[..., None, :]               # (B,H,N,N)
    y = jnp.einsum("bhn,bhnm->bhm", r, state + u[..., :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return new_state, y


def wkv6_scan(r, k, v, w, u, state=None):
    """Reference scan. r,k,v,w: (B,T,H,N); u: (H,N) -> (B,T,H,N), state."""
    B, T, H, N = r.shape
    if state is None:
        state = wkv6_init_state(B, H, N, jnp.float32)
    f32 = lambda x: x.astype(jnp.float32)

    def body(S, rkvw):
        rt, kt, vt, wt = rkvw
        S, y = wkv6_step(S, rt, kt, vt, wt, f32(u))
        return S, y

    rs = jnp.moveaxis(f32(r), 1, 0)
    ks = jnp.moveaxis(f32(k), 1, 0)
    vs = jnp.moveaxis(f32(v), 1, 0)
    ws = jnp.moveaxis(f32(w), 1, 0)
    final, ys = jax.lax.scan(body, state, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final


def wkv6_chunked(r, k, v, w, u, state=None, *, chunk: int = 64,
                 subchunk: int = 16):
    """Chunked form: O(T·N²) state path + O(T·C·N) intra-chunk matmuls.

    Stability design: every exponent that reaches `exp` is <= 0, so the
    computation can only *underflow to zero* (which is also the true limit),
    never overflow.  A naive separable split r·e^{L_t} × k·e^{-L_i} is NOT
    stable — e^{-L_i} overflows under strong decay even though the ratio for
    nearby (t, i) pairs is O(1) — so the intra-chunk part uses the two-level
    scheme of chunked linear attention:

      * target sub-chunk a (rows t ∈ a) re-references decays to the
        sub-chunk start: r'_t = r_t e^{L_{t-1} − L_start[a]}  (exponent <= 0)
      * keys from strictly earlier positions: k'_i = k_i e^{L_start[a] − L_i}
        (i < start of a ⇒ exponent <= 0), masked to −inf before exp elsewhere
      * the diagonal S×S block is evaluated with the exact per-pair
        exponent tensor (small: S×S×N), masked strictly-lower before exp.
    """
    B, T, H, N = r.shape
    if T % chunk != 0:
        raise ValueError(f"T={T} not divisible by chunk={chunk}")
    C = chunk
    S_sub = min(subchunk, C)
    if C % S_sub != 0:
        raise ValueError(f"chunk={C} not divisible by subchunk={S_sub}")
    n_sub = C // S_sub
    G = T // C
    if state is None:
        state = wkv6_init_state(B, H, N, jnp.float32)
    f32 = lambda x: x.astype(jnp.float32)
    # (G, B, C, H, N)
    resh = lambda x: jnp.moveaxis(f32(x).reshape(B, G, C, H, N), 1, 0)
    rs, ks, vs, ws = resh(r), resh(k), resh(v), resh(w)
    u32 = f32(u)

    NEG = jnp.float32(-1e30)
    # strict-lower mask for the diagonal sub-chunk block
    diag_mask = jnp.tril(jnp.ones((S_sub, S_sub), bool), k=-1)
    positions = jnp.arange(C)

    def body(S, x):
        rc, kc, vc, wc = x                       # (B,C,H,N)
        logw = jnp.log(jnp.maximum(wc, 1e-38))   # (B,C,H,N)
        L = jnp.cumsum(logw, axis=1)             # inclusive  (B,C,H,N)
        Lprev = L - logw                         # exclusive: L_{t-1}
        # ---- inter-chunk: exponent Lprev <= 0, stable
        r_dec = rc * jnp.exp(Lprev)
        y = jnp.einsum("bchn,bhnm->bchm", r_dec, S)
        # ---- intra-chunk, per target sub-chunk (static unrolled loop)
        y_intra = []
        for a in range(n_sub):
            lo, hi = a * S_sub, (a + 1) * S_sub
            L_start = Lprev[:, lo:lo + 1]        # (B,1,H,N) cum thru lo-1
            r_loc = rc[:, lo:hi] * jnp.exp(Lprev[:, lo:hi] - L_start)
            # earlier keys, masked to -inf at i >= lo BEFORE the exp
            expo = L_start - L                   # (B,C,H,N), <=0 for i<lo
            expo = jnp.where((positions < lo)[None, :, None, None],
                             expo, NEG)
            k_rel = kc * jnp.exp(expo)
            att = jnp.einsum("bshn,bchn->bhsc", r_loc, k_rel)  # (B,H,S,C)
            ya = jnp.einsum("bhsc,bchn->bshn", att, vc)
            # diagonal block: exact pairwise exponents (strictly lower)
            D = Lprev[:, lo:hi, None] - L[:, None, lo:hi]  # (B,S,S,H,N)
            D = jnp.where(diag_mask[None, :, :, None, None], D, NEG)
            att_d = jnp.einsum("bshn,bihn,bsihn->bhsi",
                               rc[:, lo:hi], kc[:, lo:hi], jnp.exp(D))
            ya = ya + jnp.einsum("bhsi,bihn->bshn", att_d, vc[:, lo:hi])
            y_intra.append(ya)
        y = y + jnp.concatenate(y_intra, axis=1)
        # ---- bonus (current token)
        y = y + jnp.einsum("bchn,bchn->bch", rc * u32[None, None], kc
                           )[..., None] * vc
        # ---- state update: exponents Ltot - L <= 0 and Ltot <= 0, stable
        Ltot = L[:, -1:, :, :]                   # (B,1,H,N)
        k_fut = kc * jnp.exp(Ltot - L)           # e^{L_C - L_i} k_i
        S_new = jnp.exp(Ltot[:, 0])[..., None] * S + jnp.einsum(
            "bchn,bchm->bhnm", k_fut, vc)
        return S_new, y

    final, ys = jax.lax.scan(body, state, (rs, ks, vs, ws))
    # ys: (G, B, C, H, N) -> (B, T, H, N)
    out = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, N)
    return out.astype(r.dtype), final
