"""RWKV-4 WKV operator (paper Eq. 2), numerically-stable running-max form.

The mathematical definition

    wkv_t = ( Σ_{i<t} e^{-(t-1-i)w + k_i} ⊙ v_i  +  e^{u+k_t} ⊙ v_t )
            / ( Σ_{i<t} e^{-(t-1-i)w + k_i}      +  e^{u+k_t} )

is evaluated with the official implementation's stable recurrence: carry
(a, b, o) where a/b are the exponent-shifted numerator/denominator sums and
o is the running max exponent, so no e^{·} ever overflows.

Shapes (channel-parallel, exactly the hardware's element-wise dataflow):
    k, v : (..., T, C)      w, u : (C,)   with w > 0 the decay rate
    state: a, b, o : (..., C)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class WKV4State(NamedTuple):
    a: jnp.ndarray  # shifted numerator
    b: jnp.ndarray  # shifted denominator
    o: jnp.ndarray  # running max exponent


def wkv4_init_state(batch_shape, channels: int, dtype=jnp.float32
                    ) -> WKV4State:
    shape = tuple(batch_shape) + (channels,)
    return WKV4State(
        a=jnp.zeros(shape, dtype),
        b=jnp.zeros(shape, dtype),
        o=jnp.full(shape, -1e38, dtype),
    )


def wkv4_step(state: WKV4State, k: jnp.ndarray, v: jnp.ndarray,
              w: jnp.ndarray, u: jnp.ndarray,
              *, exp=jnp.exp, div=None) -> tuple[WKV4State, jnp.ndarray]:
    """One decode step.  `exp`/`div` are injectable so the quantized model
    can substitute the paper's LUT units (repro.core.approx)."""
    a, b, o = state
    if div is None:
        div = lambda x, y: x / y
    # output: include the bonus u for the current token
    no = jnp.maximum(o, u + k)
    A = exp(o - no)
    B = exp(u + k - no)
    wkv = div(A * a + B * v, A * b + B)
    # state update: decay the history by w, absorb the current token
    no2 = jnp.maximum(o - w, k)
    A2 = exp(o - w - no2)
    B2 = exp(k - no2)
    new = WKV4State(a=A2 * a + B2 * v, b=A2 * b + B2, o=no2)
    return new, wkv


def wkv4_scan(k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
              u: jnp.ndarray, state: WKV4State | None = None,
              *, exp=jnp.exp, div=None
              ) -> tuple[jnp.ndarray, WKV4State]:
    """Sequence form: k, v are (..., T, C); scans over T (axis -2)."""
    T = k.shape[-2]
    C = k.shape[-1]
    if state is None:
        state = wkv4_init_state(k.shape[:-2], C, jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    u32 = u.astype(jnp.float32)

    def body(carry, kv):
        kt, vt = kv
        new, out = wkv4_step(carry, kt, vt, w32, u32, exp=exp, div=div)
        return new, out

    ks = jnp.moveaxis(k32, -2, 0)
    vs = jnp.moveaxis(v32, -2, 0)
    final, outs = jax.lax.scan(body, state, (ks, vs), length=T)
    return jnp.moveaxis(outs, 0, -2).astype(k.dtype), final
