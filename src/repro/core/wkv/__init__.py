"""Recurrent state operators.

  wkv4 — the paper's RWKV-4 WKV weighted average (Eq. 2), numerically-stable
         running-max form; scan (sequence) + single-step (decode) variants.
  wkv6 — RWKV-6 "Finch" data-dependent-decay linear attention; scan,
         single-step, and chunked (sub-quadratic prefill) variants.
  ssd  — Mamba-2 state-space-duality recurrence (scalar per-head decay) for
         the zamba2 hybrid; scan, single-step and chunked variants.
"""
from repro.core.wkv.wkv4 import (
    wkv4_scan, wkv4_step, WKV4State, wkv4_init_state)
from repro.core.wkv.wkv6 import (
    wkv6_scan, wkv6_step, wkv6_chunked, wkv6_init_state)
from repro.core.wkv.ssd import (
    ssd_scan, ssd_step, ssd_chunked, ssd_init_state)

__all__ = [
    "wkv4_scan", "wkv4_step", "WKV4State", "wkv4_init_state",
    "wkv6_scan", "wkv6_step", "wkv6_chunked", "wkv6_init_state",
    "ssd_scan", "ssd_step", "ssd_chunked", "ssd_init_state",
]
