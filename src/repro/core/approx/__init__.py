"""Complex-operation approximation units (paper §4.3–4.4).

Bit-accurate software models of the paper's hardware units:
  exp_lut      — e^x via base-2 transform + 256-entry fraction LUT
  sigmoid_pwl  — 4-segment piecewise-linear sigmoid with dyadic slopes
  div_lut      — LOD-normalized division with a 256-entry 2-D mantissa LUT
  lod          — hierarchical-binary-search leading-one detector
"""
from repro.core.approx.units import (
    exp_lut,
    sigmoid_pwl,
    div_lut,
    lod,
    EXP_LUT_TABLE,
    DIV_LUT_TABLE,
)

__all__ = ["exp_lut", "sigmoid_pwl", "div_lut", "lod",
           "EXP_LUT_TABLE", "DIV_LUT_TABLE"]
