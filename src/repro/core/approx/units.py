"""Software models of the paper's complex-operation hardware units.

These are *bit-accurate* models (matching the stated LUT sizes and index
widths), not fast paths: on TPU the VPU evaluates exp/sigmoid natively, so
the value of these units here is (a) faithfully reproducing the accelerator's
numerics for the quantized-model evaluation and (b) serving as oracles for
the Pallas kernels in `repro.kernels.expsig` / `repro.kernels.divlut`.

All units follow the paper's precision contract (§3.2): 9-bit I/O quantized
activations, 16-bit internal arithmetic. The models below operate on f32
carriers but round intermediates to the stated grids.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Exponential unit (mode=0 of the EXP–σ unit, §4.4)
#
#   e^x = 2^(x·log2 e);   y = u + v (integer + fraction);
#   2^u by shift, 2^v from a 256-entry LUT on the top-8 fraction bits.
#   The multiply by log2(e) ≈ 1.0111_2 is one add, one sub, two shifts:
#       x·log2e ≈ x + x>>2 + x>>3 + x>>4  (= x·1.4375; true value 1.442695)
#   The paper's ≈1.0111₂ = 1.4375 — we reproduce exactly that constant so the
#   model's error matches the hardware's.
# ---------------------------------------------------------------------------

_LOG2E_HW = 1.0 + 0.25 + 0.125 + 0.0625  # 1.0111_2 = 1.4375

# 256-entry fraction LUT: 2^(i/256) rounded to 8 fractional bits (paper:
# "eight-bit precision"), stored once as a module constant.
EXP_LUT_TABLE = np.round(np.exp2(np.arange(256) / 256.0) * 256.0) / 256.0
_EXP_LUT = jnp.asarray(EXP_LUT_TABLE, jnp.float32)


def exp_lut(x: jnp.ndarray, *, table: jnp.ndarray | None = None
            ) -> jnp.ndarray:
    """e^x per the paper's EXP unit.  Valid (as in hardware) for the WKV
    operator's argument range; inputs are clamped to the representable
    exponent window of the 16-bit internal format.

    `table` lets a caller supply the 256-entry fraction LUT as an explicit
    operand — the fused decode kernel must do this because Pallas kernels
    cannot capture array constants (the LUT becomes a VMEM-resident input,
    exactly the paper's on-chip table)."""
    x = jnp.asarray(x, jnp.float32)
    y = x * _LOG2E_HW
    # 16-bit internal: clamp the base-2 exponent so 2^u fits s7.8 arithmetic
    y = jnp.clip(y, -24.0, 24.0)
    u = jnp.floor(y)
    v = y - u
    idx = jnp.clip((v * 256.0).astype(jnp.int32), 0, 255)
    frac = (_EXP_LUT if table is None else table)[idx]
    return jnp.exp2(u) * frac


# ---------------------------------------------------------------------------
# Sigmoid unit (mode=1), paper Eq. (9): 4-segment PWL, dyadic slopes.
# ---------------------------------------------------------------------------

def sigmoid_pwl(x: jnp.ndarray) -> jnp.ndarray:
    x = jnp.asarray(x, jnp.float32)
    ax = jnp.abs(x)
    f = jnp.where(
        ax >= 5.0, 1.0,
        jnp.where(
            ax >= 2.375, 0.03125 * ax + 0.84375,
            jnp.where(ax >= 1.0, 0.125 * ax + 0.625, 0.25 * ax + 0.5)))
    return jnp.where(x >= 0, f, 1.0 - f)


# ---------------------------------------------------------------------------
# Leading-one detector (Algorithm 1): hierarchical binary search.
# Software model over int32 words; returns -1 for zero input, else the bit
# position of the most significant set bit.
# ---------------------------------------------------------------------------

def lod(x: jnp.ndarray, width: int = 16) -> jnp.ndarray:
    """Vectorized LOD via the paper's successive-halving loop."""
    x = jnp.asarray(x, jnp.int32)
    d = x & ((1 << width) - 1) if width < 32 else x
    p = jnp.zeros_like(d)
    w = width
    while w > 1:
        h = w // 2
        upper = d >> h
        has_upper = upper != 0
        p = jnp.where(has_upper, p + h, p)
        d = jnp.where(has_upper, upper, d & ((1 << h) - 1))
        w = h
    return jnp.where(x == 0, -1, p)


# ---------------------------------------------------------------------------
# Unsigned division unit (§4.3):
#   X = 2^k1·x, Y = 2^k2·y with 1 <= x,y < 2;
#   Q = (x/y) << (k1 - k2);   x/y from a 256-entry 2-D LUT indexed by the
#   4 MSBs after the leading one of x and y, 8-bit quotient precision.
# ---------------------------------------------------------------------------

def _build_div_lut() -> np.ndarray:
    """table[i, j] ≈ (1 + (i+0.5)/16) / (1 + (j+0.5)/16), 8-bit rounded.

    Midpoint-of-bin evaluation (i+0.5) is the standard LUT construction and
    halves the worst-case error vs. bin-left-edge.
    """
    i = (1.0 + (np.arange(16)[:, None] + 0.5) / 16.0)
    j = (1.0 + (np.arange(16)[None, :] + 0.5) / 16.0)
    t = i / j
    return np.round(t * 256.0) / 256.0


DIV_LUT_TABLE = _build_div_lut()
_DIV_LUT = jnp.asarray(DIV_LUT_TABLE.reshape(-1), jnp.float32)


def div_lut(x: jnp.ndarray, y: jnp.ndarray, *,
            table: jnp.ndarray | None = None) -> jnp.ndarray:
    """x / y per the paper's DIVU, generalized to f32 carriers.

    Signs are separated first (the unit is unsigned); magnitudes are
    decomposed with frexp (the LOD+normalize step), the mantissa ratio comes
    from the 2-D LUT, and the exponent difference is applied as a shift.
    Division by (quantized) zero saturates, as hardware would.
    `table` (flat 256-entry) has the same role as in `exp_lut`: an explicit
    operand for Pallas kernels that cannot capture array constants.
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    sign = jnp.sign(x) * jnp.where(y < 0, -1.0, 1.0)
    ax, ay = jnp.abs(x), jnp.abs(y)
    mx, ex = jnp.frexp(jnp.maximum(ax, 1e-38))   # m in [0.5, 1)
    my, ey = jnp.frexp(jnp.maximum(ay, 1e-38))
    # convert to [1, 2) normalization as in the paper
    mx, ex = mx * 2.0, ex - 1
    my, ey = my * 2.0, ey - 1
    ix = jnp.clip(((mx - 1.0) * 16.0).astype(jnp.int32), 0, 15)
    iy = jnp.clip(((my - 1.0) * 16.0).astype(jnp.int32), 0, 15)
    frac = (_DIV_LUT if table is None else table)[ix * 16 + iy]
    q = frac * jnp.exp2((ex - ey).astype(jnp.float32))
    q = jnp.where(ay <= 0, jnp.float32(2.0**15), q)  # saturate on div-by-0
    q = jnp.where(ax <= 0, 0.0, q)
    return sign * q
