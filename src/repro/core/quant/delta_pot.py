"""Δ-PoT quantization (paper §3.1).

A quantized level is a sum of n powers-of-two terms

    w_q = sign(w) * 2γ * Σ_{i<n} p_i ,   p_i ∈ {0, p_{i-1}·2^{-1}, …, p_{i-1}·2^{-(2^{k_i}-1)}},  p_{-1} = 1

and what is *stored* is the differential exponent Δq_i = q_i − q_{i-1}
(k_i bits per term; Δq_i = 0 encodes "term absent", which also zeroes every
later term).  Compared to APoT with fixed k = b/n, Δ-PoT allows distinct k_i
per term and covers a wider dynamic range at the same bit budget.

Implementation notes
--------------------
* `DPotFormat(ks)` fixes the per-term widths, e.g. ks=(4, 4) is the paper's
  "proposed" 8-code-bit format (9 bits with sign — the W9 row of Table 1);
  ks=(3, 4) is the 7-code-bit variant that packs *with* its sign into one
  int8 word for the Pallas serving kernel; ks=(4,) degenerates to plain PoT.
* Levels are enumerated once per format (≤ 2^8 = 256 entries) and quantization
  is nearest-level via `searchsorted` on midpoints — exact nearest rounding.
* The scale γ is chosen per-channel (`axis` = the *output*-channel axis of a
  weight matrix) so that the maximum representable level hits the channel's
  max |w|; an optional MSE grid-search refines it, matching how the paper
  calibrates ("algorithmically refined to balance precision and resources").
* `dpot_fake_quant` is the straight-through-estimator version used for the
  Table-1 accuracy ablation and for QAT-style experiments.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DPotFormat:
    """Static description of a Δ-PoT code format."""

    ks: tuple[int, ...] = (4, 4)

    @property
    def n_terms(self) -> int:
        return len(self.ks)

    @property
    def code_bits(self) -> int:
        return int(sum(self.ks))

    @property
    def total_bits(self) -> int:
        """Code bits + 1 sign bit (what HBM traffic accounting should use)."""
        return self.code_bits + 1

    @property
    def n_codes(self) -> int:
        return 1 << self.code_bits

    def __post_init__(self):
        if not self.ks:
            raise ValueError("need at least one term")
        if any(k < 1 for k in self.ks):
            raise ValueError(f"term widths must be >= 1, got {self.ks}")
        if self.code_bits > 8:
            raise ValueError(
                f"code bits {self.code_bits} > 8 unsupported (uint8 storage)")


# The paper's formats --------------------------------------------------------
#   W9 "proposed": sign + ks=(4,4)  -> Table-1 accuracy row
#   W8 kernel fmt: sign + ks=(3,4)  -> packs into a single int8 for Pallas
#   W4 sub-byte  : sign + ks=(3,)   -> TWO weights per uint8 (nibble pair);
#                  the RWKVQuant-direction bandwidth plane — single-term PoT
#                  levels {0, 2^-1 .. 2^-7}, half the slab traffic of W8
FORMAT_W9 = DPotFormat(ks=(4, 4))
FORMAT_W8 = DPotFormat(ks=(3, 4))
FORMAT_W4 = DPotFormat(ks=(3,))
FORMAT_POT4 = DPotFormat(ks=(4,))  # degenerate single-term = classic PoT


@functools.lru_cache(maxsize=None)
def _level_table_np(ks: tuple[int, ...]) -> np.ndarray:
    """All 2^Σk levels (unsigned, before the 2γ scale), indexed by code.

    Code layout: term 0 in the LOW k0 bits, term 1 in the next k1 bits, …
    (low-to-high), so decoding is successive shift/mask — identical to the
    paper's hardware decoder which peels terms off a shift register.
    """
    n = len(ks)
    n_codes = 1 << sum(ks)
    levels = np.zeros((n_codes,), dtype=np.float64)
    for code in range(n_codes):
        c = code
        p_prev = 1.0
        total = 0.0
        alive = True
        for i in range(n):
            dq = c & ((1 << ks[i]) - 1)
            c >>= ks[i]
            if not alive or dq == 0:
                alive = False
                continue
            p_i = p_prev * (2.0 ** (-dq))
            total += p_i
            p_prev = p_i
        levels[code] = total
    return levels


@functools.lru_cache(maxsize=None)
def _sorted_levels_np(ks: tuple[int, ...]):
    """(sorted unique levels, code for each sorted level, midpoints)."""
    levels = _level_table_np(ks)
    order = np.argsort(levels, kind="stable")
    sorted_levels = levels[order]
    # Dedup keeping the first (lowest) code for each distinct level.
    uniq_mask = np.ones_like(sorted_levels, dtype=bool)
    uniq_mask[1:] = sorted_levels[1:] != sorted_levels[:-1]
    sorted_levels = sorted_levels[uniq_mask]
    codes = order[uniq_mask].astype(np.int32)
    mids = 0.5 * (sorted_levels[1:] + sorted_levels[:-1])
    return sorted_levels, codes, mids


def dpot_levels(fmt: DPotFormat) -> jnp.ndarray:
    """Dense code→level table (length 2^code_bits), unsigned, pre-scale."""
    return jnp.asarray(_level_table_np(fmt.ks), dtype=jnp.float32)


def dpot_max_level(fmt: DPotFormat) -> float:
    return float(_level_table_np(fmt.ks).max())


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DPotQuantized:
    """A Δ-PoT-quantized tensor.

    codes : uint8, same shape as the original tensor (Δq terms packed
            low-to-high, sign NOT included)
    signs : int8 in {-1, +1}, same shape
    scale : f32, broadcastable to the tensor shape (per-channel 2γ absorbed)
    """

    codes: jnp.ndarray
    signs: jnp.ndarray
    scale: jnp.ndarray
    ks: tuple[int, ...] = (4, 4)

    @property
    def fmt(self) -> DPotFormat:
        return DPotFormat(self.ks)

    @property
    def shape(self):
        return self.codes.shape

    def nbytes_hardware(self) -> int:
        """HBM footprint at the *hardware* packing (code_bits+1 per weight,
        plus one f32 scale per channel)."""
        n = int(np.prod(self.codes.shape))
        return (n * self.fmt.total_bits + 7) // 8 + self.scale.size * 4

    def tree_flatten(self):
        return (self.codes, self.signs, self.scale), (self.ks,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, signs, scale = children
        return cls(codes=codes, signs=signs, scale=scale, ks=aux[0])


def _choose_scale(absw: jnp.ndarray, axis, fmt: DPotFormat,
                  mse_search: bool, x_for_mse: jnp.ndarray | None):
    """Per-channel scale s = 2γ so that s * max_level covers max|w|."""
    if axis is None:
        amax = jnp.max(absw)
        keep_shape = ()
    else:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        reduce_axes = tuple(i for i in range(absw.ndim) if i not in
                            tuple(a % absw.ndim for a in axes))
        amax = jnp.max(absw, axis=reduce_axes, keepdims=True)
    max_lvl = dpot_max_level(fmt)
    base = amax / max_lvl
    base = jnp.where(base <= 0, 1.0, base)
    if not mse_search:
        return base
    # grid-search a multiplicative refinement of the scale, minimizing MSE
    cands = jnp.asarray([0.6, 0.7, 0.8, 0.9, 1.0, 1.1, 1.2], jnp.float32)

    def err_for(c):
        s = base * c
        q = _nearest_level(x_for_mse / s, fmt) * s
        d = (q - x_for_mse) ** 2
        if axis is None:
            return jnp.sum(d)
        return jnp.sum(d, axis=reduce_axes, keepdims=True)

    errs = jnp.stack([err_for(c) for c in cands], axis=0)
    best = jnp.argmin(errs, axis=0)
    return base * cands[best]


def _nearest_level(x_abs_scaled: jnp.ndarray, fmt: DPotFormat) -> jnp.ndarray:
    """Map |x|/s to the nearest representable level value (not the code)."""
    sorted_levels, _, mids = _sorted_levels_np(fmt.ks)
    lv = jnp.asarray(sorted_levels, jnp.float32)
    md = jnp.asarray(mids, jnp.float32)
    idx = jnp.searchsorted(md, x_abs_scaled.astype(jnp.float32))
    return lv[idx]


def _nearest_code(x_abs_scaled: jnp.ndarray, fmt: DPotFormat) -> jnp.ndarray:
    sorted_levels, codes, mids = _sorted_levels_np(fmt.ks)
    cd = jnp.asarray(codes, jnp.int32)
    md = jnp.asarray(mids, jnp.float32)
    idx = jnp.searchsorted(md, x_abs_scaled.astype(jnp.float32))
    return cd[idx].astype(jnp.uint8)


def dpot_quantize(w: jnp.ndarray, fmt: DPotFormat = FORMAT_W9, *,
                  axis: int | None = 0, mse_search: bool = False
                  ) -> DPotQuantized:
    """Quantize a weight tensor to Δ-PoT codes.

    axis: the output-channel axis that receives an independent scale
          (None = a single tensor-wide scale).
    """
    w = jnp.asarray(w, jnp.float32)
    absw = jnp.abs(w)
    scale = _choose_scale(absw, axis, fmt, mse_search, w)
    codes = _nearest_code(absw / scale, fmt)
    signs = jnp.where(w < 0, -1, 1).astype(jnp.int8)
    return DPotQuantized(codes=codes, signs=signs, scale=scale, ks=fmt.ks)


def dpot_decode_codes(codes: jnp.ndarray, ks: Sequence[int]) -> jnp.ndarray:
    """Vectorized code → level decode (the VPU analogue of the paper's
    shift-register decoder): peel Δq_i terms, accumulate 2^(−Σ Δq)."""
    ks = tuple(ks)
    c = codes.astype(jnp.int32)
    total = jnp.zeros(codes.shape, jnp.float32)
    q_cum = jnp.zeros(codes.shape, jnp.float32)
    alive = jnp.ones(codes.shape, dtype=bool)
    for k in ks:
        dq = c & ((1 << k) - 1)
        c = c >> k
        alive = alive & (dq > 0)
        q_cum = q_cum + dq.astype(jnp.float32)
        term = jnp.where(alive, jnp.exp2(-q_cum), 0.0)
        total = total + term
        # freeze q_cum growth once dead (harmless either way since term is 0,
        # but keeps exponents small)
        q_cum = jnp.where(alive, q_cum, q_cum)
    return total


def dpot_dequantize(q: DPotQuantized) -> jnp.ndarray:
    lvl = dpot_decode_codes(q.codes, q.ks)
    return q.signs.astype(jnp.float32) * lvl * q.scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def dpot_fake_quant(w, ks: tuple[int, ...] = (4, 4), axis: int | None = 0,
                    mse_search: bool = False):
    """quantize→dequantize with a straight-through gradient."""
    fmt = DPotFormat(tuple(ks))
    q = dpot_quantize(w, fmt, axis=axis, mse_search=mse_search)
    return dpot_dequantize(q).astype(w.dtype)


def _fq_fwd(w, ks, axis, mse_search):
    return dpot_fake_quant(w, ks, axis, mse_search), None


def _fq_bwd(ks, axis, mse_search, _, g):
    return (g,)


dpot_fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# Hardware packing: sign+codes in one int8 word (requires code_bits <= 7).
# Bit layout (matching the paper's "concatenated off-chip, decoded on-chip"):
#   bit 7   : sign (1 = negative)
#   bits 6:0: code (term 0 in low bits)
# ---------------------------------------------------------------------------

def dpot_pack_int8(q: DPotQuantized) -> jnp.ndarray:
    fmt = q.fmt
    if fmt.code_bits > 7:
        raise ValueError(
            f"format {fmt.ks} needs {fmt.code_bits} code bits; only <=7 pack "
            "into int8 with the sign — use FORMAT_W8 (ks=(3,4)) for kernels")
    sign_bit = (q.signs < 0).astype(jnp.uint8) << 7
    return (q.codes | sign_bit).astype(jnp.uint8)


def dpot_unpack_int8(packed: jnp.ndarray, scale: jnp.ndarray,
                     ks: Sequence[int]) -> DPotQuantized:
    ks = tuple(ks)
    codes = (packed & 0x7F).astype(jnp.uint8)
    signs = jnp.where((packed >> 7) & 1, -1, 1).astype(jnp.int8)
    return DPotQuantized(codes=codes, signs=signs, scale=scale, ks=ks)


# ---------------------------------------------------------------------------
# Sub-byte packing: TWO sign+code nibbles per uint8 (requires code_bits <= 3).
# Nibble layout mirrors the int8 word at quarter width:
#   bit 3   : sign (1 = negative)
#   bits 2:0: code (term 0 in low bits)
# Elements pair along axis -2 — the CONTRACTION axis of a (K, N) weight — so
# row 2k lands in the low nibble and row 2k+1 in the high nibble of packed
# row k, and the output-channel axis (per-channel scales, slab column
# layout) is untouched.  A (K, N) weight becomes a (K/2, N) uint8 plane:
# half the HBM bytes of the int8 packing above.
# ---------------------------------------------------------------------------


def dpot_pack_nibbles(q: DPotQuantized) -> jnp.ndarray:
    fmt = q.fmt
    if fmt.code_bits > 3:
        raise ValueError(
            f"format {fmt.ks} needs {fmt.code_bits} code bits; only <=3 pack "
            "into a nibble with the sign — use FORMAT_W4 (ks=(3,))")
    if q.codes.ndim < 2 or q.codes.shape[-2] % 2 != 0:
        raise ValueError(
            f"nibble packing pairs along axis -2; shape {q.codes.shape} "
            "needs >= 2 dims and an even axis -2")
    word = (q.codes | ((q.signs < 0).astype(jnp.uint8) << 3)).astype(
        jnp.uint8)
    lo = word[..., 0::2, :]
    hi = word[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.uint8)


def dpot_unpack_nibbles(packed: jnp.ndarray, scale: jnp.ndarray,
                        ks: Sequence[int]) -> DPotQuantized:
    ks = tuple(ks)
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    words = jnp.stack([lo, hi], axis=-2)           # (..., K/2, 2, N)
    full = packed.shape[:-2] + (2 * packed.shape[-2], packed.shape[-1])
    words = words.reshape(full)                    # rows re-interleave
    codes = (words & 0x7).astype(jnp.uint8)
    signs = jnp.where((words >> 3) & 1, -1, 1).astype(jnp.int8)
    return DPotQuantized(codes=codes, signs=signs, scale=scale, ks=ks)
