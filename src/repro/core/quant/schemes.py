"""Baseline quantization schemes for the Table-1 ablation.

The paper compares its Δ-PoT scheme against three baselines, all "simulating
the precision loss of an equivalent W9A9 quantization":

  RTN  — round-to-nearest uniform symmetric (Jacob et al. 2017)
  PoT  — single power-of-two level per weight (INQ, Zhou et al. 2017)
  LogQ — logarithmic quantization with a fractional log step
         (LogNet, Lee et al. 2017 / Cai et al. 2018)

Each is exposed as a fake-quant `f(w, bits, axis) -> w_hat` so the ablation
harness can swap schemes over the same model.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant.uniform import uniform_fake_quant
from repro.core.quant.delta_pot import dpot_fake_quant, DPotFormat


def rtn_fake_quant(w: jnp.ndarray, bits: int = 9, axis=None) -> jnp.ndarray:
    """Round-to-nearest uniform — identical to uniform symmetric quant."""
    return uniform_fake_quant(w, bits, axis)


def _amax(x, axis):
    if axis is None:
        return jnp.max(jnp.abs(x))
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    reduce_axes = tuple(i for i in range(x.ndim)
                        if i not in tuple(a % x.ndim for a in axes))
    return jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)


def pot_fake_quant(w: jnp.ndarray, bits: int = 9, axis=None) -> jnp.ndarray:
    """Single-term powers-of-two: w_hat = s * sign(w) * 2^round(log2|w|/s).

    The exponent is clipped to the (bits-1)-bit range below the per-channel
    max, and an all-zero code exists for |w| below the smallest level — the
    standard PoT grid {0} ∪ {±s·2^-e : e ∈ [0, 2^(bits-1)-2]}.
    """
    w32 = jnp.asarray(w, jnp.float32)
    s = _amax(w32, axis)
    s = jnp.where(s <= 0, 1.0, s)
    n_exp = (1 << (bits - 1)) - 1  # exponent codes incl. the zero code
    a = jnp.abs(w32) / s
    loga = jnp.log2(jnp.maximum(a, 1e-38))
    e = jnp.clip(jnp.round(-loga), 0, n_exp - 1)
    lvl = jnp.exp2(-e)
    # zero code: values closer to 0 than to the smallest level
    smallest = 2.0 ** (-(n_exp - 1))
    lvl = jnp.where(a < smallest / 2, 0.0, lvl)
    return (jnp.sign(w32) * lvl * s).astype(w.dtype)


def logq_fake_quant(w: jnp.ndarray, bits: int = 9, axis=None,
                    log_step: float = 0.5) -> jnp.ndarray:
    """Logarithmic quantization with fractional step: levels s·2^(-i·step).

    With step < 1 the grid is denser than PoT near the max (LogNet's
    "finer-grained log" variant); still a single multiplicative level so the
    hardware cost story matches the paper's LogQ row.
    """
    w32 = jnp.asarray(w, jnp.float32)
    s = _amax(w32, axis)
    s = jnp.where(s <= 0, 1.0, s)
    n_codes = (1 << (bits - 1)) - 1
    a = jnp.abs(w32) / s
    loga = jnp.log2(jnp.maximum(a, 1e-38)) / log_step
    i = jnp.clip(jnp.round(-loga), 0, n_codes - 1)
    lvl = jnp.exp2(-i * log_step)
    smallest = 2.0 ** (-(n_codes - 1) * log_step)
    lvl = jnp.where(a < smallest / 2, 0.0, lvl)
    return (jnp.sign(w32) * lvl * s).astype(w.dtype)


def proposed_fake_quant(w: jnp.ndarray, bits: int = 9, axis=None
                        ) -> jnp.ndarray:
    """The paper's scheme at the Table-1 operating point: Δ-PoT with
    sign + ks=(4,4) (9 bits total) and per-channel MSE-refined scales."""
    del bits  # fixed by the format
    return dpot_fake_quant(w, (4, 4), axis, True)


# name -> fake-quant fn, as compared in Table 1
SCHEMES = {
    "fp": lambda w, bits=9, axis=None: w,
    "rtn": rtn_fake_quant,
    "pot": pot_fake_quant,
    "logq": logq_fake_quant,
    "proposed": proposed_fake_quant,
}
