"""Mixed-precision quantization policy over a parameter tree (paper §3.2).

Classification rule (the paper's):
  * weights that MULTIPLY activations (all ≥2-D projection matrices,
    including MoE expert tensors)            -> Δ-PoT
  * weights used ADDITIVELY or element-wise (token-shift μ, decay w, bonus u,
    LayerNorm γ/β, biases — everything 1-D)  -> 9-bit uniform symmetric
  * embedding tables (gather, no multiply)   -> 9-bit uniform symmetric
  * activations                              -> 9-bit uniform (applied inside
    the quantized model's forward pass, not here)

The classifier is path-based with a ndim fallback so it works on any of the
registered architectures' parameter trees without per-model code.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.quant.delta_pot import (
    DPotFormat, FORMAT_W9, dpot_quantize, dpot_fake_quant, DPotQuantized,
)
from repro.core.quant.uniform import (
    uniform_quantize, uniform_fake_quant, uniform_dequantize,
)

# path substrings that force the uniform branch even for 2-D tensors
_ADDITIVE_HINTS = re.compile(
    r"(embed|emb_|ln|norm|scale|bias|mu_|time_mix|time_decay|time_first|"
    r"decay|bonus|gamma|beta|_shift|pos_emb|a_log|dt_bias|conv)",
    re.IGNORECASE,
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """The mixed-precision operating point."""

    matmul_fmt: DPotFormat = FORMAT_W9   # Δ-PoT format for projection matrices
    additive_bits: int = 9               # uniform bits for additive weights
    activation_bits: int = 9             # uniform bits for activations
    channel_axis: int = -1               # per-output-channel scales
    mse_search: bool = False

    def act_fq(self, x: jnp.ndarray) -> jnp.ndarray:
        """Activation fake-quant, per-tensor (the paper's A9)."""
        return uniform_fake_quant(x, self.activation_bits, None)


def classify_param(path: str, leaf: Any) -> str:
    """'matmul' | 'additive' | 'skip' for a parameter leaf."""
    if not hasattr(leaf, "ndim"):
        return "skip"
    if leaf.ndim < 2:
        return "additive"
    if _ADDITIVE_HINTS.search(path):
        return "additive"
    return "matmul"


def _iter_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf
    return treedef


def fake_quantize_tree(params, policy: QuantPolicy = QuantPolicy()):
    """quantize→dequantize every weight per the policy (for accuracy evals).

    Returns a tree with the same structure/dtypes as `params`.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        p = jax.tree_util.keystr(path)
        kind = classify_param(p, leaf)
        if kind == "matmul":
            out.append(dpot_fake_quant(
                leaf, policy.matmul_fmt.ks, policy.channel_axis,
                policy.mse_search))
        elif kind == "additive":
            out.append(uniform_fake_quant(leaf, policy.additive_bits, None))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def fake_quantize_tree_with(params, scheme_fn: Callable, bits: int = 9,
                            axis=None):
    """Apply an arbitrary Table-1 scheme to every matmul weight; additive
    weights always get W9 uniform (the paper quantizes those uniformly under
    every scheme — the ablation varies only the matrix-weight scheme)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        p = jax.tree_util.keystr(path)
        kind = classify_param(p, leaf)
        if kind == "matmul":
            out.append(scheme_fn(leaf, bits, axis))
        elif kind == "additive":
            out.append(uniform_fake_quant(leaf, 9, None))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def quantize_tree(params, policy: QuantPolicy = QuantPolicy()):
    """Real quantization for the serving path: matmul weights become
    DPotQuantized containers, additive weights (codes, scale) pairs.

    Returns (quantized_tree, stats) where stats has byte accounting used by
    the Table-2 style resource benchmark.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    bytes_fp16 = 0
    bytes_quant = 0
    for path, leaf in flat:
        p = jax.tree_util.keystr(path)
        kind = classify_param(p, leaf)
        if kind == "skip":
            out.append(leaf)
            continue
        bytes_fp16 += leaf.size * 2
        if kind == "matmul":
            q = dpot_quantize(leaf, policy.matmul_fmt,
                              axis=policy.channel_axis,
                              mse_search=policy.mse_search)
            bytes_quant += q.nbytes_hardware()
            out.append(q)
        else:
            codes, scale = uniform_quantize(leaf, policy.additive_bits,
                                            axis=None)
            bytes_quant += (leaf.size * policy.additive_bits + 7) // 8 + 4
            out.append({"codes": codes.astype(jnp.int16), "scale": scale})
    stats = {"bytes_fp16": bytes_fp16, "bytes_quant": bytes_quant,
             "compression": bytes_fp16 / max(bytes_quant, 1)}
    return jax.tree_util.tree_unflatten(treedef, out), stats


def dequantize_tree(qparams):
    """Inverse of quantize_tree (reference path for tests)."""
    def deq(leaf):
        if isinstance(leaf, DPotQuantized):
            from repro.core.quant.delta_pot import dpot_dequantize
            return dpot_dequantize(leaf)
        return leaf

    def deq_dict(leaf):
        if isinstance(leaf, dict) and set(leaf) == {"codes", "scale"}:
            return uniform_dequantize(leaf["codes"], leaf["scale"])
        return leaf

    tree = jax.tree_util.tree_map(
        deq, qparams, is_leaf=lambda x: isinstance(x, DPotQuantized))
    return jax.tree_util.tree_map(
        deq_dict, tree,
        is_leaf=lambda x: isinstance(x, dict) and set(x) == {"codes", "scale"})
