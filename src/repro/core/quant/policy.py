"""Mixed-precision quantization policy over a parameter tree (paper §3.2).

Classification rule (the paper's):
  * weights that MULTIPLY activations (all ≥2-D projection matrices,
    including MoE expert tensors)            -> Δ-PoT
  * weights used ADDITIVELY or element-wise (token-shift μ, decay w, bonus u,
    LayerNorm γ/β, biases — everything 1-D)  -> 9-bit uniform symmetric
  * embedding tables (gather, no multiply)   -> 9-bit uniform symmetric
  * activations                              -> 9-bit uniform (applied inside
    the quantized model's forward pass, not here)

The classifier is path-based with a ndim fallback so it works on any of the
registered architectures' parameter trees without per-model code.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.quant.delta_pot import (
    DPotFormat, FORMAT_W9, dpot_quantize, dpot_fake_quant, DPotQuantized,
)
from repro.core.quant.uniform import (
    uniform_quantize, uniform_fake_quant, uniform_dequantize,
)

# path substrings that force the uniform branch even for 2-D tensors
_ADDITIVE_HINTS = re.compile(
    r"(embed|emb_|ln|norm|scale|bias|mu_|time_mix|time_decay|time_first|"
    r"decay|bonus|gamma|beta|_shift|pos_emb|a_log|dt_bias|conv)",
    re.IGNORECASE,
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """The mixed-precision operating point."""

    matmul_fmt: DPotFormat = FORMAT_W9   # Δ-PoT format for projection matrices
    additive_bits: int = 9               # uniform bits for additive weights
    activation_bits: int = 9             # uniform bits for activations
    channel_axis: int = -1               # per-output-channel scales
    mse_search: bool = False

    def act_fq(self, x: jnp.ndarray) -> jnp.ndarray:
        """Activation fake-quant, per-tensor (the paper's A9)."""
        return uniform_fake_quant(x, self.activation_bits, None)


def classify_param(path: str, leaf: Any) -> str:
    """'matmul' | 'additive' | 'skip' for a parameter leaf."""
    if not hasattr(leaf, "ndim"):
        return "skip"
    if leaf.ndim < 2:
        return "additive"
    if _ADDITIVE_HINTS.search(path):
        return "additive"
    return "matmul"


# ---------------------------------------------------------------------------
# Per-tensor plane selection (RWKVQuant direction, arXiv 2505.03803): pick
# scalar-W8 / scalar-W4 / VQ per matmul tensor with a cheap weight-outlier
# proxy.  Scalar Δ-PoT sets each channel's scale from its max |w|, so a few
# extreme weights crush the resolution of everything else in the channel —
# outlier-heavy tensors want a codebook (VQ); well-behaved near-Gaussian
# tensors tolerate the 4-bit single-term format; the middle keeps W8.
# ---------------------------------------------------------------------------

PLANES = ("w8", "w4", "vq")


def weight_outlier_proxy(w, sample: int = 1 << 16) -> float:
    """Excess kurtosis of the weight distribution — the outlier/curvature
    proxy.  ~0 for Gaussian weights, large and positive for heavy tails
    (the regime where per-channel scalar scales degrade).  Deterministic
    strided subsample keeps it cheap on big tensors."""
    import numpy as np
    v = np.asarray(w, np.float32).reshape(-1)
    if v.size > sample:
        v = v[:: (v.size + sample - 1) // sample]
    v = v - v.mean()
    var = float((v * v).mean())
    if var <= 0:
        return 0.0
    return float((v ** 4).mean() / (var * var) - 3.0)


@dataclasses.dataclass(frozen=True)
class PlanePolicy:
    """Which quantized plane each matmul tensor gets.

    default       — "proxy" (threshold `weight_outlier_proxy`) or a fixed
                    plane name ("w8" | "w4" | "vq")
    w4_max_proxy  — proxy <= this -> W4 (well-behaved tails)
    vq_min_proxy  — proxy >= this -> VQ (outlier-heavy); between the two
                    thresholds the tensor keeps scalar W8
    vq_codes      — codebook entries (<= 256, uint8 indices)
    overrides     — ((path regex, plane), ...) checked first, in order

    Serializes to/from a plain dict (`to_config` / `from_config`) so a
    snapshot's `build_config` can rebuild the exact same per-tensor
    selection — part of the plane-policy fingerprint that keys the prefix
    cache (serving.plan.ExecutionPlan.cache_variant)."""

    default: str = "proxy"
    w4_max_proxy: float = 1.5
    vq_min_proxy: float = 8.0
    vq_codes: int = 256
    overrides: tuple = ()

    def __post_init__(self):
        if self.default not in PLANES + ("proxy",):
            raise ValueError(f"default={self.default!r}: expected one of "
                             f"{PLANES + ('proxy',)}")
        for pat, plane in self.overrides:
            if plane not in PLANES:
                raise ValueError(f"override {pat!r} -> {plane!r}: expected "
                                 f"one of {PLANES}")

    def plane_for(self, path: str, leaf) -> str:
        """The plane for one matmul leaf (callers classify first)."""
        for pat, plane in self.overrides:
            if re.search(pat, path):
                return plane
        if self.default != "proxy":
            return self.default
        p = weight_outlier_proxy(leaf)
        if p >= self.vq_min_proxy:
            return "vq"
        if p <= self.w4_max_proxy:
            return "w4"
        return "w8"

    def to_config(self) -> dict:
        return {"default": self.default,
                "w4_max_proxy": float(self.w4_max_proxy),
                "vq_min_proxy": float(self.vq_min_proxy),
                "vq_codes": int(self.vq_codes),
                "overrides": [list(o) for o in self.overrides]}

    @classmethod
    def from_config(cls, cfg) -> "PlanePolicy | None":
        if cfg is None:
            return None
        return cls(default=cfg["default"],
                   w4_max_proxy=cfg["w4_max_proxy"],
                   vq_min_proxy=cfg["vq_min_proxy"],
                   vq_codes=cfg["vq_codes"],
                   overrides=tuple(tuple(o) for o in cfg["overrides"]))


# Presets: the ablation sweep's named operating points.
PLANE_W8 = PlanePolicy(default="w8")
PLANE_W4 = PlanePolicy(default="w4")     # bandwidth point (nibble planes)
PLANE_VQ = PlanePolicy(default="vq")     # accuracy fallback (codebooks)
PLANE_PROXY = PlanePolicy()              # RWKVQuant-style mixed selection


def _iter_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf
    return treedef


def fake_quantize_tree(params, policy: QuantPolicy = QuantPolicy()):
    """quantize→dequantize every weight per the policy (for accuracy evals).

    Returns a tree with the same structure/dtypes as `params`.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        p = jax.tree_util.keystr(path)
        kind = classify_param(p, leaf)
        if kind == "matmul":
            out.append(dpot_fake_quant(
                leaf, policy.matmul_fmt.ks, policy.channel_axis,
                policy.mse_search))
        elif kind == "additive":
            out.append(uniform_fake_quant(leaf, policy.additive_bits, None))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def fake_quantize_tree_with(params, scheme_fn: Callable, bits: int = 9,
                            axis=None):
    """Apply an arbitrary Table-1 scheme to every matmul weight; additive
    weights always get W9 uniform (the paper quantizes those uniformly under
    every scheme — the ablation varies only the matrix-weight scheme)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        p = jax.tree_util.keystr(path)
        kind = classify_param(p, leaf)
        if kind == "matmul":
            out.append(scheme_fn(leaf, bits, axis))
        elif kind == "additive":
            out.append(uniform_fake_quant(leaf, 9, None))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def quantize_tree(params, policy: QuantPolicy = QuantPolicy(), *,
                  planes: "PlanePolicy | None" = None):
    """Real quantization for the serving path: matmul weights become
    DPotQuantized containers, additive weights (codes, scale) pairs.

    With `planes`, each matmul tensor's format follows the per-tensor
    plane selection instead of the single `policy.matmul_fmt`: "w8" keeps
    FORMAT_W8 scalar codes, "w4" the 4-bit FORMAT_W4 (byte accounting at
    4 bits/weight), "vq" a `{"vq_idx", "codebook"}` pair (1 byte/weight +
    the codebook).  Stats gain a per-plane breakdown and the selection map.

    Returns (quantized_tree, stats) where stats has byte accounting used by
    the Table-2 style resource benchmark.
    """
    from repro.core.quant.delta_pot import FORMAT_W4, FORMAT_W8
    from repro.core.quant.vq import vq_quantize
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    bytes_fp16 = 0
    bytes_quant = 0
    by_plane: dict = {}
    plane_map: dict = {}
    for path, leaf in flat:
        p = jax.tree_util.keystr(path)
        kind = classify_param(p, leaf)
        if kind == "skip":
            out.append(leaf)
            continue
        bytes_fp16 += leaf.size * 2
        if kind == "matmul":
            if planes is None:
                q = dpot_quantize(leaf, policy.matmul_fmt,
                                  axis=policy.channel_axis,
                                  mse_search=policy.mse_search)
                bytes_quant += q.nbytes_hardware()
                out.append(q)
                continue
            plane = planes.plane_for(p, leaf)
            if plane == "w4" and (leaf.ndim < 2 or leaf.shape[-2] % 2):
                plane = "w8"        # nibble pairing needs an even axis -2
            if plane == "vq":
                idx, codebook = vq_quantize(leaf, planes.vq_codes)
                nb = idx.size + codebook.size * 2
                out.append({"vq_idx": idx, "codebook": codebook})
            else:
                fmt = FORMAT_W4 if plane == "w4" else FORMAT_W8
                q = dpot_quantize(leaf, fmt, axis=policy.channel_axis,
                                  mse_search=policy.mse_search)
                nb = q.nbytes_hardware()
                out.append(q)
            plane_map[p] = plane
            by_plane[plane] = by_plane.get(plane, 0) + nb
            bytes_quant += nb
        else:
            codes, scale = uniform_quantize(leaf, policy.additive_bits,
                                            axis=None)
            bytes_quant += (leaf.size * policy.additive_bits + 7) // 8 + 4
            out.append({"codes": codes.astype(jnp.int16), "scale": scale})
    stats = {"bytes_fp16": bytes_fp16, "bytes_quant": bytes_quant,
             "compression": bytes_fp16 / max(bytes_quant, 1)}
    if planes is not None:
        stats["bytes_by_plane"] = by_plane
        stats["planes"] = plane_map
    return jax.tree_util.tree_unflatten(treedef, out), stats


def dequantize_tree(qparams):
    """Inverse of quantize_tree (reference path for tests)."""
    def deq(leaf):
        if isinstance(leaf, DPotQuantized):
            from repro.core.quant.delta_pot import dpot_dequantize
            return dpot_dequantize(leaf)
        return leaf

    def deq_dict(leaf):
        if isinstance(leaf, dict) and set(leaf) == {"codes", "scale"}:
            return uniform_dequantize(leaf["codes"], leaf["scale"])
        if isinstance(leaf, dict) and set(leaf) == {"vq_idx", "codebook"}:
            from repro.core.quant.vq import vq_dequantize
            return vq_dequantize(leaf["vq_idx"],
                                 leaf["codebook"]).astype(jnp.float32)
        return leaf

    tree = jax.tree_util.tree_map(
        deq, qparams, is_leaf=lambda x: isinstance(x, DPotQuantized))
    return jax.tree_util.tree_map(
        deq_dict, tree,
        is_leaf=lambda x: isinstance(x, dict) and set(x) in
        ({"codes", "scale"}, {"vq_idx", "codebook"}))
