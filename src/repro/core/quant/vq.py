"""Per-tensor codebook (vector-quantized) weight plane — the RWKVQuant
direction (arXiv 2505.03803): where scalar quantization degrades (outlier-
heavy tensors whose per-channel scale is set by a few extreme weights), a
small learned codebook keeps accuracy at the same stored width.

Storage form: uint8 indices shaped like the weight + a <=256-entry bf16
codebook.  In the serving stack the indices ride the uint8 slab exactly
like Δ-PoT code planes, while the codebook — a leading-1 leaf, like the
shared packed scales — stays VMEM-resident via `fuse_layer_stack`'s aux
path; the gather decode runs INSIDE the consumer kernels
(`core.quant.serving.unpack_leaf` is the single source of decode truth).

Fitting is deterministic scalar k-means (Lloyd): quantile-spaced init over
a deterministic subsample, exact nearest-centroid assignment via
`searchsorted` on sorted-centroid midpoints, empty clusters keep their
previous centroid.  Assignment happens against the bf16-ROUNDED centroids
— the values the serving decode will actually gather — so the stored
codebook is the one the assignment optimized.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _bf16_round(x: np.ndarray) -> np.ndarray:
    """Round f32 values to their nearest bf16 representation (as f32)."""
    return np.asarray(jnp.asarray(x, jnp.float32).astype(jnp.bfloat16),
                      np.float32)


def _assign(values: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Exact nearest-centroid index per value (centroids sorted ascending)."""
    mids = 0.5 * (centroids[1:] + centroids[:-1])
    return np.searchsorted(mids, values).astype(np.int64)


def kmeans_1d(values: np.ndarray, n_codes: int, iters: int = 16
              ) -> np.ndarray:
    """Deterministic 1-D Lloyd k-means; returns `n_codes` sorted centroids
    (f32, already bf16-rounded — see module docstring)."""
    v = np.asarray(values, np.float32).reshape(-1)
    # quantile-spaced init covers the empirical distribution (incl. the
    # outlier tails that motivate VQ) without any RNG
    qs = (np.arange(n_codes, dtype=np.float64) + 0.5) / n_codes
    cent = np.quantile(v, qs).astype(np.float32)
    cent = np.sort(_bf16_round(cent))
    for _ in range(iters):
        idx = _assign(v, cent)
        sums = np.bincount(idx, weights=v, minlength=n_codes)
        cnts = np.bincount(idx, minlength=n_codes)
        new = np.where(cnts > 0, sums / np.maximum(cnts, 1), cent)
        new = np.sort(_bf16_round(new.astype(np.float32)))
        if np.array_equal(new, cent):
            break
        cent = new
    return cent


def vq_quantize(w, n_codes: int = 256, iters: int = 16,
                sample: int = 1 << 16) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fit a per-tensor codebook and assign every weight.

    Returns (idx, codebook): uint8 indices shaped like `w`, and a bf16
    codebook of shape (1, n_codes) — the leading 1 marks it as a shared
    broadcast leaf for `fuse_layer_stack` (resident operand, like the
    Δ-PoT scales)."""
    if not 2 <= n_codes <= 256:
        raise ValueError(f"n_codes={n_codes}: uint8 indices need 2..256")
    v = np.asarray(w, np.float32).reshape(-1)
    fit = v if v.size <= sample else v[:: (v.size + sample - 1) // sample]
    cent = kmeans_1d(fit, n_codes, iters)
    idx = _assign(v, cent).astype(np.uint8).reshape(np.shape(w))
    codebook = jnp.asarray(cent, jnp.float32).astype(
        jnp.bfloat16).reshape(1, n_codes)
    return jnp.asarray(idx), codebook


def vq_dequantize(idx: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Gather decode: bf16 weights shaped like `idx`.  Shape-agnostic in
    the codebook (flattened before the gather) so slab/aux re-layouts —
    (1, C) resident, (C,) squeezed in-kernel, (L, C) broadcast for scanned
    paths — all decode identically."""
    return codebook.reshape(-1)[idx.astype(jnp.int32)]
