"""9-bit uniform symmetric quantization (paper §3.2).

Used for: additive weights (token-shift μ, decay w, bonus u, LN γ/β) and all
activations / intermediate results.  "9-bit" = sign + 8 magnitude bits, i.e.
the integer grid [−255, +255] (symmetric, no negative-max asymmetry), exactly
the W9A9 setting the paper's Table-1 baselines simulate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _qmax(bits: int) -> int:
    # sign + (bits-1) magnitude bits, symmetric grid
    return (1 << (bits - 1)) - 1


def _amax(x: jnp.ndarray, axis) -> jnp.ndarray:
    if axis is None:
        return jnp.max(jnp.abs(x))
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    reduce_axes = tuple(i for i in range(x.ndim)
                        if i not in tuple(a % x.ndim for a in axes))
    return jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)


def uniform_quantize(x: jnp.ndarray, bits: int = 9, *, axis=None):
    """x -> (int32 codes in [-qmax, qmax], f32 scale)."""
    x = jnp.asarray(x, jnp.float32)
    qmax = _qmax(bits)
    amax = _amax(x, axis)
    scale = jnp.where(amax <= 0, 1.0, amax / qmax)
    codes = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return codes, scale


def uniform_dequantize(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def uniform_fake_quant(x, bits: int = 9, axis=None):
    codes, scale = uniform_quantize(x, bits, axis=axis)
    return uniform_dequantize(codes, scale).astype(x.dtype)


def _ufq_fwd(x, bits, axis):
    return uniform_fake_quant(x, bits, axis), None


def _ufq_bwd(bits, axis, _, g):
    return (g,)


uniform_fake_quant.defvjp(_ufq_fwd, _ufq_bwd)
