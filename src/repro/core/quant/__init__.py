"""Quantization library — the paper's C1/C2 contributions.

Exports:
  delta_pot   — the paper's Δ-PoT additive-powers-of-two format (§3.1)
  uniform     — 9-bit uniform symmetric quantization (§3.2)
  schemes     — baselines reproduced for the Table-1 ablation (RTN/PoT/LogQ)
  policy      — mixed-precision policy over a parameter tree (§3.2)
"""
from repro.core.quant.delta_pot import (
    DPotFormat,
    DPotQuantized,
    dpot_levels,
    dpot_quantize,
    dpot_dequantize,
    dpot_fake_quant,
    dpot_pack_int8,
    dpot_unpack_int8,
)
from repro.core.quant.uniform import (
    uniform_quantize,
    uniform_dequantize,
    uniform_fake_quant,
)
from repro.core.quant.schemes import (
    rtn_fake_quant,
    pot_fake_quant,
    logq_fake_quant,
    SCHEMES,
)
from repro.core.quant.policy import (
    QuantPolicy,
    classify_param,
    quantize_tree,
    fake_quantize_tree,
)

__all__ = [
    "DPotFormat", "DPotQuantized", "dpot_levels", "dpot_quantize",
    "dpot_dequantize", "dpot_fake_quant", "dpot_pack_int8",
    "dpot_unpack_int8", "uniform_quantize", "uniform_dequantize",
    "uniform_fake_quant", "rtn_fake_quant", "pot_fake_quant",
    "logq_fake_quant", "SCHEMES", "QuantPolicy", "classify_param",
    "quantize_tree", "fake_quantize_tree",
]
