"""Quantization library — the paper's C1/C2 contributions.

Exports:
  delta_pot   — the paper's Δ-PoT additive-powers-of-two format (§3.1)
  uniform     — 9-bit uniform symmetric quantization (§3.2)
  schemes     — baselines reproduced for the Table-1 ablation (RTN/PoT/LogQ)
  policy      — mixed-precision policy over a parameter tree (§3.2), plus
                the per-tensor plane selection (W8/W4/VQ, RWKVQuant-style)
  vq          — per-tensor k-means codebook plane (uint8 indices)
"""
from repro.core.quant.delta_pot import (
    DPotFormat,
    DPotQuantized,
    dpot_levels,
    dpot_quantize,
    dpot_dequantize,
    dpot_fake_quant,
    dpot_pack_int8,
    dpot_unpack_int8,
    dpot_pack_nibbles,
    dpot_unpack_nibbles,
)
from repro.core.quant.vq import (
    kmeans_1d,
    vq_quantize,
    vq_dequantize,
)
from repro.core.quant.uniform import (
    uniform_quantize,
    uniform_dequantize,
    uniform_fake_quant,
)
from repro.core.quant.schemes import (
    rtn_fake_quant,
    pot_fake_quant,
    logq_fake_quant,
    SCHEMES,
)
from repro.core.quant.policy import (
    QuantPolicy,
    PlanePolicy,
    PLANE_W8,
    PLANE_W4,
    PLANE_VQ,
    PLANE_PROXY,
    classify_param,
    weight_outlier_proxy,
    quantize_tree,
    fake_quantize_tree,
)

__all__ = [
    "DPotFormat", "DPotQuantized", "dpot_levels", "dpot_quantize",
    "dpot_dequantize", "dpot_fake_quant", "dpot_pack_int8",
    "dpot_unpack_int8", "dpot_pack_nibbles", "dpot_unpack_nibbles",
    "kmeans_1d", "vq_quantize", "vq_dequantize",
    "uniform_quantize", "uniform_dequantize",
    "uniform_fake_quant", "rtn_fake_quant", "pot_fake_quant",
    "logq_fake_quant", "SCHEMES", "QuantPolicy", "PlanePolicy",
    "PLANE_W8", "PLANE_W4", "PLANE_VQ", "PLANE_PROXY", "classify_param",
    "weight_outlier_proxy", "quantize_tree", "fake_quantize_tree",
]
