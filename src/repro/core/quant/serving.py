"""Packed-weight serving: the paper's deployment mode on TPU.

The accelerator stores Δ-PoT codes in (HBM-equivalent) memory and decodes
on-chip (§3.1, §4.1).  TPU translation: matmul weights live in device HBM as
ONE uint8 per weight (sign + ks=(3,4) codes, FORMAT_W8) plus an f32 scale
per output channel; `unpack_params` runs INSIDE the jitted serve step, so
XLA reads int8 from HBM and fuses the decode into the consumer matmuls —
weight traffic halves vs bf16 (the paper's bandwidth win), at the Table-1
accuracy cost.

Two sub-8-bit planes extend the stack (RWKVQuant direction, PAPERS.md):
a W4 plane packing TWO sign+3-bit Δ-PoT codes per uint8 along the
contraction axis ({"packed4", "scale"} — half the W8 slab bytes), and a
per-tensor VQ plane of uint8 codebook indices ({"vq_idx", "codebook"} —
the bf16 codebook rides the resident const maps like the shared scales).
`core.quant.policy.PlanePolicy` picks the plane per tensor; every decode
goes through the same `unpack_leaf`, so mixed-plane trees stay
bit-identical across the per-op and fused paths.

API:
  pack_params(params)          -> packed tree (+ additive leaves cast bf16)
  unpack_params(packed)        -> compute tree (call inside jit)
  unpack_leaf(leaf)            -> decode ONE packed leaf (shared by the
                                 fused decode kernels so in-kernel decode is
                                 bit-identical to the per-op path)
  broadcast_packed_scales(t,L) -> make stacked packed leaves layer-sliceable
                                 (scan / per-block kernel operands)
  cast_compute(tree, dtype)    -> packed-aware compute-dtype cast
  packed_abstract(spec)        -> ShapeDtypeStruct tree (dry-run input)
  packed_axes(spec_axes)       -> logical-sharding tree for the packed form

Param preparation (one pass, shared by every serving path):
  PreparedParams               -> container holding every per-path form of
                                 one weight set (raw / decode / prefill) —
                                 built once by serving.plan.build_plan
  prepare_layer_stack_params   -> generic megakernel prep (compute cast +
                                 fuse_layer_stack); models wrap it instead
                                 of duplicating the plumbing
  predecode_packed_leaves      -> decode named packed leaves in place (the
                                 generic form of rwkv6's prefill prep)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant.delta_pot import (
    FORMAT_W4, FORMAT_W8, dpot_decode_codes, dpot_pack_int8,
    dpot_pack_nibbles, dpot_quantize)
from repro.core.quant.policy import PlanePolicy, classify_param
from repro.core.quant.vq import vq_dequantize, vq_quantize

# The three quantized weight-plane leaf forms (one dict shape per plane):
#   w8 — {"packed":  uint8 (..., K, N),   "scale": f32 (1,...,N)}  sign+7b
#   w4 — {"packed4": uint8 (..., K/2, N), "scale": f32 (1,...,N)}  2/byte
#   vq — {"vq_idx":  uint8 (..., K, N),   "codebook": bf16 (1, C)} gather
_PLANE_KEYS = {
    frozenset({"packed", "scale"}): "w8",
    frozenset({"packed4", "scale"}): "w4",
    frozenset({"vq_idx", "codebook"}): "vq",
}


def leaf_plane(leaf) -> str | None:
    """"w8" | "w4" | "vq" for a quantized plane leaf, None otherwise."""
    if not isinstance(leaf, dict):
        return None
    return _PLANE_KEYS.get(frozenset(leaf))


def is_packed_leaf(leaf) -> bool:
    """True for ANY quantized weight-plane leaf (scalar Δ-PoT W8, nibble-
    packed W4, or VQ codebook) — THE predicate for the packed formats (the
    fused kernels and models import it from here so the formats have a
    single source of truth)."""
    return leaf_plane(leaf) is not None


_is_packed = is_packed_leaf


def pack_params(params, policy: PlanePolicy | None = None):
    """Quantize every matmul weight to a packed plane; cast the rest bf16.

    Without a `policy` every matmul weight gets scalar Δ-PoT W8 (the
    historical behavior).  With a `PlanePolicy`, each tensor's plane is
    selected per tensor (proxy-guided or forced) — "w4" halves the stored
    code bytes via nibble pairing (falling back to W8 when the contraction
    axis is odd, so any tree packs), "vq" stores uint8 codebook indices."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if classify_param(key, leaf) == "matmul":
            plane = "w8" if policy is None else policy.plane_for(key, leaf)
            if plane == "w4" and (leaf.ndim < 2 or leaf.shape[-2] % 2):
                plane = "w8"        # nibble pairing needs an even axis -2
            if plane == "vq":
                idx, codebook = vq_quantize(leaf, policy.vq_codes)
                out.append({"vq_idx": idx, "codebook": codebook})
            elif plane == "w4":
                q = dpot_quantize(leaf, FORMAT_W4, axis=-1)
                out.append({"packed4": dpot_pack_nibbles(q),
                            "scale": q.scale.astype(jnp.float32)})
            else:
                q = dpot_quantize(leaf, FORMAT_W8, axis=-1)
                out.append({"packed": dpot_pack_int8(q),
                            "scale": q.scale.astype(jnp.float32)})
        else:
            out.append(leaf.astype(jnp.bfloat16)
                       if hasattr(leaf, "astype") else leaf)
    return jax.tree_util.tree_unflatten(tdef, out)


def unpack_leaf(leaf):
    """Decode one quantized plane leaf -> bf16 weights (identity on
    anything else).  The single source of truth for the decode numerics:
    both `unpack_params` (per-op path, whole tree before the matmuls) and
    the fused kernels (per leaf, inside the launch) call this, which is
    what makes the paths bit-identical.  W4 re-interleaves the nibble
    pairs along the contraction axis before the same exp2 decode; VQ is a
    flat codebook gather (shape-agnostic: resident (1, C), in-kernel (C,)
    and scan-broadcast forms all index identically)."""
    plane = leaf_plane(leaf)
    if plane is None:
        return leaf
    if plane == "vq":
        return vq_dequantize(leaf["vq_idx"],
                             leaf["codebook"]).astype(jnp.bfloat16)
    if plane == "w4":
        p = leaf["packed4"]
        lo = p & 0xF
        hi = (p >> 4) & 0xF
        words = jnp.stack([lo, hi], axis=-2).reshape(
            p.shape[:-2] + (2 * p.shape[-2], p.shape[-1]))
        codes = (words & 0x7).astype(jnp.uint8)
        sign = jnp.where((words >> 3) & 1, -1.0, 1.0)
        lvl = dpot_decode_codes(codes, FORMAT_W4.ks)
        return (sign * lvl * leaf["scale"]).astype(jnp.bfloat16)
    p = leaf["packed"]
    codes = (p & 0x7F).astype(jnp.uint8)
    sign = jnp.where((p >> 7) & 1, -1.0, 1.0)
    lvl = dpot_decode_codes(codes, FORMAT_W8.ks)
    return (sign * lvl * leaf["scale"]).astype(jnp.bfloat16)


def broadcast_packed_scales(blocks, n_layers: int):
    """Make a packed stacked-blocks tree sliceable along the layer axis.

    `pack_params` gives a stacked weight (L, ...) one shared scale with a
    broadcast leading 1 (e.g. (1, 1, D)) — and a VQ leaf one shared (1, C)
    codebook; consumers that *slice* the tree per layer — `lax.scan` over
    blocks, or the per-block fused kernel's scanned operands — need every
    leaf to carry the L axis, so the shared leaf is broadcast to (L, ...)
    here.  The per-layer slice then decodes element-for-element exactly as
    the whole-tree broadcast would, keeping the decode bit-identical.  The
    whole-model megakernel does NOT need this:
    `kernels.fused_decode.fused_model_decode` recognizes leading-1 leaves
    and streams them with a constant index map instead (the shared scale /
    codebook stays resident while the uint8 codes are layer-sliced
    in-kernel)."""
    def fix(leaf):
        if not is_packed_leaf(leaf):
            return leaf
        out = {}
        for k, v in leaf.items():
            if k in ("scale", "codebook") and v.shape[0] == 1:
                v = jnp.broadcast_to(v, (n_layers,) + tuple(v.shape[1:]))
            out[k] = v
        return out
    return jax.tree_util.tree_map(fix, blocks, is_leaf=is_packed_leaf)


def plane_fingerprint(params) -> str:
    """The quant-form fingerprint of a (possibly packed) tree, for the
    prefix-cache variant key and snapshot `build_config`.

    "fp" when nothing is packed and exactly "dpot_w8" when every quant
    leaf is scalar W8 (the historical CacheVariant strings, so existing
    cache entries / snapshots stay valid); any other mix hashes the
    ordered (path, plane) selection — two different per-tensor policies
    can NEVER alias to the same variant."""
    import hashlib
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_packed_leaf)
    kinds = [(jax.tree_util.keystr(path), leaf_plane(leaf))
             for path, leaf in flat if is_packed_leaf(leaf)]
    if not kinds:
        return "fp"
    if all(k == "w8" for _, k in kinds):
        return "dpot_w8"
    h = hashlib.blake2b(repr(kinds).encode(), digest_size=4).hexdigest()
    return f"dpot_mix_{h}"


def unpack_params(packed):
    """Packed tree -> bf16 compute tree.  Runs inside jit: the uint8 codes
    are what crosses HBM; the exp2 decode fuses into the matmul."""
    return jax.tree_util.tree_map(unpack_leaf, packed, is_leaf=_is_packed)


# ---------------------------------------------------------------------------
# Fused layer stack: per-layer weights as ONE contiguous chunk per layer
# ---------------------------------------------------------------------------
#
# The paper's weight stream (§4.2) is chunked: the accelerator fetches each
# layer's weights as one contiguous block and double-buffers the next
# layer's chunk behind the current layer's compute.  `fuse_layer_stack`
# realizes that layout on the host — every stacked (L, ...) leaf of a
# block tree is flattened into a per-dtype (L, N) slab (uint8 Δ-PoT code
# planes and bf16 weights each get their own slab), while broadcast
# leading-1 leaves (shared packed scales, LUT tables) stay separate as
# resident operands.  The whole-model decode megakernel
# (`kernels.fused_decode.fused_model_decode`) then fetches layer l as one
# slab row per dtype and re-materializes the per-layer tree with STATIC
# slices inside the kernel (`unfuse_layer`) — stacked packed-leaf slicing
# inside the kernel, one memory stream per layer instead of one gather per
# leaf.  Packing reshapes and concatenates only, so the decoded weights
# are bit-identical to the unfused tree.


@jax.tree_util.register_pytree_node_class
class FusedLayerStack:
    """A stacked per-layer parameter tree in chunked-stream form.

    slabs    — {dtype name: (L, N) array}: layer l's weights of that dtype,
               contiguous.
    aux      — tuple of broadcast leading-1 leaves kept out of the slabs
               (shared Δ-PoT scales, LUT tables): VMEM-resident operands.
    manifest — static per-leaf recipe aligned with the original tree's
               flatten order: ("slab", dtype, offset, per-layer shape) or
               ("aux", index).
    tdef     — the original tree's treedef (packed {"packed","scale"}
               dicts reassemble automatically).
    """

    def __init__(self, slabs, aux, manifest, tdef):
        self.slabs = dict(slabs)
        self.aux = tuple(aux)
        self.manifest = tuple(manifest)
        self.tdef = tdef

    @property
    def n_layers(self) -> int:
        return next(iter(self.slabs.values())).shape[0]

    def tree_flatten(self):
        keys = tuple(sorted(self.slabs))
        children = tuple(self.slabs[k] for k in keys) + self.aux
        return children, (keys, len(self.aux), self.manifest, self.tdef)

    @classmethod
    def tree_unflatten(cls, static, children):
        keys, n_aux, manifest, tdef = static
        slabs = dict(zip(keys, children[:len(keys)]))
        aux = children[len(keys):len(keys) + n_aux]
        return cls(slabs, aux, manifest, tdef)


def fuse_layer_stack(blocks, n_layers: int) -> FusedLayerStack:
    """Pack a stacked per-layer block tree into per-dtype (L, N) slabs.

    Values are only reshaped/concatenated, never converted — unfusing is
    bit-identical.  Do this ONCE outside the decode step (the serving
    engine and `Model.prepare_fused_model_params` do): repacking inside a
    jitted step would copy every weight per token."""
    flat, tdef = jax.tree_util.tree_flatten(blocks)
    manifest, aux, parts, offs = [], [], {}, {}
    for leaf in flat:
        if leaf.ndim and leaf.shape[0] == n_layers:
            key = jnp.dtype(leaf.dtype).name
            shape = tuple(leaf.shape[1:])
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            manifest.append(("slab", key, offs.get(key, 0), shape))
            parts.setdefault(key, []).append(
                jnp.reshape(leaf, (n_layers, n)))
            offs[key] = offs.get(key, 0) + n
        elif leaf.ndim and leaf.shape[0] == 1:
            manifest.append(("aux", len(aux)))
            aux.append(leaf)
        else:
            raise ValueError(
                f"per-layer leaf has shape {getattr(leaf, 'shape', None)}; "
                f"expected a leading axis of {n_layers} (stacked) or 1 "
                "(broadcast)")
    slabs = {k: (jnp.concatenate(v, axis=1) if len(v) > 1 else v[0])
             for k, v in parts.items()}
    return FusedLayerStack(slabs, aux, manifest, tdef)


def unfuse_layer(rows, aux_vals, manifest, tdef):
    """Rebuild ONE layer's parameter tree from its slab rows.

    rows     — {dtype name: (N,) slab row for layer l} (or abstract).
    aux_vals — broadcast leaves with the leading 1 squeezed.
    All slices are STATIC (offsets come from the manifest), so inside a
    kernel this compiles to views feeding the consumers — the only
    per-layer memory stream is the slab row fetch itself."""
    leaves = []
    for entry in manifest:
        if entry[0] == "slab":
            _, key, off, shape = entry
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            leaves.append(rows[key][off:off + n].reshape(shape))
        else:
            leaves.append(aux_vals[entry[1]])
    return jax.tree_util.tree_unflatten(tdef, leaves)


@dataclasses.dataclass(frozen=True)
class PreparedParams:
    """Every per-path form of one weight set, prepared ONCE at startup.

    The serving engine used to keep three ad-hoc param transforms
    (`pack_params` at init, `prepare_fused_model_params` for the
    megakernel, `prepare_prefill_params` for the fused prefill) as
    separate attributes wired by boolean flags.  This container is the
    single product of that pipeline — built by
    `repro.serving.plan.build_plan` in one pass:

      raw      — the tree as stored (packed Δ-PoT when `quantized`);
                 per-op paths consume it, unpacking IN-TRACE when packed.
      decode   — the form the selected decode path consumes (e.g. the
                 megakernel's pre-cast `FusedLayerStack` slabs; == raw for
                 per-op / per-block paths).
      prefill  — the form the selected prefill path consumes (e.g. rwkv6's
                 pre-decoded elementwise leaves; == raw for per-op).

    quantized / decode_path / prefill_path record which pipeline produced
    the forms, so consumers (and error messages) never re-derive it."""
    raw: Any
    decode: Any
    prefill: Any
    quantized: bool = False
    decode_path: str = "per_op"
    prefill_path: str = "per_op"
    # truncated-stack drafter weights for the speculative path (the first
    # `draft_depth` layers of `raw`, leaves aliased — see
    # Model.truncate_params); None when the plan has no SpeculativePath
    draft: Any = None


def prepare_layer_stack_params(params, cfg, extra_block_operands=None):
    """Generic host-side prep for the whole-model megakernel: apply the
    packed-aware compute cast, attach any extra per-block kernel operands
    (rwkv4's hw LUT tables), and chunk the stacked per-layer weights into
    per-dtype contiguous slabs (`fuse_layer_stack`) — the paper's per-layer
    weight chunk, fetched as ONE stream per layer instead of one gather per
    leaf.  Models' `prepare_fused_model_params` entries wrap this instead
    of each duplicating the cast + fuse plumbing."""
    params = cast_compute(params, jnp.dtype(cfg.dtype))
    blocks = params["blocks"]
    if extra_block_operands:
        blocks = {**blocks, **extra_block_operands}
    return {**params, "blocks": fuse_layer_stack(blocks, cfg.n_layers)}


def predecode_packed_leaves(params, paths):
    """Decode the packed leaves at the given key-paths (tuples of dict
    keys) with `unpack_leaf`, leaving everything else — including plain
    leaves at those paths — untouched.  The generic form of "this path
    consumes a few leaves element-wise, so pre-decode them once at startup
    and let every remaining uint8 code plane stream into a kernel"
    (rwkv6's fused-prefill prep).  Same `unpack_leaf` as the per-op
    oracle, so bits match."""
    def update(node, path):
        if not path:
            return unpack_leaf(node) if _is_packed(node) else node
        head, rest = path[0], path[1:]
        return {**node, head: update(node[head], rest)}

    for path in paths:
        params = update(params, tuple(path))
    return params


def cast_compute(tree, dtype):
    """Packed-aware mixed-precision cast: floating leaves go to `dtype`
    (exactly `Model.cast_params`), packed Δ-PoT leaves pass through intact
    so their uint8 codes and f32 scales reach the fused kernel unchanged
    (casting the scale would perturb the decode vs the per-op path)."""
    dt = jnp.dtype(dtype)

    def cast(a):
        if _is_packed(a):
            return a
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(dt)
        return a
    return jax.tree_util.tree_map(cast, tree, is_leaf=_is_packed)


def packed_abstract(spec_tree, abstract_params):
    """ShapeDtypeStruct tree of the packed form (for the dry-run)."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if classify_param(key, leaf) == "matmul":
            scale_shape = tuple(1 for _ in leaf.shape[:-1]) + \
                (leaf.shape[-1],)
            out.append({
                "packed": jax.ShapeDtypeStruct(leaf.shape, jnp.uint8),
                "scale": jax.ShapeDtypeStruct(scale_shape, jnp.float32),
            })
        else:
            out.append(jax.ShapeDtypeStruct(leaf.shape, jnp.bfloat16))
    return jax.tree_util.tree_unflatten(tdef, out)


def serving_axes(param_axes_tree, abstract_packed_tree):
    """Axes tree matching the *packed* structure: for packed leaves the
    codes get the original axes and the scale gets (None..., last-axis)."""
    flat_axes, adef = jax.tree_util.tree_flatten(
        param_axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    flat_pk = adef.flatten_up_to(abstract_packed_tree)
    out = []
    for axes, leaf in zip(flat_axes, flat_pk):
        if isinstance(leaf, dict) and set(leaf) == {"packed", "scale"}:
            out.append({
                "packed": axes,
                "scale": tuple([None] * (len(axes) - 1)) + (axes[-1],),
            })
        else:
            out.append(axes)
    return jax.tree_util.tree_unflatten(adef, out)


def replicate_fsdp(axes_tree):
    """Serving sharding policy: drop the FSDP axis (weights replicated over
    'data'; TP only).  Kills the per-step weight all-gather that FSDP
    sharding would force during decode — see EXPERIMENTS.md §Perf."""
    def strip(axes):
        return tuple(None if a == "fsdp" else a for a in axes)
    return jax.tree_util.tree_map(
        strip, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
