"""Packed-weight serving: the paper's deployment mode on TPU.

The accelerator stores Δ-PoT codes in (HBM-equivalent) memory and decodes
on-chip (§3.1, §4.1).  TPU translation: matmul weights live in device HBM as
ONE uint8 per weight (sign + ks=(3,4) codes, FORMAT_W8) plus an f32 scale
per output channel; `unpack_params` runs INSIDE the jitted serve step, so
XLA reads int8 from HBM and fuses the decode into the consumer matmuls —
weight traffic halves vs bf16 (the paper's bandwidth win), at the Table-1
accuracy cost.

API:
  pack_params(params)          -> packed tree (+ additive leaves cast bf16)
  unpack_params(packed)        -> compute tree (call inside jit)
  unpack_leaf(leaf)            -> decode ONE packed leaf (shared by the
                                 fused decode kernel so in-kernel decode is
                                 bit-identical to the per-op path)
  cast_compute(tree, dtype)    -> packed-aware compute-dtype cast
  packed_abstract(spec)        -> ShapeDtypeStruct tree (dry-run input)
  packed_axes(spec_axes)       -> logical-sharding tree for the packed form
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.delta_pot import (
    FORMAT_W8, dpot_decode_codes, dpot_pack_int8, dpot_quantize)
from repro.core.quant.policy import classify_param


def is_packed_leaf(leaf) -> bool:
    """True for a `{"packed", "scale"}` Δ-PoT leaf — THE predicate for the
    packed format (the fused decode kernel and models import it from here
    so the format has a single source of truth)."""
    return isinstance(leaf, dict) and set(leaf) == {"packed", "scale"}


_is_packed = is_packed_leaf


def pack_params(params):
    """Quantize every matmul weight to packed Δ-PoT W8; cast the rest bf16."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if classify_param(key, leaf) == "matmul":
            q = dpot_quantize(leaf, FORMAT_W8, axis=-1)
            out.append({"packed": dpot_pack_int8(q),
                        "scale": q.scale.astype(jnp.float32)})
        else:
            out.append(leaf.astype(jnp.bfloat16)
                       if hasattr(leaf, "astype") else leaf)
    return jax.tree_util.tree_unflatten(tdef, out)


def unpack_leaf(leaf):
    """Decode one `{"packed", "scale"}` leaf -> bf16 weights (identity on
    anything else).  The single source of truth for the decode numerics:
    both `unpack_params` (per-op path, whole tree before the matmuls) and
    the fused decode kernel (per leaf, inside the launch) call this, which
    is what makes the two paths bit-identical."""
    if not _is_packed(leaf):
        return leaf
    p = leaf["packed"]
    codes = (p & 0x7F).astype(jnp.uint8)
    sign = jnp.where((p >> 7) & 1, -1.0, 1.0)
    lvl = dpot_decode_codes(codes, FORMAT_W8.ks)
    return (sign * lvl * leaf["scale"]).astype(jnp.bfloat16)


def unpack_params(packed):
    """Packed tree -> bf16 compute tree.  Runs inside jit: the uint8 codes
    are what crosses HBM; the exp2 decode fuses into the matmul."""
    return jax.tree_util.tree_map(unpack_leaf, packed, is_leaf=_is_packed)


def cast_compute(tree, dtype):
    """Packed-aware mixed-precision cast: floating leaves go to `dtype`
    (exactly `Model.cast_params`), packed Δ-PoT leaves pass through intact
    so their uint8 codes and f32 scales reach the fused kernel unchanged
    (casting the scale would perturb the decode vs the per-op path)."""
    dt = jnp.dtype(dtype)

    def cast(a):
        if _is_packed(a):
            return a
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(dt)
        return a
    return jax.tree_util.tree_map(cast, tree, is_leaf=_is_packed)


def packed_abstract(spec_tree, abstract_params):
    """ShapeDtypeStruct tree of the packed form (for the dry-run)."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if classify_param(key, leaf) == "matmul":
            scale_shape = tuple(1 for _ in leaf.shape[:-1]) + \
                (leaf.shape[-1],)
            out.append({
                "packed": jax.ShapeDtypeStruct(leaf.shape, jnp.uint8),
                "scale": jax.ShapeDtypeStruct(scale_shape, jnp.float32),
            })
        else:
            out.append(jax.ShapeDtypeStruct(leaf.shape, jnp.bfloat16))
    return jax.tree_util.tree_unflatten(tdef, out)


def serving_axes(param_axes_tree, abstract_packed_tree):
    """Axes tree matching the *packed* structure: for packed leaves the
    codes get the original axes and the scale gets (None..., last-axis)."""
    flat_axes, adef = jax.tree_util.tree_flatten(
        param_axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    flat_pk = adef.flatten_up_to(abstract_packed_tree)
    out = []
    for axes, leaf in zip(flat_axes, flat_pk):
        if isinstance(leaf, dict) and set(leaf) == {"packed", "scale"}:
            out.append({
                "packed": axes,
                "scale": tuple([None] * (len(axes) - 1)) + (axes[-1],),
            })
        else:
            out.append(axes)
    return jax.tree_util.tree_unflatten(adef, out)


def replicate_fsdp(axes_tree):
    """Serving sharding policy: drop the FSDP axis (weights replicated over
    'data'; TP only).  Kills the per-step weight all-gather that FSDP
    sharding would force during decode — see EXPERIMENTS.md §Perf."""
    def strip(axes):
        return tuple(None if a == "fsdp" else a for a in axes)
    return jax.tree_util.tree_map(
        strip, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
