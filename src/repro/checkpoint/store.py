"""Sharded numpy checkpoint store with async save and elastic restore.

Layout (one directory per step):

    <dir>/step_000100/
        MANIFEST.json          tree structure, shapes, dtypes, step
        <leaf-key>.npy         one file per leaf (host 0 writes in this
                               single-process container; on a real pod each
                               host writes its owned shards — the manifest
                               format already records per-leaf sharding)
        COMMIT                 written last; restore ignores dirs without it

Fault-tolerance contract:
  * atomic-by-rename: data is staged into `.tmp-step_X` and renamed after the
    COMMIT marker is in place, so a host failure mid-save never corrupts the
    latest checkpoint;
  * elastic restore: `restore_checkpoint(..., mesh=new_mesh, axes=...)`
    re-shards leaves onto a DIFFERENT mesh than the one that saved them —
    restoring a (2,16,16) run onto (16,16) (pod loss) or vice versa;
  * async: `AsyncCheckpointer` snapshots device arrays to host memory
    synchronously (cheap) and does the file I/O on a background thread, so
    training never blocks on disk.

Serving-shaped trees (repro.serving.snapshot) stressed two corners the
training path never hit, both fixed here: leaves roundtrip with their
EXACT dtype (np.load forgets extension dtypes like bfloat16 — the
manifest dtype string is authoritative and mismatches are view-cast
back; uint8 Δ-PoT code planes pass through untouched), and python
scalar leaves (ints/floats/bools in host bookkeeping trees) come back
as the same python type, not 0-d arrays.  A checkpoint may also carry a
JSON `meta` blob (stored in MANIFEST.json) for host state that is not
an array — `load_manifest` reads it back without needing a `like` tree.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax

from repro.parallel.sharding import tree_shardings


def _flatten_with_keys(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path).replace("/", "_")
        safe = "".join(c if c.isalnum() or c in "._-[]'" else "_"
                       for c in key)
        out.append((safe, leaf))
    return out, treedef


def _resolve_dtype(name: str) -> np.dtype:
    """Manifest dtype string -> numpy dtype, including the ml_dtypes
    extension types (bfloat16, float8_*) numpy cannot parse by name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    meta: Any = None) -> str:
    """Blocking sharded save. Returns the final checkpoint path.
    `meta` (JSON-serializable) is stored inside MANIFEST.json — host-side
    bookkeeping that rides along with the array tree (the serving
    snapshot layer keeps scheduler/RNG/counter state there)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten_with_keys(tree)
    manifest = {"step": step, "leaves": [], "meta": meta}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        # python scalars arrive as 0-d arrays; remember the python type so
        # restore can hand back an int, not a numpy 0-d (exact roundtrip)
        scalar = (type(leaf).__name__
                  if isinstance(leaf, (bool, int, float)) else None)
        np.save(os.path.join(tmp, f"{key}.npy"), arr)
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "scalar": scalar})
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_manifest(directory: str, step: int) -> dict:
    """The committed checkpoint's MANIFEST.json (step, per-leaf records,
    and the `meta` blob).  Refuses uncommitted/torn directories — a
    `.tmp-step_X` left by a crash mid-write, or a step dir without its
    COMMIT marker, is never readable state."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(
            f"no committed checkpoint at {path} (missing COMMIT marker — "
            "uncommitted or torn write)")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        return json.load(f)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       mesh=None, axes=None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  With mesh+axes, device-put each leaf with the
    sharding derived for the NEW mesh — the elastic-resharding path."""
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = load_manifest(directory, step)
    records = {r["key"]: r for r in manifest["leaves"]}
    flat_like, treedef = _flatten_with_keys(like)
    leaves = []
    shardings = None
    if mesh is not None and axes is not None:
        sh_tree = tree_shardings(axes, jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), like), mesh)
        shardings = [s for _, s in _flatten_with_keys(sh_tree)[0]]
    for i, (key, ref) in enumerate(flat_like):
        rec = records.get(key)
        if rec is None:
            raise KeyError(
                f"checkpoint leaf {key!r} missing from the manifest at "
                f"{path} — the saved tree had a different structure")
        try:
            arr = np.load(os.path.join(path, f"{key}.npy"))
        except FileNotFoundError as e:
            raise FileNotFoundError(
                f"checkpoint leaf {key!r}: file missing at {path} "
                f"(manifest lists it — torn/corrupt checkpoint)") from e
        except Exception as e:
            raise ValueError(
                f"checkpoint leaf {key!r}: unreadable/corrupt .npy at "
                f"{path}: {e}") from e
        # np.load forgets extension dtypes (bfloat16 comes back as a raw
        # |V2 void view) — the manifest dtype is authoritative
        want = _resolve_dtype(rec["dtype"])
        if arr.dtype != want:
            arr = arr.view(want) if arr.dtype.itemsize == want.itemsize \
                else arr.astype(want)
        if rec.get("scalar") or not hasattr(ref, "shape"):
            # python scalar leaf: same value, same python type (prefer the
            # type recorded at save; fall back to the like-tree's)
            py = {"bool": bool, "int": int, "float": float}.get(
                rec.get("scalar") or type(ref).__name__, float)
            leaves.append(py(arr.item()))
            continue
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != model {ref.shape}")
        if arr.dtype != ref.dtype:
            arr = arr.astype(ref.dtype)
        if shardings is not None:
            leaves.append(jax.device_put(arr, shardings[i]))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda x: 0, like)), leaves)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk asynchronously.

    `save(step, tree)` returns immediately after device_get; `wait()` joins
    the in-flight write (call before exiting or before deleting old steps).
    Keeps at most `keep` committed checkpoints (older ones pruned after a
    successful commit — never before).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, *, meta: Any = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x))
            if not isinstance(x, (bool, int, float)) else x, tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, meta=meta)
                self._prune()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _prune(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.directory, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
