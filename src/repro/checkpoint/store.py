"""Sharded numpy checkpoint store with async save and elastic restore.

Layout (one directory per step):

    <dir>/step_000100/
        MANIFEST.json          tree structure, shapes, dtypes, step
        <leaf-key>.npy         one file per leaf (host 0 writes in this
                               single-process container; on a real pod each
                               host writes its owned shards — the manifest
                               format already records per-leaf sharding)
        COMMIT                 written last; restore ignores dirs without it

Fault-tolerance contract:
  * atomic-by-rename: data is staged into `.tmp-step_X` and renamed after the
    COMMIT marker is in place, so a host failure mid-save never corrupts the
    latest checkpoint;
  * elastic restore: `restore_checkpoint(..., mesh=new_mesh, axes=...)`
    re-shards leaves onto a DIFFERENT mesh than the one that saved them —
    restoring a (2,16,16) run onto (16,16) (pod loss) or vice versa;
  * async: `AsyncCheckpointer` snapshots device arrays to host memory
    synchronously (cheap) and does the file I/O on a background thread, so
    training never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax

from repro.parallel.sharding import tree_shardings


def _flatten_with_keys(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path).replace("/", "_")
        safe = "".join(c if c.isalnum() or c in "._-[]'" else "_"
                       for c in key)
        out.append((safe, leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Blocking sharded save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten_with_keys(tree)
    manifest = {"step": step, "leaves": []}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"{key}.npy"), arr)
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       mesh=None, axes=None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  With mesh+axes, device-put each leaf with the
    sharding derived for the NEW mesh — the elastic-resharding path."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    flat_like, treedef = _flatten_with_keys(like)
    leaves = []
    shardings = None
    if mesh is not None and axes is not None:
        sh_tree = tree_shardings(axes, jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), like), mesh)
        shardings = [s for _, s in _flatten_with_keys(sh_tree)[0]]
    for i, (key, ref) in enumerate(flat_like):
        arr = np.load(os.path.join(path, f"{key}.npy"))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != model {ref.shape}")
        arr = arr.astype(ref.dtype)
        if shardings is not None:
            leaves.append(jax.device_put(arr, shardings[i]))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda x: 0, like)), leaves)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk asynchronously.

    `save(step, tree)` returns immediately after device_get; `wait()` joins
    the in-flight write (call before exiting or before deleting old steps).
    Keeps at most `keep` committed checkpoints (older ones pruned after a
    successful commit — never before).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._prune()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _prune(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.directory, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
