"""Checkpointing: sharded, async, elastic-reshardable."""
from repro.checkpoint.store import (
    save_checkpoint, restore_checkpoint, latest_step, AsyncCheckpointer)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]
