"""Fig-7 analogue: decode throughput across RWKV-4 model sizes.

Two numbers per size:
  * roofline tokens/s on the TARGET (TPU v5e): batch-1 decode is
    bandwidth-bound (arithmetic intensity ~1 FLOP/byte), so
    tokens/s = HBM_BW / bytes_per_token — reported for fp16 weights and for
    the Δ-PoT-packed weights (the paper's speedup mechanism: same ratio the
    paper gets from its on-chip + low-bit design);
  * measured CPU tokens/s for the sizes small enough to run here (169M),
    the "official implementation on commodity hardware" baseline of Fig 7.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import RWKV4_ARCHS, get_config
from repro.launch.roofline import HBM_BW
from repro.models.registry import get_model
from benchmarks.bench_resources import spec_bytes
from benchmarks.common import emit


def roofline_tokens_per_s(arch: str):
    model, b16, bq = spec_bytes(arch)
    # batch-1 decode reads every weight once per token
    return HBM_BW / b16, HBM_BW / bq


def measured_cpu_decode(arch: str, n_tokens: int = 12) -> float:
    model = get_model(arch)
    params = model.init_params(jax.random.PRNGKey(0))
    state = model.init_decode_state(1, n_tokens + 1)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((1, 1), jnp.int32)
    logits, state = step(params, state, tok, jnp.int32(0))  # compile
    jax.block_until_ready(logits)
    t0 = time.time()
    for i in range(n_tokens):
        logits, state = step(params, state, tok, jnp.int32(i + 1))
    jax.block_until_ready(logits)
    return n_tokens / (time.time() - t0)


def run():
    for arch in RWKV4_ARCHS:
        fp16_tps, q_tps = roofline_tokens_per_s(arch)
        emit(f"throughput/{arch}/roofline", 0.0,
             f"fp16_tok_s={fp16_tps:,.0f};dpot_tok_s={q_tps:,.0f};"
             f"speedup={q_tps/fp16_tps:.2f}x")
    # CPU measurement for the smallest size (the others exceed this
    # container's budget; the paper's CPU baseline is the same idea)
    tps = measured_cpu_decode("rwkv4-169m")
    emit("throughput/rwkv4-169m/cpu_measured", 1e6 / tps,
         f"tok_s={tps:.2f}")


if __name__ == "__main__":
    run()
