"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  bench_quant_ablation  — Table 1 (quantization scheme ablation)
  bench_resources       — Table 2 (footprint/compression accounting)
  bench_throughput      — Fig 7   (decode throughput across sizes)
  bench_energy_proxy    — Fig 8   (energy-efficiency proxy)
  bench_kernels         — §4 modules (kernel vs oracle)
  bench_serving         — continuous-batching engine vs the seed loop
  bench_prefill         — fused chunked prefill vs the per-op scan
  bench_prefix_cache    — prefix-cache TTFT vs cache-off serving
  bench_speculative     — self-speculative decode vs plain decode ticks
  bench_serving_slo     — bursty 2x-overload load vs the SLO layer
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_energy_proxy, bench_kernels,
                            bench_prefill, bench_prefix_cache,
                            bench_quant_ablation, bench_resources,
                            bench_serving, bench_serving_slo,
                            bench_speculative, bench_throughput)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_resources, bench_energy_proxy, bench_throughput,
                bench_kernels, bench_quant_ablation, bench_serving,
                bench_prefill, bench_prefix_cache, bench_speculative,
                bench_serving_slo):
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"{mod.__name__},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
