"""SLO-aware serving under overload: bursty/zipfian load vs the SLO layer.

A seeded open-loop load generator drives the engine the way production
traffic does — arrivals do not wait for completions:

  * BURSTY arrivals: exponential inter-arrival gaps whose rate
    alternates between a 4x-burst phase and a calm phase (mean held at
    the target rate), so the admission queue actually fills.
  * ZIPFIAN prompts: each prompt = a shared prefix drawn zipf-weighted
    from a small pool (so the prefix cache sees realistic reuse) + a
    unique random suffix.
  * Mixed priority classes (~20% priority 1) so shedding and priority
    admission have work to do.

Three phases:

  1. SUSTAINABLE RATE — closed-loop run at full occupancy; its
     requests/s sets the arrival rates below.
  2. UNLOADED baseline — the same workload at 0.5x sustainable on a
     default-SLO engine (unbounded queue): p50/p99 TTFT + inter-token
     latency with the engine comfortably keeping up.
  3. 2x OVERLOAD — double the sustainable rate, bursty, against the SLO
     engine (bounded queue, shed policy, prefill budget, cache-aware
     priority admission).  The gates (written to BENCH_serving.json
     with the standard provenance stamp):

       - p99 inter-token latency <= 3x the unloaded baseline (graceful
         degradation, not latency collapse),
       - zero engine errors,
       - every non-admitted request observable (submitted == finished +
         shed + backpressured + deadline-evicted: nothing silently
         lost),
       - post-run pool/cache invariants hold (free list full, no queued
         or active lanes, `PrefixCache.check_state`, zero outstanding
         leases, traced-once program cache), and
       - finished requests' token streams bit-identical to a fresh
         unbudgeted default-SLO run of the same prompts (the SLO layer
         changes WHEN work runs, never WHAT it computes).

In this fixed-shape masked engine a prefill call costs the same however
many lanes participate, so the budget's effect here is bounding
per-tick admitted prefill work (and spreading bursts) — the deferral
counter in the output shows it engaging; on hardware where prefill cost
scales with tokens the same knob caps the jitter directly.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.models.registry import get_model
from repro.serving import (AdmissionPolicy, Overloaded,
                           PrefixCacheConfig, ServingEngine, ServingSLO,
                           build_plan)

ARCH = "rwkv4-169m"
CHUNK = 16
N_TOKENS = 12
N_PREFIXES = 4
ZIPF_S = 1.2


def _make_trace(n: int, rate_rps: float, vocab: int, seed: int,
                *, deadline_s: float | None = None):
    """Seeded arrival trace: [(t_arrival, prompt, priority, deadline_s)].
    Gap rate alternates every 8 arrivals between 4x the target (burst)
    and the calm rate that keeps the overall mean at `rate_rps`."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, N_PREFIXES + 1, dtype=np.float64)
    pz = ranks ** -ZIPF_S
    pz /= pz.sum()
    prefixes = [rng.integers(0, vocab, size=CHUNK).tolist()
                for _ in range(N_PREFIXES)]
    trace, t = [], 0.0
    for k in range(n):
        burst = (k // 8) % 2 == 0
        mean_gap = (1.0 / (4.0 * rate_rps)) if burst \
            else (1.75 / rate_rps)
        t += float(rng.exponential(mean_gap))
        prefix = prefixes[int(rng.choice(N_PREFIXES, p=pz))]
        suffix = rng.integers(0, vocab,
                              size=int(rng.integers(3, 8))).tolist()
        priority = 1 if rng.random() < 0.2 else 0
        trace.append((t, prefix + suffix, priority, deadline_s))
    return trace


def _make_engine(plan, batch: int, *, slo=None, cache: bool = True):
    pc = PrefixCacheConfig(device_slots=32, host_slots=64) if cache \
        else None
    return ServingEngine(plan.model, plan=plan, max_batch=batch,
                         prefix_cache=pc, slo=slo)


def _drive(engine, trace):
    """Open-loop driver: submit each request at its trace time (wall
    clock), tick the engine in between.  Returns (handles of accepted
    requests, backpressured count, engine error count)."""
    handles, backpressured, errors = [], 0, 0
    i, t0 = 0, time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            _, prompt, priority, deadline_s = trace[i]
            i += 1
            try:
                handles.append(engine.submit(
                    prompt, max_new_tokens=N_TOKENS,
                    priority=priority, deadline_s=deadline_s))
            except Overloaded:
                backpressured += 1
        sch = engine.scheduler
        if sch.slots or sch.queue:
            try:
                engine.step()
            except Exception:
                errors += 1
                raise
        elif i < len(trace):
            time.sleep(min(2e-3, max(trace[i][0] - now, 0.0)))
        else:
            return handles, backpressured, errors


def _phase_record(name, trace, handles, backpressured, snap):
    outcomes = [h.outcome for h in handles]
    return {
        "phase": name,
        "submitted": len(trace),
        "finished": outcomes.count("finished"),
        "shed": outcomes.count("shed"),
        "deadline_evicted": outcomes.count("deadline"),
        "backpressured": backpressured,
        "ttft_p50_ms": snap["ttft_p50_s"] * 1e3,
        "ttft_p99_ms": snap["ttft_p99_s"] * 1e3,
        "itl_p50_ms": snap["itl_p50_s"] * 1e3,
        "itl_p99_ms": snap["itl_p99_s"] * 1e3,
        "mean_active_slots": snap["mean_active_slots"],
        "mean_queue_depth": snap["mean_queue_depth"],
        "peak_queue_depth": snap["peak_queue_depth"],
        "decode_tok_s": snap["decode_tokens_per_s"],
        "budget_deferred_tokens": snap["budget_deferred_tokens"],
        "cache_hit_rate": snap["cache_hit_rate"],
    }


def _check_invariants(engine, batch: int) -> list[str]:
    """Post-run pool/cache/program invariants; returns violations."""
    bad = []
    if engine.pool.n_free != batch:
        bad.append(f"pool free list {engine.pool.n_free}/{batch}")
    if engine.scheduler.slots or engine.scheduler.queue:
        bad.append("scheduler not drained")
    if engine.prefix_cache is not None:
        try:
            engine.prefix_cache.check_state()
        except AssertionError as e:
            bad.append(f"cache check_state: {e}")
        leases = sum(e.refcount for e in
                     list(engine.prefix_cache._device.values()) +
                     list(engine.prefix_cache._host.values()))
        if leases:
            bad.append(f"{leases} outstanding leases")
    if engine.trace_counts != {"decode": 1, "prefill": 1}:
        bad.append(f"retraced: {engine.trace_counts}")
    return bad


def run(*, smoke: bool = False, json_path: str | None = None,
        devices: int | None = None):
    batch = 8
    n_unloaded = 16 if smoke else 32
    n_overload = 48 if smoke else 128
    mesh = None
    if devices is not None:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(devices)
    model = get_model(ARCH, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    # ONE plan for every engine below: all phases share the compiled
    # programs, so trace_counts staying 1 covers the whole bench
    plan = build_plan(model, params, prefill_chunk=CHUNK, mesh=mesh)
    vocab = model.cfg.vocab

    # warmup: compile both programs outside every timed phase
    eng = _make_engine(plan, batch, cache=False)
    eng.submit([1] * (CHUNK + 2), max_new_tokens=2)
    eng.run()

    # phase 1: sustainable rate (closed loop at full occupancy)
    eng = _make_engine(plan, batch, cache=False)
    closed = _make_trace(2 * batch, 1e9, vocab, seed=1)
    t0 = time.perf_counter()
    for _, prompt, _, _ in closed:
        eng.submit(prompt, max_new_tokens=N_TOKENS)
    eng.run()
    rate = len(closed) / (time.perf_counter() - t0)
    emit("serving_slo/sustainable", 1e6 / rate, f"req_s={rate:.2f}")

    # phase 2: unloaded baseline at 0.5x sustainable, default SLO
    eng_u = _make_engine(plan, batch)
    trace_u = _make_trace(n_unloaded, 0.5 * rate, vocab, seed=2)
    h_u, bp_u, err_u = _drive(eng_u, trace_u)
    snap_u = eng_u.counters.snapshot()
    rec_u = _phase_record("unloaded_0.5x", trace_u, h_u, bp_u, snap_u)
    emit("serving_slo/unloaded", snap_u["mean_itl_s"] * 1e6,
         f"itl_p99_ms={rec_u['itl_p99_ms']:.2f};"
         f"ttft_p99_ms={rec_u['ttft_p99_ms']:.2f}")

    # phase 3: 2x overload, bursty, SLO engine
    slo = ServingSLO(
        prefill_budget=2 * CHUNK,
        admission=AdmissionPolicy(max_queue=2 * batch, overload="shed",
                                  prefer_cache_hits=True, aging_ticks=16))
    eng_o = _make_engine(plan, batch, slo=slo)
    trace_o = _make_trace(n_overload, 2.0 * rate, vocab, seed=3,
                          deadline_s=None if smoke else 20.0)
    h_o, bp_o, err_o = _drive(eng_o, trace_o)
    snap_o = eng_o.counters.snapshot()
    rec_o = _phase_record("overload_2x_bursty", trace_o, h_o, bp_o,
                          snap_o)
    violations = _check_invariants(eng_o, batch)

    # accounting: every submitted request must be observable somewhere
    accounted = (rec_o["finished"] + rec_o["shed"] +
                 rec_o["deadline_evicted"] + rec_o["backpressured"])
    # bit parity: finished requests replayed on a fresh default-SLO,
    # cache-off engine must reproduce their token streams exactly
    finished = [(h.request.prompt, h.tokens) for h in h_o
                if h.outcome == "finished"]
    eng_p = _make_engine(plan, batch, cache=False)
    replays = [eng_p.submit(p, max_new_tokens=N_TOKENS)
               for p, _ in finished]
    eng_p.run()
    identical = all(rh.tokens == toks for rh, (_, toks)
                    in zip(replays, finished))

    itl_ratio = (rec_o["itl_p99_ms"] / rec_u["itl_p99_ms"]
                 if rec_u["itl_p99_ms"] > 0 else float("inf"))
    gates = {
        "p99_itl_overload_vs_unloaded": {
            "value": itl_ratio, "threshold": 3.0,
            "pass": itl_ratio <= 3.0},
        "zero_engine_errors": {
            "value": err_u + err_o, "threshold": 0,
            "pass": err_u + err_o == 0},
        "all_non_admitted_observable": {
            "value": accounted, "threshold": rec_o["submitted"],
            "pass": accounted == rec_o["submitted"]},
        "post_run_invariants": {
            "value": violations or "ok", "threshold": "ok",
            "pass": not violations},
        "admitted_streams_bit_identical": {
            "value": len(finished), "threshold": len(finished),
            "pass": identical and bool(finished)},
    }
    emit("serving_slo/overload_2x", snap_o["mean_itl_s"] * 1e6,
         f"itl_p99_ratio={itl_ratio:.2f}x;"
         f"shed={rec_o['shed']};backpressured={bp_o};"
         f"deadline={rec_o['deadline_evicted']};"
         f"finished={rec_o['finished']}/{rec_o['submitted']};"
         f"gates={'PASS' if all(g['pass'] for g in gates.values()) else 'FAIL'}")

    if json_path:
        write_bench_json(json_path, {
            "arch": ARCH,
            "batch": batch,
            "n_tokens": N_TOKENS,
            "sustainable_req_s": rate,
            "slo": {"prefill_budget": slo.prefill_budget,
                    "max_queue": slo.admission.max_queue,
                    "overload": slo.admission.overload,
                    "aging_ticks": slo.admission.aging_ticks},
            "records": [rec_u, rec_o],
            "gates": gates,
        })
    if not all(g["pass"] for g in gates.values()):
        raise SystemExit(f"serving SLO gates failed: "
                         f"{ {k: g for k, g in gates.items() if not g['pass']} }")
    return gates


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized traces (16 unloaded / 48 overload)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serving.json")
    ap.add_argument("--devices", type=int, default=None,
                    help="drive the engines on a data-parallel serving "
                         "mesh over N local devices (0 = all visible)")
    args = ap.parse_args()
    run(smoke=args.smoke,
        json_path="BENCH_serving.json" if args.json else None,
        devices=args.devices)
