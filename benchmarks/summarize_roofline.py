"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline
table (single-pod per the assignment; multi-pod rows available via --mesh).

    PYTHONPATH=src:. python -m benchmarks.summarize_roofline [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def load(mesh: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def fmt_row(r: dict) -> str:
    if r["status"] == "skip":
        return (f"| {r['cell'].split('__')[0]} | "
                f"{r['cell'].split('__')[1]} | — | — | — | — | skipped | — |")
    ro = r["roofline"]
    dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
    ur = ro.get("useful_ratio")
    return (f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.2e} | "
            f"{ro['memory_s']:.2e} | {ro['collective_s']:.2e} | "
            f"**{ro['bottleneck']}** | {dom:.2e} | "
            f"{ur:.3f} |" if ur is not None else "—")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load(args.mesh)
    print(f"| arch | shape | compute (s) | memory (s) | collective (s) | "
          f"bottleneck | dominant (s) | useful FLOP ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
