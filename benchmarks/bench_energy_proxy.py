"""Fig-8 analogue: energy-efficiency proxy per decoded token.

Vivado power reports don't transfer; the architecture-independent proxy is
data movement + compute energy:
    E_token = hbm_bytes * E_HBM + flops * E_MAC
with representative 7nm-class constants (pJ): HBM access ~7 pJ/byte,
bf16 MAC ~0.3 pJ/flop.  The paper's win comes from moving fewer bytes
(quantized weights) and keeping intermediates on-chip; the same two levers
set this proxy.
"""
from __future__ import annotations

from repro.configs.base import RWKV4_ARCHS
from repro.models.registry import get_model
from benchmarks.bench_resources import spec_bytes
from benchmarks.common import emit

E_HBM_PJ_PER_BYTE = 7.0
E_MAC_PJ_PER_FLOP = 0.3


def run():
    for arch in RWKV4_ARCHS:
        model, b16, bq = spec_bytes(arch)
        n = model.param_count()
        flops = 2.0 * n                       # per decoded token
        e_fp16 = b16 * E_HBM_PJ_PER_BYTE + flops * E_MAC_PJ_PER_FLOP
        e_qnt = bq * E_HBM_PJ_PER_BYTE + flops * E_MAC_PJ_PER_FLOP
        emit(f"energy/{arch}", 0.0,
             f"fp16_uJ_tok={e_fp16/1e6:.1f};dpot_uJ_tok={e_qnt/1e6:.1f};"
             f"gain={e_fp16/e_qnt:.2f}x")


if __name__ == "__main__":
    run()
