"""§Perf hillclimb driver: fused-flash-attention projection for a cell.

Methodology (EXPERIMENTS.md §Perf): interpret-mode Pallas cannot be
*measured* through the dry-run (its functional grid loop copies whole
arrays), so the kernel's effect is spliced structurally:

  1. lower the BASE cell                         -> terms_base   (measured)
  2. lower the cell with attention STUBBED       -> terms_stub   (measured)
     (attention's traffic/flops = base - stub)
  3. add the kernel's analytic BlockSpec traffic -> terms_proj
     terms_proj = terms_stub + kernel_traffic(...)   per layer count

The kernel itself is validated for correctness separately (forward AND
custom-VJP backward vs the XLA oracle, tests/test_kernels.py).

    PYTHONPATH=src:. python -m benchmarks.hillclimb_flash smollm-135m train_4k
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json    # noqa: E402
import sys     # noqa: E402

from repro.configs.base import SHAPES, get_config          # noqa: E402
from repro.kernels.flash_attention import kernel_traffic   # noqa: E402
from repro.launch.dryrun import OUT_DIR, run_cell          # noqa: E402
from repro.launch.roofline import HBM_BW, PEAK_FLOPS       # noqa: E402


def project(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_tag = "multi" if multi_pod else "single"
    base_path = os.path.join(OUT_DIR,
                             f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(base_path):
        base = json.load(open(base_path))
    else:
        base = run_cell(arch, shape_name, multi_pod)
    stub = run_cell(arch, shape_name, multi_pod,
                    cfg_overrides={"attn_stub": True},
                    variant_tag="attnstub")
    rb, rs = base["roofline"], stub["roofline"]

    # per-device dims on the mesh
    chips = base["chips"]
    data = 16
    model = 16
    pod = 2 if multi_pod else 1
    B_dev = max(shape.global_batch // (data * pod), 1)
    H = cfg.n_heads
    H_dev = H // model if H % model == 0 else H
    n_attn = cfg.n_layers + cfg.enc_layers
    S = shape.seq_len
    kt = kernel_traffic(B_dev, H_dev, S, S, cfg.resolved_head_dim,
                        causal=True, train=(shape.kind == "train"))
    k_bytes = kt["bytes"] * n_attn
    k_flops = kt["flops"] * n_attn

    proj = {
        "compute_s": rs["compute_s"] + k_flops / PEAK_FLOPS,
        "memory_s": rs["memory_s"] + k_bytes / HBM_BW,
        "collective_s": rs["collective_s"],
    }
    attn_measured = {
        "flops": rb["flops"] - rs["flops"],
        "bytes": rb["hbm_bytes"] - rs["hbm_bytes"],
    }
    rec = {
        "cell": f"{arch}__{shape_name}__{mesh_tag}__flashproj",
        "status": "ok", "kind": "projection",
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "chips": chips,
        "method": "stub-diff + BlockSpec-analytic kernel traffic",
        "base_terms": {k: rb[k] for k in
                       ("compute_s", "memory_s", "collective_s")},
        "stub_terms": {k: rs[k] for k in
                       ("compute_s", "memory_s", "collective_s")},
        "xla_attention_measured": attn_measured,
        "kernel_analytic": {"bytes": k_bytes, "flops": k_flops,
                            "per_layer": kt, "layers": n_attn,
                            "B_dev": B_dev, "H_dev": H_dev},
        "roofline": {
            **proj,
            "bottleneck": max(proj, key=proj.get).replace("_s", ""),
            "flops": rs["flops"] + k_flops,
            "hbm_bytes": rs["hbm_bytes"] + k_bytes,
            "coll_bytes": rs["coll_bytes"],
            "coll_breakdown": rs["coll_breakdown"],
        },
    }
    with open(os.path.join(OUT_DIR, rec["cell"] + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    dom_b = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
    dom_p = max(proj.values())
    print(f"{arch} {shape_name} [{mesh_tag}]")
    print(f"  base : compute {rb['compute_s']:.3e}  memory "
          f"{rb['memory_s']:.3e}  coll {rb['collective_s']:.3e}")
    print(f"  stub : compute {rs['compute_s']:.3e}  memory "
          f"{rs['memory_s']:.3e}  coll {rs['collective_s']:.3e}")
    print(f"  proj : compute {proj['compute_s']:.3e}  memory "
          f"{proj['memory_s']:.3e}  coll {proj['collective_s']:.3e}")
    print(f"  dominant term {dom_b:.3e} -> {dom_p:.3e}  "
          f"({dom_b / dom_p:.2f}x)")
    return rec


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "smollm-135m"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    multi = len(sys.argv) > 3 and sys.argv[3] == "multi"
    project(arch, shape, multi)
