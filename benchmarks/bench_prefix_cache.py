"""Prefix cache TTFT: zipfian prefix-reuse serving, cache on vs off.

The serving win under test: an RWKV prompt prefix collapses to ONE O(1)
recurrent state, so a repeated prefix (system prompt, few-shot header,
multi-turn history) costs a state copy instead of a prefill pass —
near-zero time-to-first-token for the cached portion
(src/repro/serving/prefix_cache.py; bit-parity pinned in
tests/test_prefix_cache.py).

Workload: K shared system prompts of `PREFIX_CHUNKS` prefill chunks,
drawn zipfian (rank-weighted — a few prefixes dominate, the long tail
still misses), each request appending a short unique suffix.  Requests
run through two identical engines — prefix cache OFF then ON — and every
request's generated tokens are asserted EQUAL between the two runs
before any number is reported: the speedup must come from skipping
redundant prefill, not from changing what is served.

Reported per prefix rank: observed TTFT both ways.  Gates (enforced via
exit status on full runs, recorded always):

  * mean TTFT improves >= 5x with the cache on, and
  * the workload's prefix hit rate is >= 60% (the zipf draw actually
    exercised the cache; below that the TTFT comparison is vacuous).

`--json` writes BENCH_prefix.json; `--smoke` shrinks the workload for
CI, where the schema is validated but timing gates are not enforced.

Run: PYTHONPATH=src python -m benchmarks.bench_prefix_cache [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.models.registry import get_model
from repro.runtime.monitor import ServingCounters
from repro.serving import PrefixCacheConfig, ServingEngine

ARCH = "rwkv4-169m"
CHUNK = 16
JSON_PATH = "BENCH_prefix.json"
GATE_TTFT_X = 5.0
GATE_HIT_RATE = 0.6
ZIPF_S = 1.1                 # rank weight ~ 1/rank^s


def _workload(vocab: int, *, n_prefixes: int, prefix_chunks: int,
              n_requests: int, suffix_len: int = 4, seed: int = 0):
    """Zipfian prefix-reuse request stream: each request is (shared
    system prompt drawn by rank weight) + (unique suffix)."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab,
                             size=prefix_chunks * CHUNK).tolist()
                for _ in range(n_prefixes)]
    w = 1.0 / np.arange(1, n_prefixes + 1) ** ZIPF_S
    ranks = rng.choice(n_prefixes, size=n_requests, p=w / w.sum())
    # every prefix appears at least once so the tail misses are real
    ranks[:n_prefixes] = np.arange(n_prefixes)
    return [(int(r),
             prefixes[r] + rng.integers(0, vocab, size=suffix_len).tolist())
            for r in ranks]


def _serve(model, params, workload, *, cache_slots: int,
           n_new: int = 4) -> tuple[dict, list, list, ServingEngine]:
    """Drive the request stream to completion one request at a time (the
    TTFT comparison wants each request's prefill wall time unshadowed by
    neighbors), returning per-request TTFT and tokens.  Both device
    programs AND the cache's read/write/probe paths are compiled by a
    throwaway warmup request, then the counters reset — compile time is
    not time-to-first-token."""
    cache = PrefixCacheConfig(device_slots=cache_slots, host_slots=0) \
        if cache_slots else None
    engine = ServingEngine(model, params=params, max_batch=2,
                           prefill_chunk=CHUNK, fused_prefill=True,
                           prefix_cache=cache)
    warm = [7] * (2 * CHUNK + 1)         # 2 boundaries + proper suffix
    engine.submit(warm, max_new_tokens=2)
    engine.run()
    engine.submit(warm + [9], max_new_tokens=2)   # exercises the hit path
    engine.run()
    if engine.prefix_cache is not None:
        assert engine.prefix_cache.stats["hits"] == 1, "warmup never hit"
    counters = ServingCounters()
    engine.counters = engine.scheduler.counters = counters
    if engine.prefix_cache is not None:
        engine.prefix_cache.counters = counters
    tokens, ttft = [], []
    t0 = time.perf_counter()
    for _, prompt in workload:
        h = engine.submit(prompt, max_new_tokens=n_new)
        engine.run()
        tokens.append(h.tokens)
        ttft.append(counters.ttft_s[-1])
    wall = time.perf_counter() - t0
    snap = counters.snapshot()
    snap["wall_s"] = wall
    return snap, ttft, tokens, engine


def run(smoke: bool = False, json_out: bool = False) -> bool:
    model = get_model(ARCH, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    n_prefixes = 2 if smoke else 4
    prefix_chunks = 4 if smoke else 32
    n_requests = 6 if smoke else 48
    workload = _workload(model.cfg.vocab, n_prefixes=n_prefixes,
                         prefix_chunks=prefix_chunks,
                         n_requests=n_requests)
    cache_slots = n_prefixes * prefix_chunks + 8

    snap_off, ttft_off, toks_off, _ = _serve(model, params, workload,
                                             cache_slots=0)
    snap_on, ttft_on, toks_on, eng = _serve(model, params, workload,
                                            cache_slots=cache_slots)
    # the non-negotiable precondition: identical tokens, request by
    # request — only then do the TTFT numbers mean anything
    assert toks_on == toks_off, "cached serving changed the output tokens"

    cache_snap = eng.prefix_cache.snapshot()
    mean_off = float(np.mean(ttft_off))
    mean_on = float(np.mean(ttft_on))
    improvement = mean_off / max(mean_on, 1e-9)
    # hit rate over the measured workload only (counters were reset after
    # warmup; cache_snap additionally counts the warmup probes)
    hit_rate = snap_on["cache_hit_rate"]
    records = []
    for rank in range(n_prefixes):
        idx = [i for i, (r, _) in enumerate(workload) if r == rank]
        records.append({
            "prefix_rank": rank,
            "requests": len(idx),
            "prompt_tokens": len(workload[idx[0]][1]),
            "mean_ttft_off_ms": round(1e3 * float(
                np.mean([ttft_off[i] for i in idx])), 3),
            "mean_ttft_on_ms": round(1e3 * float(
                np.mean([ttft_on[i] for i in idx])), 3),
        })
        emit(f"prefix_cache/{model.cfg.name}/rank{rank}",
             1e6 * float(np.mean([ttft_on[i] for i in idx])),
             f"requests={len(idx)};"
             f"ttft_off_ms={records[-1]['mean_ttft_off_ms']};"
             f"ttft_on_ms={records[-1]['mean_ttft_on_ms']}")

    gates = {
        "ttft_improvement": {
            "value": round(improvement, 3), "target": GATE_TTFT_X,
            "pass": improvement >= GATE_TTFT_X},
        "hit_rate": {
            "value": round(hit_rate, 3), "target": GATE_HIT_RATE,
            "pass": hit_rate >= GATE_HIT_RATE},
    }
    ok = True
    for name, g in gates.items():
        ok = ok and g["pass"]
        print(f"gate: {name} = {g['value']} (target >= {g['target']}) -> "
              f"{'PASS' if g['pass'] else 'FAIL'}")

    if json_out:
        write_bench_json(JSON_PATH, {
            "bench": "prefix_cache",
            "arch": model.cfg.name,
            "backend": jax.default_backend(),
            "smoke": smoke,
            "chunk": CHUNK,
            "n_prefixes": n_prefixes,
            "prefix_chunks": prefix_chunks,
            "n_requests": n_requests,
            "zipf_s": ZIPF_S,
            "tokens_identical": toks_on == toks_off,
            "mean_ttft_off_ms": round(1e3 * mean_off, 3),
            "mean_ttft_on_ms": round(1e3 * mean_on, 3),
            "cached_tokens": snap_on["cached_tokens"],
            "prefill_tokens_on": snap_on["prefill_tokens"],
            "prefill_tokens_off": snap_off["prefill_tokens"],
            "cache": cache_snap,
            "records": records,
            "gates": gates,
        })
    # CI smoke pins the script + JSON schema, not shared-runner timing
    return ok or smoke


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal workload for CI: gates reported but "
                         "not enforced")
    ap.add_argument("--json", action="store_true",
                    help=f"write {JSON_PATH} (machine-readable records)")
    args = ap.parse_args()
    return 0 if run(smoke=args.smoke, json_out=args.json) else 1


if __name__ == "__main__":
    raise SystemExit(main())
