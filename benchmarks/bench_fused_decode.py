"""Fused decode-layer kernel vs the per-op decode path.

Measures single-token decode throughput (tokens/s at batch 8, greedy,
state carried across steps) for three executions of the SAME math — all
three produce identical argmax tokens (asserted before timing):

  * PER-OP    — one device launch per datapath op (layernorm, each
    token-shift mix, each matvec, the WKV update, each gate), i.e. every
    intermediate makes an HBM round-trip between launches.  This is the
    baseline the paper's fully-on-chip pipeline is built against (and what
    RWKVQuant's bandwidth analysis says dominates single-token inference).
  * MONOLITHIC — the engine's per-op path: `decode_step` under one jit.
    XLA fuses elementwise chains but still materializes matmul and scan
    intermediates between its kernels.
  * FUSED      — `decode_step_fused`: ONE Pallas launch per block
    (kernels/fused_decode.py); off-TPU it runs in interpret mode, so its
    advantage here is launch/round-trip amortization vs PER-OP; on TPU the
    same launch keeps state + intermediates VMEM-resident.

Also reports an analytic HBM bytes/token estimate for the per-op vs fused
datapaths, fp(bf16) vs Δ-PoT-packed weights — the paper's bandwidth
story.  The acceptance gate for PR 2 is fused >= 1.5x PER-OP at batch 8
on CPU; fused-vs-MONOLITHIC is reported for honesty (expect ~1x on CPU,
where XLA already fuses the whole step into one program).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.quant.serving import pack_params
from repro.core.wkv.wkv4 import WKV4State, wkv4_step
from repro.models import layers as L
from repro.models.registry import get_model
from repro.models.rwkv4 import block_decode

ARCH = "rwkv4-169m"
BATCH = 8
N_STEPS = 16


# ---------------------------------------------------------------------------
# PER-OP path: every datapath op is its own jitted device call
# ---------------------------------------------------------------------------


def build_per_op_step(model):
    """decode_step as one launch PER OP (rwkv4).  Same math/dtype sequence
    as models.rwkv4.block_decode, so tokens match the oracle."""
    cfg = model.cfg
    dt = jnp.dtype(cfg.dtype)
    j = jax.jit

    embed = j(lambda emb, t: jnp.take(emb, t[:, 0], axis=0).astype(dt))
    ln = j(lambda p, x: L.apply_norm(p, x[:, None], "layernorm")[:, 0])
    mix = j(lambda h, prev, m: h * m + prev * (1.0 - m))
    mm = j(lambda a, w: a @ w)
    decay = j(lambda td: jnp.exp(td.astype(jnp.float32)))
    wkv = j(lambda a, b, o, k, v, w, u: wkv4_step(
        WKV4State(a.astype(jnp.float32), b.astype(jnp.float32),
                  o.astype(jnp.float32)),
        k.astype(jnp.float32), v.astype(jnp.float32), w,
        u.astype(jnp.float32)))
    gate = j(lambda r, out: jax.nn.sigmoid(r) * out.astype(r.dtype))
    add = j(lambda x, y: x + y.astype(x.dtype))
    sig = j(jax.nn.sigmoid)
    sqrelu = j(lambda k: jnp.square(jax.nn.relu(k)))
    mul = j(lambda a, b: a * b)
    head = j(lambda x, w: x @ w.astype(x.dtype))
    cast = j(lambda s, like: s.astype(like.dtype))

    def step(params, layer_params, state, tokens):
        """state: list of per-layer dicts (host-sliced once, outside)."""
        x = embed(params["embed"], tokens)
        x = ln(params["ln0"], x)
        new_state = []
        for lp, st in zip(layer_params, state):
            h = ln(lp["ln1"], x)
            p = lp["att"]
            r = mm(mix(h, st["att_x"], p["time_mix_r"]), p["wr"])
            k = mm(mix(h, st["att_x"], p["time_mix_k"]), p["wk"])
            v = mm(mix(h, st["att_x"], p["time_mix_v"]), p["wv"])
            w = decay(p["time_decay"])
            nwkv, out = wkv(st["wkv_a"], st["wkv_b"], st["wkv_o"],
                            k, v, w, p["time_first"])
            att = mm(gate(r, out), p["wo"])
            x2 = add(x, att)
            h2 = ln(lp["ln2"], x2)
            p = lp["ffn"]
            rr = sig(mm(mix(h2, st["ffn_x"], p["time_mix_r"]), p["wr"]))
            kk = sqrelu(mm(mix(h2, st["ffn_x"], p["time_mix_k"]), p["wk"]))
            ffn = mul(rr, mm(kk, p["wv"]))
            x = add(x2, ffn)
            new_state.append({
                "att_x": cast(h, st["att_x"]),
                "ffn_x": cast(h2, st["ffn_x"]),
                "wkv_a": cast(nwkv.a, st["wkv_a"]),
                "wkv_b": cast(nwkv.b, st["wkv_b"]),
                "wkv_o": cast(nwkv.o, st["wkv_o"])})
        x = ln(params["ln_f"], x)
        return head(x, params["head"])[:, None], new_state

    return step


# ---------------------------------------------------------------------------
# HBM bytes/token (analytic; see docs/kernels.md §bandwidth)
# ---------------------------------------------------------------------------


def hbm_bytes_per_token(cfg, batch: int, packed: bool):
    """(per_op_bytes, fused_bytes) per decoded token.

    Weight stream: every launch re-reads its weights; both paths read each
    weight once per step (XLA/Pallas keep them HBM-resident), at 2 B (bf16)
    or 1 B + per-channel scales (Δ-PoT W8).  Per-op additionally round-trips
    every intermediate (written by one launch, read by the next): ~18
    (B, D)-sized activations + r/k/v/gates per layer, plus the state twice
    (read + write per launch touching it).  Fused writes only the new state
    and the block output."""
    D, F, Lc, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    wb = 1 if packed else 2
    per_layer_w = (5 * D * D + 2 * D * F) * wb + (7 * D * 4 if packed else 0)
    weights = Lc * per_layer_w + (V * D + D * V) * wb
    state = Lc * 5 * batch * D * 2          # bf16 state leaves
    act = batch * D * 2
    per_layer_int = 18 * act + 2 * batch * F * 2
    per_op = weights + Lc * (per_layer_int * 2 + state // Lc * 2)
    fused = weights + state * 2 + Lc * act * 2 + batch * V * 4
    return per_op / batch, fused / batch


# ---------------------------------------------------------------------------


def _tokens_per_s(step_fn, n_steps=N_STEPS):
    out = step_fn()                      # warmup / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        out = step_fn()
    jax.block_until_ready(out)
    return BATCH * n_steps / (time.perf_counter() - t0)


def run():
    model = get_model(ARCH, smoke=True)
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, 1)), jnp.int32)

    # --- build the three paths ---------------------------------------------
    per_op_step = build_per_op_step(model)
    cast = model.cast_params(params)
    layer_params = [jax.tree_util.tree_map(lambda p: p[i], cast["blocks"])
                    for i in range(cfg.n_layers)]
    mono = jax.jit(model.decode_step)
    fused = jax.jit(model.decode_step_fused)

    # --- token equivalence before timing -----------------------------------
    st0 = model.init_decode_state(BATCH, 0, jnp.bfloat16)
    st_list = [jax.tree_util.tree_map(lambda s: s[i], st0)
               for i in range(cfg.n_layers)]
    l_po, _ = per_op_step(cast, layer_params, st_list, toks)
    l_mono, _ = mono(params, st0, toks, jnp.int32(0))
    l_fu, _ = fused(params, st0, toks, jnp.int32(0))
    assert np.array_equal(np.argmax(np.asarray(l_po, np.float32), -1),
                          np.argmax(np.asarray(l_mono, np.float32), -1))
    assert np.array_equal(np.asarray(l_mono, np.float32),
                          np.asarray(l_fu, np.float32))

    # --- time them (state carried across steps, like the engine) ------------
    def po():
        po.state = per_op_step(cast, layer_params, po.state, toks)[1]
        return po.state
    po.state = st_list

    def mo():
        _, mo.state = mono(params, mo.state, toks, jnp.int32(0))
        return mo.state
    mo.state = st0

    def fu():
        _, fu.state = fused(params, fu.state, toks, jnp.int32(0))
        return fu.state
    fu.state = st0

    tps_po = _tokens_per_s(po)
    tps_mo = _tokens_per_s(mo)
    tps_fu = _tokens_per_s(fu)

    hbm_po, hbm_fu = hbm_bytes_per_token(cfg, BATCH, packed=False)
    emit(f"fused_decode/{ARCH}/batch{BATCH}/fp", 1e6 / max(tps_fu, 1e-9),
         f"per_op_tok_s={tps_po:.1f};mono_tok_s={tps_mo:.1f};"
         f"fused_tok_s={tps_fu:.1f};fused_vs_per_op={tps_fu/tps_po:.2f}x;"
         f"fused_vs_mono={tps_fu/tps_mo:.2f}x;"
         f"hbm_bytes_tok_per_op={hbm_po:.3g};hbm_bytes_tok_fused={hbm_fu:.3g}")

    # --- quantized: packed codes into the kernel ----------------------------
    packed = pack_params(params)
    from repro.core.quant.serving import unpack_params
    mono_q = jax.jit(lambda p, s, t: model.decode_step(
        unpack_params(p), s, t, jnp.int32(0)))
    fused_q = jax.jit(lambda p, s, t: model.decode_step_fused(
        p, s, t, jnp.int32(0)))
    l_mq, _ = mono_q(packed, st0, toks)
    l_fq, _ = fused_q(packed, st0, toks)
    assert np.array_equal(np.asarray(l_mq, np.float32),
                          np.asarray(l_fq, np.float32))

    def moq():
        _, moq.state = mono_q(packed, moq.state, toks)
        return moq.state
    moq.state = st0

    def fuq():
        _, fuq.state = fused_q(packed, fuq.state, toks)
        return fuq.state
    fuq.state = st0

    tps_moq = _tokens_per_s(moq)
    tps_fuq = _tokens_per_s(fuq)
    hbm_poq, hbm_fuq = hbm_bytes_per_token(cfg, BATCH, packed=True)
    emit(f"fused_decode/{ARCH}/batch{BATCH}/dpot_w8",
         1e6 / max(tps_fuq, 1e-9),
         f"mono_tok_s={tps_moq:.1f};fused_tok_s={tps_fuq:.1f};"
         f"fused_vs_mono={tps_fuq/tps_moq:.2f}x;"
         f"hbm_bytes_tok_per_op={hbm_poq:.3g};"
         f"hbm_bytes_tok_fused={hbm_fuq:.3g}")

    ok = tps_fu / tps_po >= 1.5
    print(f"gate: fused {tps_fu:.1f} tok/s vs per-op {tps_po:.1f} tok/s "
          f"= {tps_fu/tps_po:.2f}x (target >= 1.5x) -> "
          f"{'PASS' if ok else 'FAIL'}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
