"""Fused decode kernels vs the per-op decode path, across depths/batches.

Measures single-token decode throughput (tokens/s, greedy, state carried
across steps) for four executions of the SAME math — all producing
identical argmax tokens (asserted before timing):

  * PER-OP      — one device launch per datapath op (layernorm, each
    token-shift mix, each matvec, the WKV update, each gate), i.e. every
    intermediate makes an HBM round-trip between launches.  This is the
    baseline the paper's fully-on-chip pipeline is built against (and what
    RWKVQuant's bandwidth analysis says dominates single-token inference).
  * MONOLITHIC  — the engine's per-op path: `decode_step` under one jit.
    XLA fuses elementwise chains but still materializes matmul and scan
    intermediates between its kernels.
  * FUSED-BLOCK — `decode_step_fused`: ONE Pallas launch per block
    (kernels/fused_decode.py), L launches per step under `lax.scan`.
  * FUSED-MODEL — `decode_step_fused_model`: the whole-model megakernel —
    ONE Pallas launch per step, residual on-chip across the entire stack,
    each layer's weights fetched as one contiguous chunk per dtype
    (pre-chunked once outside the step via `prepare_fused_model_params`,
    exactly as the serving engine runs it) and double-buffered behind the
    previous layer's compute in the streaming binding.  Off-TPU all
    Pallas paths run in interpret mode, so the megakernel's advantage
    here is launch amortization plus the chunked weight stream (one
    fetch per layer instead of one gather per leaf); on TPU the same
    launch additionally keeps residual + state VMEM-resident for the
    entire stack.

The sweep covers batch 1 and 8 at several model depths (launch overhead
scales with L, which is exactly what the megakernel amortizes) and reports
an analytic HBM bytes/token estimate per path, fp(bf16) vs Δ-PoT-packed —
the paper's bandwidth story.

Gates (enforced via exit status on full runs, recorded always):
  * fused-block >= 1.5x PER-OP at batch 8 (PR 2's gate, kept honest);
  * fused-model >= 1.0x fused-block at batch 8 (the megakernel must not
    lose to the per-block path it replaces).

`--json` writes the machine-readable `BENCH_decode.json` (median tok/s and
bytes/token per variant) so the repo's perf trajectory is tracked across
PRs; `--smoke` shrinks the sweep for CI, where gates are reported but not
enforced (shared-runner timing is too noisy to fail a build on).

Run: PYTHONPATH=src python -m benchmarks.bench_fused_decode [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, tokens_per_s, write_bench_json
from repro.core.quant.serving import pack_params, unpack_params
from repro.core.wkv.wkv4 import WKV4State, wkv4_step
from repro.models import layers as L
from repro.models.registry import get_model
from repro.models.rwkv4 import block_decode   # noqa: F401  (datapath ref)

ARCH = "rwkv4-169m"
BATCHES = (1, 8)
DEPTHS = (2, 4, 8)
N_ITERS = 12
N_ROUNDS = 5     # interleaved re-measurements per variant; best-of-rounds
                 # (shared machines: load spikes hit single rounds, not 5)
JSON_PATH = "BENCH_decode.json"


# ---------------------------------------------------------------------------
# PER-OP path: every datapath op is its own jitted device call
# ---------------------------------------------------------------------------


def build_per_op_step(model):
    """decode_step as one launch PER OP (rwkv4).  Same math/dtype sequence
    as models.rwkv4.block_decode, so tokens match the oracle."""
    cfg = model.cfg
    dt = jnp.dtype(cfg.dtype)
    j = jax.jit

    embed = j(lambda emb, t: jnp.take(emb, t[:, 0], axis=0).astype(dt))
    ln = j(lambda p, x: L.apply_norm(p, x[:, None], "layernorm")[:, 0])
    mix = j(lambda h, prev, m: h * m + prev * (1.0 - m))
    mm = j(lambda a, w: a @ w)
    decay = j(lambda td: jnp.exp(td.astype(jnp.float32)))
    wkv = j(lambda a, b, o, k, v, w, u: wkv4_step(
        WKV4State(a.astype(jnp.float32), b.astype(jnp.float32),
                  o.astype(jnp.float32)),
        k.astype(jnp.float32), v.astype(jnp.float32), w,
        u.astype(jnp.float32)))
    gate = j(lambda r, out: jax.nn.sigmoid(r) * out.astype(r.dtype))
    add = j(lambda x, y: x + y.astype(x.dtype))
    sig = j(jax.nn.sigmoid)
    sqrelu = j(lambda k: jnp.square(jax.nn.relu(k)))
    mul = j(lambda a, b: a * b)
    head = j(lambda x, w: x @ w.astype(x.dtype))
    cast = j(lambda s, like: s.astype(like.dtype))

    def step(params, layer_params, state, tokens):
        """state: list of per-layer dicts (host-sliced once, outside)."""
        x = embed(params["embed"], tokens)
        x = ln(params["ln0"], x)
        new_state = []
        for lp, st in zip(layer_params, state):
            h = ln(lp["ln1"], x)
            p = lp["att"]
            r = mm(mix(h, st["att_x"], p["time_mix_r"]), p["wr"])
            k = mm(mix(h, st["att_x"], p["time_mix_k"]), p["wk"])
            v = mm(mix(h, st["att_x"], p["time_mix_v"]), p["wv"])
            w = decay(p["time_decay"])
            nwkv, out = wkv(st["wkv_a"], st["wkv_b"], st["wkv_o"],
                            k, v, w, p["time_first"])
            att = mm(gate(r, out), p["wo"])
            x2 = add(x, att)
            h2 = ln(lp["ln2"], x2)
            p = lp["ffn"]
            rr = sig(mm(mix(h2, st["ffn_x"], p["time_mix_r"]), p["wr"]))
            kk = sqrelu(mm(mix(h2, st["ffn_x"], p["time_mix_k"]), p["wk"]))
            ffn = mul(rr, mm(kk, p["wv"]))
            x = add(x2, ffn)
            new_state.append({
                "att_x": cast(h, st["att_x"]),
                "ffn_x": cast(h2, st["ffn_x"]),
                "wkv_a": cast(nwkv.a, st["wkv_a"]),
                "wkv_b": cast(nwkv.b, st["wkv_b"]),
                "wkv_o": cast(nwkv.o, st["wkv_o"])})
        x = ln(params["ln_f"], x)
        return head(x, params["head"])[:, None], new_state

    return step


# ---------------------------------------------------------------------------
# HBM bytes/token (weight stream measured from the ACTUAL arrays;
# activation/state round-trips analytic — see docs/kernels.md §bandwidth)
# ---------------------------------------------------------------------------


def hbm_bytes_per_token(cfg, batch: int, params, prep) -> dict:
    """Bytes/token per decode path.

    Weight stream: every path reads each weight once per step (XLA/Pallas
    keep them HBM-resident) EXCEPT the embedding table, which is a
    batch-row gather — `batch` rows at the stored dtype, not a full-table
    scan.  The per-path weight bytes come straight from
    `common.tree_hbm_bytes` over the tree that path actually consumes:
    the raw (fp or packed) tree for per-op / mono / fused-block, the
    prepared megakernel tree (per-dtype contiguous slabs + aux const
    maps) for fused-model — so bf16 (2 B), Δ-PoT W8 codes (1 B), W4
    nibble pairs (0.5 B) and VQ indices (1 B + codebook) are priced at
    their true stored sizes, and a new weight plane changes the number
    without anyone editing a formula here.

    Activation/state traffic stays analytic per path: per-op round-trips
    every intermediate (written by one launch, read by the next) — ~18
    (B, D)-sized activations + the F-wide FFN pair per layer, plus the
    state twice per launch touching it.  Monolithic fuses the elementwise
    chains but still materializes every matmul output (6 D-wide + 1
    F-wide per layer) between its kernels, plus the state both ways.
    Fused-block writes only the new state and the block output — but the
    residual still crosses HBM between the L launches.  Fused-model
    eliminates those L round-trips too: the residual enters and leaves
    HBM exactly once per step."""
    from benchmarks.common import tree_hbm_bytes
    D, F, Lc, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab

    def weight_stream(tree):
        emb = tree["embed"]
        isz = jnp.dtype(emb.dtype).itemsize
        return tree_hbm_bytes(tree) - int(emb.size) * isz + batch * D * isz

    w_tree, w_mega = weight_stream(params), weight_stream(prep)
    state = Lc * 5 * batch * D * 2          # bf16 state leaves
    act = batch * D * 2
    per_layer_int = 18 * act + 2 * batch * F * 2
    per_op = w_tree + Lc * (per_layer_int * 2 + state // Lc * 2)
    per_layer_mm = (6 * act + batch * F * 2) * 2    # matmul outs, w+r
    mono = w_tree + state * 2 + Lc * per_layer_mm + 2 * act + batch * V * 4
    fused_block = w_tree + state * 2 + Lc * act * 2 + batch * V * 4
    fused_model = w_mega + state * 2 + 2 * act + batch * V * 4
    return {"per_op": per_op / batch,
            "mono": mono / batch,
            "fused_block": fused_block / batch,
            "fused_model": fused_model / batch}


# ---------------------------------------------------------------------------
# One (depth, batch) sweep cell
# ---------------------------------------------------------------------------


def _carried(step):
    """Wrap (state -> (logits, state)) into a state-carrying closure the
    shared timing helper can call repeatedly."""
    def run():
        run.state = step(run.state)[1]
        return run.state
    return run


def _measure(variants, states, batch: int, iters: int,
             rounds: int = N_ROUNDS) -> dict:
    """tok/s per variant: `rounds` interleaved passes over all variants,
    best-of-rounds per variant (median within a pass, max across passes) —
    interleaving keeps shared-machine load drift from skewing the RATIOS
    between variants, which is what the gates consume."""
    tok_s = {name: 0.0 for name in variants}
    for _ in range(rounds):
        for name, step in variants.items():
            step.state = states[name]
            tok_s[name] = max(tok_s[name],
                              tokens_per_s(step, batch, iters=iters))
    return tok_s


def bench_depth(cfg, batch: int, iters: int, records: list,
                rounds: int = N_ROUNDS) -> dict:
    """Time every variant at one (depth, batch) cell; returns fp tok/s by
    variant name (for the gates)."""
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)
    st0 = model.init_decode_state(batch, 0, jnp.bfloat16)

    per_op_step = build_per_op_step(model)
    cast = model.cast_params(params)
    layer_params = [jax.tree_util.tree_map(lambda p: p[i], cast["blocks"])
                    for i in range(cfg.n_layers)]
    st_list = [jax.tree_util.tree_map(lambda s: s[i], st0)
               for i in range(cfg.n_layers)]
    mono = jax.jit(model.decode_step)
    fused_b = jax.jit(model.decode_step_fused)
    fused_m = jax.jit(model.decode_step_fused_model)
    # megakernel serving form: weights chunked once, outside the step
    prep = model.prepare_fused_model_params(params)

    # --- token equivalence before timing -----------------------------------
    l_po, _ = per_op_step(cast, layer_params, st_list, toks)
    l_mono, _ = mono(params, st0, toks, jnp.int32(0))
    l_fb, _ = fused_b(params, st0, toks, jnp.int32(0))
    l_fm, _ = fused_m(prep, st0, toks, jnp.int32(0))
    assert np.array_equal(np.argmax(np.asarray(l_po, np.float32), -1),
                          np.argmax(np.asarray(l_mono, np.float32), -1))
    assert np.array_equal(np.asarray(l_mono, np.float32),
                          np.asarray(l_fb, np.float32))
    assert np.array_equal(np.asarray(l_mono, np.float32),
                          np.asarray(l_fm, np.float32))

    # --- fp variants (state carried across steps, like the engine) ---------
    hbm = hbm_bytes_per_token(cfg, batch, params, prep)
    variants = {
        "per_op": _carried(lambda s: per_op_step(cast, layer_params, s,
                                                 toks)),
        "mono": _carried(lambda s: mono(params, s, toks, jnp.int32(0))),
        "fused_block": _carried(lambda s: fused_b(params, s, toks,
                                                  jnp.int32(0))),
        "fused_model": _carried(lambda s: fused_m(prep, s, toks,
                                                  jnp.int32(0))),
    }
    states = {name: (st_list if name == "per_op" else st0)
              for name in variants}
    tok_s = _measure(variants, states, batch, iters, rounds)
    for name in variants:
        records.append({
            "variant": name, "quant": "fp", "batch": batch,
            "n_layers": cfg.n_layers, "tok_s": round(tok_s[name], 3),
            "us_per_step": round(batch * 1e6 / tok_s[name], 1),
            "hbm_bytes_per_token": hbm[name],
        })
    emit(f"fused_decode/{cfg.name}/L{cfg.n_layers}/batch{batch}/fp",
         batch * 1e6 / tok_s["fused_model"],
         f"per_op_tok_s={tok_s['per_op']:.1f};"
         f"mono_tok_s={tok_s['mono']:.1f};"
         f"fused_block_tok_s={tok_s['fused_block']:.1f};"
         f"fused_model_tok_s={tok_s['fused_model']:.1f};"
         f"model_vs_block={tok_s['fused_model']/tok_s['fused_block']:.2f}x;"
         f"block_vs_per_op={tok_s['fused_block']/tok_s['per_op']:.2f}x;"
         f"hbm_bytes_tok_model={hbm['fused_model']:.3g}")
    return tok_s


def bench_quantized(cfg, batch: int, iters: int, records: list,
                    rounds: int = N_ROUNDS):
    """Δ-PoT W8 variants: per-op path unpacks the tree inside the jit; the
    fused paths stream uint8 codes into the kernel."""
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    packed = pack_params(params)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)
    st0 = model.init_decode_state(batch, 0, jnp.bfloat16)

    mono_q = jax.jit(lambda p, s, t: model.decode_step(
        unpack_params(p), s, t, jnp.int32(0)))
    fused_bq = jax.jit(lambda p, s, t: model.decode_step_fused(
        p, s, t, jnp.int32(0)))
    fused_mq = jax.jit(lambda p, s, t: model.decode_step_fused_model(
        p, s, t, jnp.int32(0)))
    prep_q = model.prepare_fused_model_params(packed)
    l_mq, _ = mono_q(packed, st0, toks)
    l_bq, _ = fused_bq(packed, st0, toks)
    l_mq2, _ = fused_mq(prep_q, st0, toks)
    assert np.array_equal(np.asarray(l_mq, np.float32),
                          np.asarray(l_bq, np.float32))
    assert np.array_equal(np.asarray(l_mq, np.float32),
                          np.asarray(l_mq2, np.float32))

    hbm = hbm_bytes_per_token(cfg, batch, packed, prep_q)
    variants = {
        "mono": _carried(lambda s: mono_q(packed, s, toks)),
        "fused_block": _carried(lambda s: fused_bq(packed, s, toks)),
        "fused_model": _carried(lambda s: fused_mq(prep_q, s, toks)),
    }
    tok_s = _measure(variants, {name: st0 for name in variants},
                     batch, iters, rounds)
    for name in variants:
        records.append({
            "variant": name, "quant": "dpot_w8", "batch": batch,
            "n_layers": cfg.n_layers, "tok_s": round(tok_s[name], 3),
            "us_per_step": round(batch * 1e6 / tok_s[name], 1),
            "hbm_bytes_per_token": hbm[name],
        })
    emit(f"fused_decode/{cfg.name}/L{cfg.n_layers}/batch{batch}/dpot_w8",
         batch * 1e6 / tok_s["fused_model"],
         f"mono_tok_s={tok_s['mono']:.1f};"
         f"fused_block_tok_s={tok_s['fused_block']:.1f};"
         f"fused_model_tok_s={tok_s['fused_model']:.1f};"
         f"model_vs_block={tok_s['fused_model']/tok_s['fused_block']:.2f}x;"
         f"hbm_bytes_tok_model={hbm['fused_model']:.3g}")


# ---------------------------------------------------------------------------


def run(smoke: bool = False, json_out: bool = False) -> bool:
    base = get_model(ARCH, smoke=True).cfg
    depths = DEPTHS[:1] if smoke else DEPTHS
    iters = 3 if smoke else N_ITERS
    rounds = 2 if smoke else N_ROUNDS
    records: list[dict] = []
    gate_cell = {}                 # batch-8 fp tok/s at the deepest depth
    for depth in depths:
        cfg = dataclasses.replace(base, n_layers=depth,
                                  name=f"{base.name}-L{depth}")
        for batch in BATCHES:
            tok_s = bench_depth(cfg, batch, iters, records, rounds)
            if batch == 8 and depth == depths[-1]:
                gate_cell = tok_s
        if depth == depths[0]:     # quantized sweep at the base depth
            for batch in BATCHES:
                bench_quantized(cfg, batch, iters, records, rounds)

    gates = {
        "fused_block_vs_per_op_batch8": {
            "speedup": round(gate_cell["fused_block"]
                             / gate_cell["per_op"], 3),
            "target": 1.5},
        "fused_model_vs_fused_block_batch8": {
            "speedup": round(gate_cell["fused_model"]
                             / gate_cell["fused_block"], 3),
            "target": 1.0},
    }
    ok = True
    for name, g in gates.items():
        g["pass"] = g["speedup"] >= g["target"]
        ok = ok and g["pass"]
        print(f"gate: {name} = {g['speedup']:.2f}x "
              f"(target >= {g['target']}x) -> "
              f"{'PASS' if g['pass'] else 'FAIL'}")

    if json_out:
        write_bench_json(JSON_PATH, {
            "bench": "fused_decode",
            "arch": base.name,
            "backend": jax.default_backend(),
            "smoke": smoke,
            "batches": list(BATCHES),
            "depths": list(depths),
            "iters": iters,
            "records": records,
            "gates": gates,
        })
    # CI smoke exists to pin the script + JSON schema, not shared-runner
    # timing — gates are recorded above but only enforced on full runs.
    return ok or smoke


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal sweep for CI: one depth, few iterations; "
                         "gates reported but not enforced")
    ap.add_argument("--json", action="store_true",
                    help=f"write {JSON_PATH} (machine-readable records)")
    args = ap.parse_args()
    return 0 if run(smoke=args.smoke, json_out=args.json) else 1


if __name__ == "__main__":
    raise SystemExit(main())
