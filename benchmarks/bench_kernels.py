"""Kernel microbenchmarks (§4 modules).

On this CPU container the Pallas kernels run in interpret mode, so
wall-times are NOT TPU times; what is meaningful here and is reported:
  * correctness deltas vs the oracle (must be ~0),
  * bytes-moved ratios (the Δ-PoT kernel moves 8-bit codes vs 16-bit
    weights: the exact HBM-traffic ratio the TPU would see),
  * oracle (XLA-compiled) wall time as a portable reference point.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant.delta_pot import (
    FORMAT_W8, dpot_quantize, dpot_pack_int8)
from repro.kernels import (dpot_matmul, fused_layernorm, wkv4_pallas,
                           wkv6_pallas)
from repro.kernels import ref as R
from benchmarks.common import emit, time_call


def run():
    rng = np.random.default_rng(0)

    # --- dpot_matmul: the serving matvec (batch 8 x 1024 -> 1024)
    M, K, N = 8, 1024, 1024
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.05, jnp.float32)
    q = dpot_quantize(w, FORMAT_W8, axis=1)
    packed, scale = dpot_pack_int8(q), q.scale[0]
    t_ref = time_call(R.dpot_matmul_ref, x, packed, scale)
    got = dpot_matmul(x, packed, scale)
    err = float(jnp.max(jnp.abs(got - R.dpot_matmul_ref(x, packed, scale))))
    bytes_fp16 = K * N * 2
    bytes_dpot = K * N * 1 + N * 4
    emit("kernels/dpot_matmul", t_ref,
         f"err={err:.1e};hbm_ratio={bytes_fp16/bytes_dpot:.2f}x")

    # --- fused layernorm
    xln = jnp.asarray(rng.normal(size=(256, 1024)), jnp.float32)
    g = jnp.ones((1024,), jnp.float32)
    b = jnp.zeros((1024,), jnp.float32)
    t_ref = time_call(R.fused_layernorm_ref, xln, g, b)
    err = float(jnp.max(jnp.abs(
        fused_layernorm(xln, g, b) - R.fused_layernorm_ref(xln, g, b))))
    # single-pass reads x once + writes once vs 2-pass (3 reads 1 write)
    emit("kernels/fused_layernorm", t_ref, f"err={err:.1e};passes=1_vs_2")

    # --- wkv4 scan
    B, T, C = 1, 256, 768
    k = jnp.asarray(rng.normal(size=(B, T, C)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, C)), jnp.float32)
    wd = jnp.asarray(np.abs(rng.normal(size=(C,))) + 0.05, jnp.float32)
    u = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
    t_ref = time_call(lambda *a: R.wkv4_ref(*a)[0], k, v, wd, u)
    y, _ = wkv4_pallas(k, v, wd, u)
    err = float(jnp.max(jnp.abs(y - R.wkv4_ref(k, v, wd, u)[0])))
    state_hbm_roundtrips_gpu = T * 3 * C * 4 * 2   # read+write per step
    emit("kernels/wkv4", t_ref,
         f"err={err:.1e};onchip_state_bytes_saved={state_hbm_roundtrips_gpu}")

    # --- wkv6 chunked
    B, T, H, Nd = 1, 256, 8, 64
    r6 = jnp.asarray(rng.normal(size=(B, T, H, Nd)), jnp.float32)
    k6 = jnp.asarray(rng.normal(size=(B, T, H, Nd)), jnp.float32)
    v6 = jnp.asarray(rng.normal(size=(B, T, H, Nd)), jnp.float32)
    w6 = jnp.asarray(rng.uniform(0.5, 0.999, (B, T, H, Nd)), jnp.float32)
    u6 = jnp.asarray(rng.normal(size=(H, Nd)), jnp.float32)
    t_ref = time_call(lambda *a: R.wkv6_ref(*a)[0], r6, k6, v6, w6, u6)
    y6, _ = wkv6_pallas(r6, k6, v6, w6, u6, chunk=64)
    err = float(jnp.max(jnp.abs(y6 - R.wkv6_ref(r6, k6, v6, w6, u6)[0])))
    emit("kernels/wkv6", t_ref, f"err={err:.1e};chunk=64")


if __name__ == "__main__":
    run()
