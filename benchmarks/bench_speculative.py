"""Self-speculative decode throughput: K-token verify windows vs plain ticks.

The serving win under test (src/repro/serving/plan.py SpeculativePath;
losslessness pinned in tests/test_speculative.py): a truncated-stack
drafter proposes K-1 tokens per lane and ONE chunk-shaped verify call
scores the whole window, so an accepted window advances a lane K tokens
for one drafter pass plus one verify pass — decode throughput scales
with the ACCEPTANCE RATE while the emitted bits stay exactly the plain
engine's (asserted before any timing).

Sweep: K in {2, 4, 8} x batch in {1, 4}, two drafter configurations:

  * ALIGNED — layers >= draft_depth have att.wo / ffn.wv zeroed, so the
    deep blocks' residual contributions vanish and the depth-1 drafter's
    argmax IS the full model's: acceptance ~= 1.0 with the full stack
    still paying its real compute.  This is the benchmark's calibrated
    upper bound — the speedup K can buy when the drafter is right.
  * NATURAL — the untouched random-init weights: whatever acceptance the
    depth-1 drafter really earns (low, for random weights), showing how
    the win decays with acceptance.

Reported per cell: decode tokens/s (steady-state decode ticks only —
prefill excluded by construction), acceptance rate, and speedup vs the
plain engine at the same batch.  Gate (enforced via exit status on full
runs, recorded always):

  * best aligned-drafter speculative config >= 1.5x plain decode
    tokens/s at batch 1.

`--json` merges a "speculative" section (records + gates) into
`BENCH_decode.json`, preserving the fused-decode sweep already there;
`--smoke` shrinks the sweep for CI, where the schema is validated but
timing gates are not enforced.

Run: PYTHONPATH=src python -m benchmarks.bench_speculative [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, provenance, write_bench_json
from repro.models.registry import get_model
from repro.serving import ServingEngine
from repro.serving.scheduler import DECODE

ARCH = "rwkv4-169m"
KS = (2, 4, 8)
BATCHES = (1, 4)
DRAFT_DEPTH = 1
JSON_PATH = "BENCH_decode.json"
GATE_SPEEDUP = 1.5
PROMPT_LEN = 8


def aligned_params(model, params, depth: int):
    """Zero att.wo / ffn.wv for layers >= depth: those blocks' residual
    contributions become exactly zero, so the first-`depth`-layers
    drafter predicts the full model's argmax (tests/test_speculative.py
    pins acceptance_rate == 1.0 on this configuration)."""
    def zero_tail(leaf):
        z = np.asarray(leaf, np.float32).copy()
        z[depth:] = 0.0
        return jnp.asarray(z, leaf.dtype)

    blocks = dict(params["blocks"])
    blocks["att"] = {**blocks["att"], "wo": zero_tail(blocks["att"]["wo"])}
    blocks["ffn"] = {**blocks["ffn"], "wv": zero_tail(blocks["ffn"]["wv"])}
    return {**params, "blocks": blocks}


def _prompts(vocab: int, batch: int, seed: int = 7):
    r = np.random.default_rng(seed)
    return [r.integers(0, vocab, size=PROMPT_LEN).tolist()
            for _ in range(batch)]


def _engine(model, params, *, batch: int, speculative=None):
    return ServingEngine(model, params=params, max_batch=batch,
                         prefill_chunk=PROMPT_LEN, fused_prefill=True,
                         speculative=speculative, draft_depth=None if
                         speculative is None else DRAFT_DEPTH)


def _decode_rate(model, params, *, batch: int, speculative, ticks: int,
                 warm_ticks: int) -> tuple[float, float]:
    """Steady-state decode tokens/s of one engine configuration, plus the
    run's acceptance rate.  The measured window opens only after every
    lane reached DECODE phase and `warm_ticks` ticks compiled + warmed
    every program, and `max_new_tokens` is sized so no lane can retire
    inside the window — the rate is pure decode-tick throughput, the
    same quantity for speculative and plain engines."""
    k = speculative or 1
    eng = _engine(model, params, batch=batch, speculative=speculative)
    max_new = (warm_ticks + ticks + 4) * k + 2
    for p in _prompts(model.cfg.vocab, batch):
        eng.submit(p, max_new_tokens=max_new)
    while len(eng.scheduler.slots) < batch or any(
            m.phase != DECODE for m in eng.scheduler.slots.values()):
        eng.step()
    for _ in range(warm_ticks):
        eng.step()
    c0 = eng.counters.decode_tokens
    t0 = time.perf_counter()
    for _ in range(ticks):
        eng.step()
    dt = time.perf_counter() - t0
    tok_s = (eng.counters.decode_tokens - c0) / max(dt, 1e-9)
    while eng.step():
        pass
    return tok_s, eng.counters.snapshot()["acceptance_rate"]


def _assert_lossless(model, params, speculative: int):
    """The precondition that makes the numbers mean anything: the
    speculative engine emits the plain engine's exact tokens."""
    def run(spec):
        eng = _engine(model, params, batch=2, speculative=spec)
        hs = [eng.submit(p, max_new_tokens=12)
              for p in _prompts(model.cfg.vocab, 2)]
        eng.run()
        return [h.tokens for h in hs]
    assert run(speculative) == run(None), \
        "speculative decode changed the output tokens"


def run(smoke: bool = False, json_out: bool = False) -> bool:
    base = get_model(ARCH, smoke=True).cfg
    n_layers = 2 if smoke else 6
    cfg = dataclasses.replace(base, n_layers=n_layers,
                              name=f"{base.name}-L{n_layers}")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    weights = {"aligned": aligned_params(model, params, DRAFT_DEPTH),
               "natural": params}
    ks = KS[:2] if smoke else KS
    ticks = 4 if smoke else 24
    warm_ticks = 2 if smoke else 6
    for name, w in weights.items():
        _assert_lossless(model, w, max(ks))

    records: list[dict] = []
    plain = {}
    for batch in BATCHES:
        tok_s, _ = _decode_rate(model, weights["aligned"], batch=batch,
                                speculative=None, ticks=ticks,
                                warm_ticks=warm_ticks)
        plain[batch] = tok_s
        records.append({"variant": "plain", "drafter": None, "k": 1,
                        "batch": batch, "n_layers": n_layers,
                        "draft_depth": None, "acceptance_rate": None,
                        "tok_s": round(tok_s, 3), "speedup_vs_plain": 1.0})
    best_batch1 = 0.0
    for drafter, w in weights.items():
        for batch in BATCHES:
            for k in ks:
                tok_s, acc = _decode_rate(model, w, batch=batch,
                                          speculative=k, ticks=ticks,
                                          warm_ticks=warm_ticks)
                speedup = tok_s / max(plain[batch], 1e-9)
                if drafter == "aligned" and batch == 1:
                    best_batch1 = max(best_batch1, speedup)
                records.append({
                    "variant": "speculative", "drafter": drafter, "k": k,
                    "batch": batch, "n_layers": n_layers,
                    "draft_depth": DRAFT_DEPTH,
                    "acceptance_rate": round(acc, 3),
                    "tok_s": round(tok_s, 3),
                    "speedup_vs_plain": round(speedup, 3)})
                emit(f"speculative/{cfg.name}/{drafter}/K{k}/batch{batch}",
                     batch * 1e6 / max(tok_s, 1e-9),
                     f"tok_s={tok_s:.1f};acceptance={acc:.3f};"
                     f"plain_tok_s={plain[batch]:.1f};"
                     f"speedup={speedup:.2f}x")

    gates = {
        "speculative_vs_plain_batch1": {
            "speedup": round(best_batch1, 3), "target": GATE_SPEEDUP,
            "pass": best_batch1 >= GATE_SPEEDUP},
    }
    ok = True
    for name, g in gates.items():
        ok = ok and g["pass"]
        print(f"gate: {name} = {g['speedup']:.2f}x "
              f"(target >= {g['target']}x) -> "
              f"{'PASS' if g['pass'] else 'FAIL'}")

    if json_out:
        # merge into BENCH_decode.json: the speculative rows extend the
        # decode-throughput record, they do not replace the fused-decode
        # sweep already there
        payload = {}
        if os.path.exists(JSON_PATH):
            with open(JSON_PATH) as f:
                payload = json.load(f)
        payload["speculative"] = {
            "arch": cfg.name,
            "backend": jax.default_backend(),
            "smoke": smoke,
            "ks": list(ks),
            "batches": list(BATCHES),
            "draft_depth": DRAFT_DEPTH,
            "ticks": ticks,
            "provenance": provenance(),
            "records": records,
            "gates": gates,
        }
        write_bench_json(JSON_PATH, payload)
    # CI smoke pins the script + JSON schema, not shared-runner timing
    return ok or smoke


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal sweep for CI: K in {2,4}, few ticks; "
                         "gates reported but not enforced")
    ap.add_argument("--json", action="store_true",
                    help=f"merge speculative records into {JSON_PATH}")
    args = ap.parse_args()
    return 0 if run(smoke=args.smoke, json_out=args.json) else 1


if __name__ == "__main__":
    raise SystemExit(main())
