"""Continuous-batching serving throughput: engine vs the seed loop.

Measures end-to-end generated tokens/s for N concurrent requests at
N = 1 / 8 / 32 two ways:

  * SEED LOOP — the pre-engine serving mode: each request decoded alone
    (batch-1 `greedy_decode`), one after another; N requests cost N full
    passes of per-token dispatch.
  * ENGINE   — `repro.serving.ServingEngine` with an N-slot pool: all N
    requests share ONE fused decode step per tick, so the per-token
    dispatch cost is paid once per *tick*, not once per *request*.

The ratio at batch 8 is the PR's acceptance gate (>= 4x on CPU).  Smoke
configs keep this container-sized; the mechanism (amortizing dispatch and
reading weights once per step for the whole batch) is exactly what scales
on real accelerators.

`--devices N` drives the engine on a data-parallel ("data",) serving
mesh (the slot pool and per-tick batch shard, weights replicate) —
`--smoke` shrinks the sweep to one batch size for CI, which runs this
under XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import greedy_decode
from repro.models.registry import get_model
from repro.serving import ServingEngine
from benchmarks.common import emit

ARCH = "rwkv4-169m"
PROMPT_LEN = 8
N_TOKENS = 16


def _prompts(n: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=PROMPT_LEN).tolist()
            for _ in range(n)]


def seed_loop_tokens_per_s(model, params, prompts) -> float:
    """Seed serving: one request at a time, batch-1 host loop (prompt fed
    token-by-token through the same jitted step, then greedy decode)."""
    step = jax.jit(model.decode_step)

    def one(prompt):
        state = model.init_decode_state(1, N_TOKENS + 8)
        lg = None
        for t in prompt:
            lg, state = step(params, state,
                             jnp.array([[t]], jnp.int32), jnp.int32(0))
        first = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        toks, _ = greedy_decode(model, params, state, first, N_TOKENS - 1)
        return toks

    jax.block_until_ready(one(prompts[0]))       # compile
    t0 = time.perf_counter()
    for p in prompts:
        jax.block_until_ready(one(p))
    dt = time.perf_counter() - t0
    return len(prompts) * N_TOKENS / dt


def engine_tokens_per_s(model, params, prompts,
                        mesh=None) -> tuple[float, dict]:
    engine = ServingEngine(model, params=params, max_batch=len(prompts),
                           prefill_chunk=PROMPT_LEN, mesh=mesh)
    # compile both device programs outside the timed region
    warm = engine.submit(prompts[0], max_new_tokens=2)
    engine.run()
    assert warm.done
    # fresh counters: the warmup's TTFT/prefill samples are compile time,
    # which would dominate the emitted latency means
    from repro.runtime.monitor import ServingCounters
    engine.counters = engine.scheduler.counters = ServingCounters()
    t0 = time.perf_counter()
    for p in prompts:
        engine.submit(p, max_new_tokens=N_TOKENS)
    snap = engine.run()
    dt = time.perf_counter() - t0
    return snap["decode_tokens"] / dt, snap


def run(*, smoke: bool = False, devices: int | None = None):
    model = get_model(ARCH, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    mesh = None
    tag = ""
    if devices is not None:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(devices)
        tag = f"/dp{mesh.devices.size}"
    for n in ((8,) if smoke else (1, 8, 32)):
        prompts = _prompts(n, model.cfg.vocab)
        seed_tps = seed_loop_tokens_per_s(model, params, prompts)
        eng_tps, snap = engine_tokens_per_s(model, params, prompts, mesh)
        emit(f"serving/{ARCH}{tag}/batch{n}", 1e6 / max(eng_tps, 1e-9),
             f"seed_tok_s={seed_tps:.1f};engine_tok_s={eng_tps:.1f};"
             f"speedup={eng_tps/seed_tps:.2f}x;"
             f"mean_ttft_ms={snap['mean_ttft_s']*1e3:.1f};"
             f"mean_prefill_ms={snap['mean_prefill_s']*1e3:.1f};"
             f"mean_prefill_ticks={snap['mean_prefill_ticks']:.1f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one batch size (CI-sized)")
    ap.add_argument("--devices", type=int, default=None,
                    help="drive the engine on a data-parallel serving "
                         "mesh over N local devices (0 = all visible)")
    args = ap.parse_args()
    run(smoke=args.smoke, devices=args.devices)
