"""Continuous-batching serving throughput: engine vs the seed loop.

Measures end-to-end generated tokens/s for N concurrent requests at
N = 1 / 8 / 32 two ways:

  * SEED LOOP — the pre-engine serving mode: each request decoded alone
    (batch-1 `greedy_decode`), one after another; N requests cost N full
    passes of per-token dispatch.
  * ENGINE   — `repro.serving.ServingEngine` with an N-slot pool: all N
    requests share ONE fused decode step per tick, so the per-token
    dispatch cost is paid once per *tick*, not once per *request*.

The ratio at batch 8 is the PR's acceptance gate (>= 4x on CPU).  Smoke
configs keep this container-sized; the mechanism (amortizing dispatch and
reading weights once per step for the whole batch) is exactly what scales
on real accelerators.

`--devices N` drives the engine on a data-parallel ("data",) serving
mesh (the slot pool and per-tick batch shard, weights replicate) —
`--smoke` shrinks the sweep to one batch size for CI, which runs this
under XLA_FLAGS=--xla_force_host_platform_device_count=8.

Two crash-safety checks ride along (repro.serving.snapshot):

  * `--json` measures the tick-boundary snapshot overhead at batch 8
    (engine tokens/s with snapshot-every=8 vs without, gate >= 0.95x)
    and merges a "snapshot" section into BENCH_serving.json — the SLO
    bench owns that file, so this is a read-modify-write.
  * `--crash-smoke` SIGKILLs a child engine mid-run (`--crash-child` is
    the child entry point), restores from the last committed snapshot
    and asserts every request's concatenated pre-crash + post-restore
    stream is bit-identical to a never-crashed oracle.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import greedy_decode
from repro.models.registry import get_model
from repro.serving import ServingEngine, SnapshotConfig
from benchmarks.common import emit, provenance

ARCH = "rwkv4-169m"
PROMPT_LEN = 8
N_TOKENS = 16
JSON_PATH = "BENCH_serving.json"

# --crash-smoke geometry: snapshot every 4 ticks, SIGKILL at tick 10, so
# the child dies with a committed step_00000008 behind it and every lane
# mid-stream (24 new tokens per request, mixed greedy/sampled)
CRASH_TICK = 10
CRASH_EVERY = 4
CRASH_BATCH = 4
CRASH_TOKENS = 24


def _prompts(n: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=PROMPT_LEN).tolist()
            for _ in range(n)]


def seed_loop_tokens_per_s(model, params, prompts) -> float:
    """Seed serving: one request at a time, batch-1 host loop (prompt fed
    token-by-token through the same jitted step, then greedy decode)."""
    step = jax.jit(model.decode_step)

    def one(prompt):
        state = model.init_decode_state(1, N_TOKENS + 8)
        lg = None
        for t in prompt:
            lg, state = step(params, state,
                             jnp.array([[t]], jnp.int32), jnp.int32(0))
        first = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        toks, _ = greedy_decode(model, params, state, first, N_TOKENS - 1)
        return toks

    jax.block_until_ready(one(prompts[0]))       # compile
    t0 = time.perf_counter()
    for p in prompts:
        jax.block_until_ready(one(p))
    dt = time.perf_counter() - t0
    return len(prompts) * N_TOKENS / dt


def engine_tokens_per_s(model, params, prompts, mesh=None,
                        snapshot=None) -> tuple[float, dict]:
    engine = ServingEngine(model, params=params, max_batch=len(prompts),
                           prefill_chunk=PROMPT_LEN, mesh=mesh,
                           snapshot=snapshot)
    # compile both device programs outside the timed region
    warm = engine.submit(prompts[0], max_new_tokens=2)
    engine.run()
    assert warm.done
    # fresh counters: the warmup's TTFT/prefill samples are compile time,
    # which would dominate the emitted latency means
    from repro.runtime.monitor import ServingCounters
    engine.counters = engine.scheduler.counters = ServingCounters()
    t0 = time.perf_counter()
    for p in prompts:
        engine.submit(p, max_new_tokens=N_TOKENS)
    snap = engine.run()
    dt = time.perf_counter() - t0
    if engine.snapshot_manager is not None:
        # drain the async writer outside the timed region: the gate is
        # about steady-state capture overhead, not flush latency
        engine.snapshot_manager.wait()
        snap = engine.counters.snapshot()
    return snap["decode_tokens"] / dt, snap


def snapshot_overhead(model, params, mesh=None, tag: str = "",
                      json_out: bool = False, smoke: bool = False) -> bool:
    """Tick-boundary snapshot cost at batch 8: engine tokens/s with
    snapshot-every=8 vs without — interleaved best-of-5 pairs, because
    run-to-run noise on shared CPU runners (±15%) swamps the ~1ms/interval
    snapshot cost at a 1.6ms smoke tick.  The synchronous capture cost is
    the recorded snapshot_wall_s; the rest of any measured gap is the
    background writer competing for host cores, which a real accelerator
    deployment doesn't see.  Merges a "snapshot" section into
    BENCH_serving.json — bench_serving_slo owns the file's top-level
    records/gates, which this must not clobber."""
    prompts = _prompts(8, model.cfg.vocab)
    base_tps, snap_tps, counters = 0.0, 0.0, {}
    for _ in range(5):
        base_tps = max(base_tps,
                       engine_tokens_per_s(model, params, prompts, mesh)[0])
        with tempfile.TemporaryDirectory() as d:
            tps, c = engine_tokens_per_s(
                model, params, prompts, mesh,
                snapshot=SnapshotConfig(directory=d, every=8))
        if tps > snap_tps:
            snap_tps, counters = tps, c
    ratio = snap_tps / max(base_tps, 1e-9)
    gate = {"value": ratio, "threshold": 0.95, "pass": ratio >= 0.95}
    emit(f"serving/{ARCH}{tag}/snapshot_overhead", 1e6 / max(snap_tps, 1e-9),
         f"base_tok_s={base_tps:.1f};snap_tok_s={snap_tps:.1f};"
         f"ratio={ratio:.3f};"
         f"snapshots_written={counters['snapshots_written']};"
         f"snapshot_wall_ms={counters['snapshot_wall_s']*1e3:.2f};"
         f"gate={'PASS' if gate['pass'] else 'FAIL'}")
    if json_out:
        payload = {}
        if os.path.exists(JSON_PATH):
            with open(JSON_PATH) as f:
                payload = json.load(f)
        payload["snapshot"] = {
            "arch": ARCH,
            "batch": 8,
            "n_tokens": N_TOKENS,
            "every": 8,
            "provenance": provenance(),
            "records": [{
                "base_tok_s": base_tps, "snap_tok_s": snap_tps,
                "overhead_ratio": ratio,
                "snapshots_written": counters["snapshots_written"],
                "snapshot_wall_s": counters["snapshot_wall_s"],
            }],
            "gates": {"snapshot_overhead_vs_plain": gate},
        }
        with open(JSON_PATH, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"merged snapshot section into {JSON_PATH}", flush=True)
    # CI smoke pins the script + JSON schema, not shared-runner timing
    return gate["pass"] or smoke


def _crash_submit(engine, prompts):
    """Same submission schedule in the child, the restored engine's past
    and the oracle: even lanes greedy, odd lanes seeded-sampled, so the
    parity check covers both token-selection paths."""
    return [engine.submit(p, max_new_tokens=CRASH_TOKENS,
                          temperature=(0.8 if i % 2 else 0.0), seed=7 + i)
            for i, p in enumerate(prompts)]


def crash_child(directory: str):
    """`--crash-child` entry: serve with snapshots every 4 ticks and a
    fault injector that SIGKILLs the process at tick 10.  Never returns."""
    from repro.runtime.monitor import ServingFaultInjector
    model = get_model(ARCH, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    inj = ServingFaultInjector(
        schedule={CRASH_TICK: [("crash_at_tick", "sigkill")]})
    engine = ServingEngine(
        model, params=params, max_batch=CRASH_BATCH,
        prefill_chunk=PROMPT_LEN, fault_injector=inj,
        snapshot=SnapshotConfig(directory=directory, every=CRASH_EVERY))
    _crash_submit(engine, _prompts(CRASH_BATCH, model.cfg.vocab))
    engine.run()
    raise SystemExit("crash child survived its own SIGKILL fault")


def crash_smoke() -> bool:
    """`--crash-smoke`: SIGKILL a child engine mid-run, restore from its
    last committed snapshot, drain, and assert every request's
    `resumed + tokens` stream is bit-identical to a never-crashed
    in-process oracle."""
    model = get_model(ARCH, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = _prompts(CRASH_BATCH, model.cfg.vocab)
    oracle_engine = ServingEngine(model, params=params,
                                  max_batch=CRASH_BATCH,
                                  prefill_chunk=PROMPT_LEN)
    oracle_handles = _crash_submit(oracle_engine, prompts)
    oracle_engine.run()
    oracle = {h.rid: list(h.tokens) for h in oracle_handles}

    with tempfile.TemporaryDirectory() as d:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_serving",
             "--crash-child", d])
        if proc.returncode != -signal.SIGKILL:
            print(f"crash child exited rc={proc.returncode}, "
                  f"expected {-signal.SIGKILL}", flush=True)
            return False
        t0 = time.perf_counter()
        engine = ServingEngine.restore(d, params=params)
        handles = engine.handles          # run() pops them as lanes finish
        snap = engine.run()
        if engine.snapshot_manager is not None:
            engine.snapshot_manager.wait()
        dt = time.perf_counter() - t0
    streams = {rid: h.resumed + h.tokens for rid, h in handles.items()}
    parity = streams == oracle
    emit(f"serving/{ARCH}/crash_recovery", dt * 1e6,
         f"rc={-signal.SIGKILL};restores={snap['restores']};"
         f"resumed_lanes={snap['resumed_lanes']};"
         f"quarantined_lanes={snap['quarantined_lanes']};"
         f"checksum_failures={snap['checksum_failures']};"
         f"path_fallbacks={snap['path_fallbacks']};"
         f"parity={'PASS' if parity else 'FAIL'}")
    if not parity:
        for rid in oracle:
            if streams.get(rid) != oracle[rid]:
                print(f"rid {rid}: resumed+restored {streams.get(rid)} "
                      f"!= oracle {oracle[rid]}", flush=True)
    return parity


def run(*, smoke: bool = False, devices: int | None = None,
        json_out: bool = False) -> bool:
    model = get_model(ARCH, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    mesh = None
    tag = ""
    if devices is not None:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(devices)
        tag = f"/dp{mesh.devices.size}"
    for n in ((8,) if smoke else (1, 8, 32)):
        prompts = _prompts(n, model.cfg.vocab)
        seed_tps = seed_loop_tokens_per_s(model, params, prompts)
        eng_tps, snap = engine_tokens_per_s(model, params, prompts, mesh)
        emit(f"serving/{ARCH}{tag}/batch{n}", 1e6 / max(eng_tps, 1e-9),
             f"seed_tok_s={seed_tps:.1f};engine_tok_s={eng_tps:.1f};"
             f"speedup={eng_tps/seed_tps:.2f}x;"
             f"mean_ttft_ms={snap['mean_ttft_s']*1e3:.1f};"
             f"mean_prefill_ms={snap['mean_prefill_s']*1e3:.1f};"
             f"mean_prefill_ticks={snap['mean_prefill_ticks']:.1f}")
    ok = True
    if json_out:
        ok = snapshot_overhead(model, params, mesh, tag,
                               json_out=True, smoke=smoke)
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one batch size (CI-sized)")
    ap.add_argument("--devices", type=int, default=None,
                    help="drive the engine on a data-parallel serving "
                         "mesh over N local devices (0 = all visible)")
    ap.add_argument("--json", action="store_true",
                    help="measure snapshot overhead and merge a "
                         f"'snapshot' section into {JSON_PATH}")
    ap.add_argument("--crash-smoke", action="store_true",
                    help="SIGKILL a child engine mid-run, restore, and "
                         "check stream parity vs a never-crashed oracle")
    ap.add_argument("--crash-child", metavar="DIR", default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.crash_child:
        crash_child(args.crash_child)
    if args.crash_smoke:
        raise SystemExit(0 if crash_smoke() else 1)
    raise SystemExit(0 if run(smoke=args.smoke, devices=args.devices,
                              json_out=args.json) else 1)
