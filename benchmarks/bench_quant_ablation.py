"""Table-1 reproduction (structure): quantization-scheme ablation.

No LAMBADA offline; instead (DESIGN.md §2-C5) we train a small RWKV-4 on the
synthetic motif stream until it has real structure to lose, then evaluate
perplexity + logit-KL-vs-FP under the same five schemes the paper compares:
FP (baseline), RTN, PoT, LogQ, Proposed (Δ-PoT W9 + per-channel MSE scales).

Expected ordering (the paper's): PoT worst, RTN/LogQ middle, Proposed
closest to FP.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant.policy import fake_quantize_tree_with
from repro.core.quant.schemes import SCHEMES
from repro.configs.base import ModelConfig
from repro.data import SyntheticLM
from repro.models.registry import Model, get_model, loss_fn
from benchmarks.common import emit

_ABL_CFG = ModelConfig(
    name="rwkv4-ablation", family="rwkv",
    n_layers=4, d_model=128, n_heads=1, n_kv_heads=1,
    d_ff=512, vocab=512, norm="layernorm", rwkv_version=4, remat=False,
    dtype="float32",
)


def _train(model: Model, steps: int = 240, batch: int = 16, seq: int = 64):
    ds = SyntheticLM(vocab=model.cfg.vocab, seq_len=seq, global_batch=batch,
                     seed=7)
    params = model.init_params(jax.random.PRNGKey(0))

    @jax.jit
    def step(p, batch):
        (l, _), g = jax.value_and_grad(
            lambda q: loss_fn(model, q, batch), has_aux=True)(p)
        return jax.tree_util.tree_map(lambda a, b: a - 3e-3 * b, p, g), l

    for s in range(steps):
        hb = ds.batch(s)
        batch_j = {k: jnp.asarray(v) for k, v in hb.items()}
        params, l = step(params, batch_j)
    return params, float(l)


def _eval(model: Model, params, n_batches: int = 4):
    ds = SyntheticLM(vocab=model.cfg.vocab, seq_len=64, global_batch=16,
                     seed=1234)   # held-out stream

    @jax.jit
    def fwd(p, batch):
        logits, _ = model.forward(p, batch)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, batch["labels"][..., None],
                                   -1)[..., 0]
        return jnp.mean(nll), logits

    nlls, logits_all = [], []
    for i in range(n_batches):
        hb = ds.batch(10_000 + i)
        b = {k: jnp.asarray(v) for k, v in hb.items()}
        nll, lg = fwd(params, b)
        nlls.append(float(nll))
        logits_all.append(lg)
    return float(np.mean(nlls)), logits_all


def _kl(p_logits, q_logits):
    tot, n = 0.0, 0
    for a, b in zip(p_logits, q_logits):
        p = jax.nn.softmax(a.astype(jnp.float32), -1)
        lq = jax.nn.log_softmax(b.astype(jnp.float32), -1)
        lp = jnp.log(p + 1e-9)
        tot += float(jnp.mean(jnp.sum(p * (lp - lq), -1)))
        n += 1
    return tot / n


def run() -> list[str]:
    model = get_model(_ABL_CFG)
    t0 = time.time()
    params, train_loss = _train(model)
    rows = []
    fp_nll, fp_logits = _eval(model, params)
    for name, fn in SCHEMES.items():
        if name == "fp":
            qparams, t_us = params, 0.0
        else:
            t1 = time.time()
            qparams = fake_quantize_tree_with(params, fn, bits=9, axis=-1)
            t_us = (time.time() - t1) * 1e6
        nll, logits = _eval(model, qparams)
        kl = _kl(fp_logits, logits) if name != "fp" else 0.0
        ppl = float(np.exp(nll))
        emit(f"quant_ablation/{name}", t_us,
             f"ppl={ppl:.3f};dppl={ppl - np.exp(fp_nll):+.3f};kl={kl:.5f}")
        rows.append((name, ppl, kl))
    emit("quant_ablation/train", (time.time() - t0) * 1e6,
         f"train_loss={train_loss:.3f}")
    return rows


if __name__ == "__main__":
    run()
