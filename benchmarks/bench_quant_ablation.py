"""Table-1 reproduction (structure): quantization-scheme ablation, plus
the serving weight-plane sweep (W8 / W4-nibble / VQ / proxy-mixed).

No LAMBADA offline; instead (DESIGN.md §2-C5) we train a small RWKV-4 on the
synthetic motif stream until it has real structure to lose, then evaluate
perplexity + logit-KL-vs-FP under the same five schemes the paper compares:
FP (baseline), RTN, PoT, LogQ, Proposed (Δ-PoT W9 + per-channel MSE scales).

Expected ordering (the paper's): PoT worst, RTN/LogQ middle, Proposed
closest to FP.

The plane sweep then packs the SAME trained weights under each serving
plane policy (`core.quant.PlanePolicy`):

  w8     — all tensors Δ-PoT W8 (the historical serving plane)
  w4     — all tensors W4: two sign+3-bit nibble codes per uint8, HALF the
           megakernel slab bytes
  vq     — all tensors VQ: per-tensor 256-entry k-means codebook, uint8
           indices in the slab + bf16 codebook riding the const maps
  mixed  — RWKVQuant-style proxy picks a plane per tensor
           (weight-outlier proxy; `PLANE_PROXY`)

and reports, per plane: quality vs the fp oracle (ppl / logit-KL through
the per-op unpack path), megakernel decode tokens/s at batch 8 (parity-
asserted against the per-op path first — bit-identical, so the speed
number can never come from different math), and HBM bytes/token per
decode path derived from the ACTUAL packed arrays and fused slabs
(`bench_fused_decode.hbm_bytes_per_token`).

Gates (enforced via exit status on full runs, recorded always):
  * W4 megakernel bytes/token >= 1.7x smaller than W8 at batch 8 (the
    PR's slab-traffic claim — bytes are deterministic, so this is
    enforced even though it is measured in the same run as timing);
  * W4 decode tok/s >= W8 at batch 8 (halving the stream must not slow
    decode; timing gate, full runs only).

`--json` merges a "quant_planes" section into `BENCH_decode.json`,
preserving the fused-decode sweep and speculative section already there.

Run: PYTHONPATH=src python -m benchmarks.bench_quant_ablation
     [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant.policy import (PLANE_PROXY, PLANE_VQ, PLANE_W4,
                                     fake_quantize_tree_with)
from repro.core.quant.schemes import SCHEMES
from repro.core.quant.serving import (pack_params, plane_fingerprint,
                                      unpack_params)
from repro.configs.base import ModelConfig
from repro.data import SyntheticLM
from repro.models.registry import Model, get_model, loss_fn
from benchmarks.common import emit, provenance, tokens_per_s, \
    write_bench_json

JSON_PATH = "BENCH_decode.json"
PLANE_POLICIES = {
    "w8": None,            # pack_params' historical all-W8 default
    "w4": PLANE_W4,
    "vq": PLANE_VQ,
    "mixed": PLANE_PROXY,
}

_ABL_CFG = ModelConfig(
    name="rwkv4-ablation", family="rwkv",
    n_layers=4, d_model=128, n_heads=1, n_kv_heads=1,
    d_ff=512, vocab=512, norm="layernorm", rwkv_version=4, remat=False,
    dtype="float32",
)


def _train(model: Model, steps: int = 240, batch: int = 16, seq: int = 64):
    ds = SyntheticLM(vocab=model.cfg.vocab, seq_len=seq, global_batch=batch,
                     seed=7)
    params = model.init_params(jax.random.PRNGKey(0))

    @jax.jit
    def step(p, batch):
        (l, _), g = jax.value_and_grad(
            lambda q: loss_fn(model, q, batch), has_aux=True)(p)
        return jax.tree_util.tree_map(lambda a, b: a - 3e-3 * b, p, g), l

    for s in range(steps):
        hb = ds.batch(s)
        batch_j = {k: jnp.asarray(v) for k, v in hb.items()}
        params, l = step(params, batch_j)
    return params, float(l)


def _eval(model: Model, params, n_batches: int = 4):
    """ppl + logits on a held-out stream (params may be an unpacked tree)."""
    ds = SyntheticLM(vocab=model.cfg.vocab, seq_len=64, global_batch=16,
                     seed=1234)   # held-out stream

    @jax.jit
    def fwd(p, batch):
        logits, _ = model.forward(p, batch)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, batch["labels"][..., None],
                                   -1)[..., 0]
        return jnp.mean(nll), logits

    nlls, logits_all = [], []
    for i in range(n_batches):
        hb = ds.batch(10_000 + i)
        b = {k: jnp.asarray(v) for k, v in hb.items()}
        nll, lg = fwd(params, b)
        nlls.append(float(nll))
        logits_all.append(lg)
    return float(np.mean(nlls)), logits_all


def _kl(p_logits, q_logits):
    tot, n = 0.0, 0
    for a, b in zip(p_logits, q_logits):
        p = jax.nn.softmax(a.astype(jnp.float32), -1)
        lq = jax.nn.log_softmax(b.astype(jnp.float32), -1)
        lp = jnp.log(p + 1e-9)
        tot += float(jnp.mean(jnp.sum(p * (lp - lq), -1)))
        n += 1
    return tot / n


def _scheme_rows(model: Model, params, fp_nll, fp_logits,
                 n_batches: int) -> list:
    rows = []
    for name, fn in SCHEMES.items():
        if name == "fp":
            qparams, t_us = params, 0.0
        else:
            t1 = time.time()
            qparams = fake_quantize_tree_with(params, fn, bits=9, axis=-1)
            t_us = (time.time() - t1) * 1e6
        nll, logits = _eval(model, qparams, n_batches)
        kl = _kl(fp_logits, logits) if name != "fp" else 0.0
        ppl = float(np.exp(nll))
        emit(f"quant_ablation/{name}", t_us,
             f"ppl={ppl:.3f};dppl={ppl - np.exp(fp_nll):+.3f};kl={kl:.5f}")
        rows.append((name, ppl, kl))
    return rows


# ---------------------------------------------------------------------------
# Serving weight-plane sweep (W8 / W4 / VQ / proxy-mixed)
# ---------------------------------------------------------------------------


def _plane_sweep(model: Model, params, fp_nll, fp_logits, *, batch: int,
                 n_batches: int, iters: int, rounds: int) -> list[dict]:
    """Pack the trained weights under each plane policy; measure quality
    (per-op unpack forward), megakernel decode tok/s (parity-asserted)
    and actual bytes/token per decode path."""
    from benchmarks.bench_fused_decode import _carried, hbm_bytes_per_token
    cfg = model.cfg
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)
    st0 = model.init_decode_state(batch, 0, jnp.bfloat16)

    records = []
    for name, policy in PLANE_POLICIES.items():
        packed = pack_params(params, policy)

        # quality through the per-op unpack path (the serving oracle)
        nll, logits = _eval(model, unpack_params(packed), n_batches)
        ppl, kl = float(np.exp(nll)), _kl(fp_logits, logits)

        # megakernel decode: parity vs per-op FIRST, then time
        mono_q = jax.jit(lambda p, s, t: model.decode_step(
            unpack_params(p), s, t, jnp.int32(0)))
        fused_mq = jax.jit(lambda p, s, t: model.decode_step_fused_model(
            p, s, t, jnp.int32(0)))
        prep = model.prepare_fused_model_params(packed)
        l_mono, _ = mono_q(packed, st0, toks)
        l_mega, _ = fused_mq(prep, st0, toks)
        assert np.array_equal(np.asarray(l_mono, np.float32),
                              np.asarray(l_mega, np.float32)), \
            f"plane {name}: megakernel != per-op oracle"

        step = _carried(lambda s, f=fused_mq, p=prep: f(p, s, toks))
        tok_s = 0.0
        for _ in range(rounds):
            step.state = st0
            tok_s = max(tok_s, tokens_per_s(step, batch, iters=iters))

        hbm = hbm_bytes_per_token(cfg, batch, packed, prep)
        records.append({
            "plane": name,
            "fingerprint": plane_fingerprint(packed),
            "batch": batch,
            "ppl": round(ppl, 4),
            "dppl_vs_fp": round(ppl - float(np.exp(fp_nll)), 4),
            "kl_vs_fp": round(kl, 6),
            "tok_s": round(tok_s, 3),
            "hbm_bytes_per_token": hbm,
        })
        emit(f"quant_planes/{name}/batch{batch}", batch * 1e6 / tok_s,
             f"ppl={ppl:.3f};kl={kl:.5f};tok_s={tok_s:.1f};"
             f"hbm_bytes_tok_model={hbm['fused_model']:.5g};"
             f"fingerprint={plane_fingerprint(packed)}")
    return records


def _plane_gates(records: list[dict]) -> dict:
    by = {r["plane"]: r for r in records}
    w8, w4 = by["w8"], by["w4"]
    return {
        "w4_hbm_bytes_vs_w8_batch8": {
            "ratio": round(w8["hbm_bytes_per_token"]["fused_model"]
                           / w4["hbm_bytes_per_token"]["fused_model"], 3),
            "target": 1.7},
        "w4_tok_s_vs_w8_batch8": {
            "ratio": round(w4["tok_s"] / max(w8["tok_s"], 1e-9), 3),
            "target": 1.0},
    }


def run(smoke: bool = False, json_out: bool = False) -> bool:
    model = get_model(_ABL_CFG)
    t0 = time.time()
    steps = 60 if smoke else 240
    n_batches = 2 if smoke else 4
    params, train_loss = _train(model, steps=steps)
    fp_nll, fp_logits = _eval(model, params, n_batches)
    _scheme_rows(model, params, fp_nll, fp_logits, n_batches)
    emit("quant_ablation/train", (time.time() - t0) * 1e6,
         f"train_loss={train_loss:.3f}")

    records = _plane_sweep(model, params, fp_nll, fp_logits, batch=8,
                           n_batches=n_batches,
                           iters=2 if smoke else 6,
                           rounds=2 if smoke else 4)
    gates = _plane_gates(records)
    ok = True
    for name, g in gates.items():
        g["pass"] = g["ratio"] >= g["target"]
        ok = ok and g["pass"]
        print(f"gate: {name} = {g['ratio']:.2f}x "
              f"(target >= {g['target']}x) -> "
              f"{'PASS' if g['pass'] else 'FAIL'}")

    if json_out:
        # merge into BENCH_decode.json: the plane rows extend the decode
        # record; the fused-decode sweep and speculative section stay
        payload = {}
        if os.path.exists(JSON_PATH):
            with open(JSON_PATH) as f:
                payload = json.load(f)
        payload["quant_planes"] = {
            "arch": _ABL_CFG.name,
            "backend": jax.default_backend(),
            "smoke": smoke,
            "batch": 8,
            "provenance": provenance(),
            "records": records,
            "gates": gates,
        }
        write_bench_json(JSON_PATH, payload)
    # the bytes gate is deterministic (actual array sizes), so it is
    # enforced even on smoke; the timing gate only fails full runs
    bytes_ok = gates["w4_hbm_bytes_vs_w8_batch8"]["pass"]
    return bytes_ok and (ok or smoke)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short train + tiny sweep for CI; the timing "
                         "gate is reported but not enforced (the "
                         "deterministic bytes gate always is)")
    ap.add_argument("--json", action="store_true",
                    help=f"merge a quant_planes section into {JSON_PATH}")
    args = ap.parse_args()
    return 0 if run(smoke=args.smoke, json_out=args.json) else 1


if __name__ == "__main__":
    raise SystemExit(main())
