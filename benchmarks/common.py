"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 5, **kw) -> float:
    """Median wall time of fn(*args) in microseconds (blocking on device)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
