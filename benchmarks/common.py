"""Shared benchmark utilities: timing, CSV emission, BENCH_*.json records."""
from __future__ import annotations

import json
import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 5, **kw) -> float:
    """Median wall time of fn(*args) in microseconds (blocking on device)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def tokens_per_s(step_fn, batch: int, *, warmup: int = 1,
                 iters: int = 5) -> float:
    """Median decode throughput (tokens/s) of a state-carrying step closure:
    `step_fn()` advances `batch` sequences by one token and is timed with
    `time_call`, so every decode benchmark shares one warmup/median policy."""
    return batch * 1e6 / max(time_call(step_fn, warmup=warmup, iters=iters),
                             1e-9)


def tree_hbm_bytes(tree) -> int:
    """ACTUAL bytes of every array in a pytree — the HBM residency of a
    weight set as stored, not an analytic guess.  Works on raw fp trees,
    packed trees (W8 uint8 codes, W4 nibble pairs at half the bytes, VQ
    uint8 indices + bf16 codebooks) and prepared megakernel trees
    (`FusedLayerStack` is a registered pytree, so its per-dtype slabs and
    aux const maps are counted at their true dtypes).  This is what the
    decode benchmarks' bytes/token accounting is derived from, so a new
    weight plane changes the number without anyone editing a formula."""
    total = 0
    for a in jax.tree_util.tree_leaves(tree):
        if hasattr(a, "dtype") and hasattr(a, "size"):
            total += int(a.size) * jax.numpy.dtype(a.dtype).itemsize
    return total


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def provenance() -> dict:
    """Where this record was measured: jax version, device kind/count and
    the active mesh shape (None outside any mesh).  Stamped into every
    BENCH_*.json by `write_bench_json`, so a number can never be compared
    across PRs without knowing what hardware/topology produced it."""
    from repro.parallel.sharding import get_current_mesh
    devices = jax.devices()
    mesh = get_current_mesh()
    return {
        "jax_version": jax.__version__,
        "device_kind": devices[0].device_kind,
        "device_count": len(devices),
        "mesh_shape": dict(mesh.shape) if mesh is not None else None,
    }


def write_bench_json(path: str, payload: dict) -> None:
    """Persist one benchmark's machine-readable record (a BENCH_*.json at
    the repo root) so the perf trajectory is diffable across PRs.  The
    measurement provenance (jax version, device kind/count, mesh shape)
    is stamped into every record; a payload's own "provenance" key wins
    if it sets one."""
    payload = {"provenance": provenance(), **payload}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", flush=True)
