"""Shared benchmark utilities: timing, CSV emission, BENCH_*.json records."""
from __future__ import annotations

import json
import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 5, **kw) -> float:
    """Median wall time of fn(*args) in microseconds (blocking on device)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def tokens_per_s(step_fn, batch: int, *, warmup: int = 1,
                 iters: int = 5) -> float:
    """Median decode throughput (tokens/s) of a state-carrying step closure:
    `step_fn()` advances `batch` sequences by one token and is timed with
    `time_call`, so every decode benchmark shares one warmup/median policy."""
    return batch * 1e6 / max(time_call(step_fn, warmup=warmup, iters=iters),
                             1e-9)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def write_bench_json(path: str, payload: dict) -> None:
    """Persist one benchmark's machine-readable record (a BENCH_*.json at
    the repo root) so the perf trajectory is diffable across PRs."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", flush=True)
