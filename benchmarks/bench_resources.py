"""Table-2 analogue: footprint accounting per model size.

The paper's Table 2 reports FPGA LUT/FF/DSP/BRAM/URAM — fabric concepts with
no TPU analogue (DESIGN.md §2).  The TPU-meaningful equivalent: HBM bytes of
the weights at fp16 vs the mixed-precision quantized packing (Δ-PoT matrices
+ W9 additive), the achieved compression (the paper's bandwidth story), and
the VMEM working set the fused kernels claim per block.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.configs.base import RWKV4_ARCHS, ASSIGNED_ARCHS, get_config
from repro.models.param import P
from repro.models.registry import get_model
from repro.core.quant.policy import classify_param
from repro.core.quant.delta_pot import FORMAT_W8
from benchmarks.common import emit

VMEM_BYTES = 128 * 1024 * 1024  # v5e ~128 MiB VMEM per chip


def spec_bytes(arch: str):
    """Static byte accounting straight from the parameter spec (no
    materialization — works for the 400B config)."""
    model = get_model(arch)
    spec = model.spec()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=lambda x: isinstance(x, P))
    b_fp16 = b_quant = 0
    for path, p in flat:
        n = int(np.prod(p.shape))
        key = jax.tree_util.keystr(path)
        # classify on path + ndim without materializing the tensor
        kind = classify_param(key, type("L", (), {"ndim": len(p.shape)})())
        b_fp16 += n * 2
        if kind == "matmul":
            b_quant += n * FORMAT_W8.total_bits // 8 + 4 * p.shape[-1]
        else:
            b_quant += (n * 9 + 7) // 8 + 4
    return model, b_fp16, b_quant


def run():
    for arch in RWKV4_ARCHS + ASSIGNED_ARCHS:
        model, b16, bq = spec_bytes(arch)
        cfg = model.cfg
        d = cfg.d_model
        # fused-step VMEM working set: activations + one streamed weight tile
        # (128x512 int8) + wkv state (3 channel vectors or H*N*N)
        if cfg.rwkv_version == 6:
            state = cfg.n_heads * cfg.rwkv_head_dim ** 2 * 4
        else:
            state = 3 * d * 4
        vmem = 8 * d * 4 + 128 * 512 + state
        emit(f"resources/{arch}", 0.0,
             f"params={model.param_count()/1e6:.1f}M;"
             f"fp16_GB={b16/2**30:.3f};quant_GB={bq/2**30:.3f};"
             f"compression={b16/max(bq,1):.2f}x;"
             f"vmem_step_KB={vmem/1024:.0f};"
             f"fits_vmem={vmem < VMEM_BYTES}")


if __name__ == "__main__":
    run()
