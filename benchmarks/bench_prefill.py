"""Fused chunked prefill vs the per-op scan-of-decode_step, across chunks.

Prefill gates time-to-first-token and dominates prompt-heavy serving
traffic.  This benchmark times the ENGINE'S OWN two prefill programs (it
constructs `ServingEngine`s and drives their compiled prefill functions
directly, so what is measured is exactly what serves):

  * PER-OP  — `fused_prefill=False`: a `lax.scan` of the masked pool-wide
    `decode_step` over the chunk.  One D-wide matvec per token per
    projection: every token re-reads the entire weight set, and with
    Δ-PoT weights the whole tree is unpacked to bf16 in HBM first.
  * FUSED   — `fused_prefill=True` (`Model.prefill_chunk` through
    `kernels/fused_prefill.py`): the chunk's token-shift / layernorm /
    projections / FFN as (S·C, D)-shaped matmuls — the weight stream is
    read ONCE per chunk, amortized over C tokens — and the WKV recurrence
    through the Pallas sequence kernels with the recurrent state resident
    on-chip across the chunk's timesteps.  Packed Δ-PoT codes decode
    inside the matmul kernels: uint8 is all that crosses HBM.

Both programs are bit-identical (asserted here before timing, and pinned
exhaustively in tests/test_prefill.py).  The sweep covers prefill chunk
sizes {16, 64, 256} x batch {1, 8} x fp/dpot_w8, reporting absorbed
prompt tokens/s and the analytic weight-stream bytes per prompt token.

Gate (enforced via exit status on full runs, recorded always):
  * fused >= 2.0x per-op at chunk 64, batch 8 (fp) — the paper's §4
    reordering claim, applied to the prompt phase.

`--json` writes BENCH_prefill.json; `--smoke` shrinks the sweep for CI,
where the schema is validated but timing gates are not enforced.

Run: PYTHONPATH=src python -m benchmarks.bench_prefill [--smoke] [--json]
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call, write_bench_json
from repro.models.registry import get_model
from repro.serving import ServingEngine

ARCH = "rwkv4-169m"
CHUNKS = (16, 64, 256)
BATCHES = (1, 8)
N_ITERS = 10
N_ROUNDS = 5     # interleaved best-of-rounds (see bench_fused_decode)
JSON_PATH = "BENCH_prefill.json"
GATE_CHUNK, GATE_BATCH, GATE_X = 64, 8, 2.0


def weight_stream_bytes_per_token(cfg, chunk: int, packed: bool) -> dict:
    """Analytic weight bytes crossing HBM per absorbed prompt token.

    Per-op: every scan step re-reads the full weight set (bf16; with
    packed weights the tree is unpacked first — uint8 read + bf16 write
    once per chunk, then bf16 re-read per token).  Fused: each chunk
    matmul reads its weight tile ONCE per chunk — 1/C of the stream per
    token, at 1 B/weight when packed (codes decode in-kernel)."""
    D, F, Lc, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    n_w = Lc * (5 * D * D + 2 * D * F) + 2 * V * D
    if packed:
        per_op = n_w * (1 + 2) / chunk + n_w * 2     # unpack, then re-read
        fused = n_w * 1 / chunk
    else:
        per_op = n_w * 2
        fused = n_w * 2 / chunk
    return {"per_op": per_op, "fused": fused}


def _engines(model, params, chunk: int, batch: int, quantized: bool):
    mk = lambda fused: ServingEngine(
        model, params=params, max_batch=batch, prefill_chunk=chunk,
        quantized=quantized, fused_prefill=fused)
    return mk(False), mk(True)


def _prefill_closure(engine, toks, valid, fresh):
    """State-carrying closure over the engine's compiled prefill program
    (the pool state buffer is donated per call, exactly as in serving)."""
    fn = engine.scheduler.prefill_fn

    def run():
        engine.pool.state, last = fn(engine.pool.state, toks, valid, fresh)
        return last
    return run


def bench_cell(model, params, chunk: int, batch: int, quantized: bool,
               iters: int, rounds: int, records: list) -> dict:
    cfg = model.cfg
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (batch, chunk)).astype(np.int32)
    valid = np.ones((batch, chunk), bool)
    fresh = np.zeros((batch,), bool)
    per_op, fused = _engines(model, params, chunk, batch, quantized)

    # --- bit-equivalence before timing (fresh lanes, full chunk) ---------
    st1, l1 = per_op.scheduler.prefill_fn(
        per_op.pool.state, toks, valid, np.ones((batch,), bool))
    st2, l2 = fused.scheduler.prefill_fn(
        fused.pool.state, toks, valid, np.ones((batch,), bool))
    assert np.array_equal(np.asarray(l1, np.float32),
                          np.asarray(l2, np.float32))
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
    per_op.pool.state, fused.pool.state = st1, st2

    variants = {
        "per_op": _prefill_closure(per_op, toks, valid, fresh),
        "fused": _prefill_closure(fused, toks, valid, fresh),
    }
    tok_s = {name: 0.0 for name in variants}
    for _ in range(rounds):
        for name, step in variants.items():
            us = time_call(step, iters=iters)
            tok_s[name] = max(tok_s[name], batch * chunk * 1e6 / us)
    quant = "dpot_w8" if quantized else "fp"
    wbytes = weight_stream_bytes_per_token(cfg, chunk, quantized)
    for name in variants:
        records.append({
            "variant": name, "quant": quant, "batch": batch,
            "chunk": chunk, "tok_s": round(tok_s[name], 3),
            "us_per_chunk": round(batch * chunk * 1e6 / tok_s[name], 1),
            "weight_bytes_per_token": wbytes[name],
        })
    emit(f"prefill/{cfg.name}/chunk{chunk}/batch{batch}/{quant}",
         batch * chunk * 1e6 / tok_s["fused"],
         f"per_op_tok_s={tok_s['per_op']:.1f};"
         f"fused_tok_s={tok_s['fused']:.1f};"
         f"fused_vs_per_op={tok_s['fused']/tok_s['per_op']:.2f}x;"
         f"weight_bytes_tok_fused={wbytes['fused']:.3g}")
    return tok_s


def run(smoke: bool = False, json_out: bool = False) -> bool:
    model = get_model(ARCH, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    chunks = CHUNKS[:2] if smoke else CHUNKS
    iters = 2 if smoke else N_ITERS
    rounds = 2 if smoke else N_ROUNDS
    records: list[dict] = []
    gate_cell = {}
    for quantized in (False, True):
        for chunk in chunks:
            for batch in BATCHES:
                tok_s = bench_cell(model, params, chunk, batch, quantized,
                                   iters, rounds, records)
                if (not quantized and chunk == GATE_CHUNK
                        and batch == GATE_BATCH):
                    gate_cell = tok_s

    gates = {
        f"fused_vs_per_op_chunk{GATE_CHUNK}_batch{GATE_BATCH}": {
            "speedup": round(gate_cell["fused"] / gate_cell["per_op"], 3)
            if gate_cell else None,
            "target": GATE_X},
    }
    ok = True
    for name, g in gates.items():
        g["pass"] = g["speedup"] is not None and g["speedup"] >= g["target"]
        ok = ok and g["pass"]
        print(f"gate: {name} = {g['speedup']}x "
              f"(target >= {g['target']}x) -> "
              f"{'PASS' if g['pass'] else 'FAIL'}")

    if json_out:
        write_bench_json(JSON_PATH, {
            "bench": "prefill",
            "arch": model.cfg.name,
            "backend": jax.default_backend(),
            "smoke": smoke,
            "chunks": list(chunks),
            "batches": list(BATCHES),
            "iters": iters,
            "records": records,
            "gates": gates,
        })
    # CI smoke pins the script + JSON schema, not shared-runner timing
    return ok or smoke


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal sweep for CI: fewer chunks/iterations; "
                         "gates reported but not enforced")
    ap.add_argument("--json", action="store_true",
                    help=f"write {JSON_PATH} (machine-readable records)")
    args = ap.parse_args()
    return 0 if run(smoke=args.smoke, json_out=args.json) else 1


if __name__ == "__main__":
    raise SystemExit(main())
