"""End-to-end driver (assignment deliverable b): train a ~100M-class RWKV-4
for a few hundred steps on the synthetic pipeline, with checkpointing and a
simulated mid-run host failure + restore (the fault-tolerance drill).

    PYTHONPATH=src python examples/train_rwkv4.py [--steps 300] [--full-169m]

Default uses a ~15M-param RWKV-4 (CPU-friendly); --full-169m trains the
paper's real 169M config (slower).
"""
import argparse
import os
import tempfile

import jax

from repro.configs.base import ModelConfig
from repro.checkpoint import latest_step
from repro.launch.train import train
from repro.models.registry import get_model
from repro.runtime import FailureInjector, TrainingSupervisor
from repro.runtime.monitor import HostFailure

CFG_100M = ModelConfig(          # ~15M params: 100M-class structure, CPU pace
    name="rwkv4-mini", family="rwkv",
    n_layers=6, d_model=384, n_heads=1, n_kv_heads=1,
    d_ff=1536, vocab=8192, norm="layernorm", rwkv_version=4, remat=False,
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-169m", action="store_true")
    ap.add_argument("--drill", action="store_true",
                    help="inject a host failure mid-run and recover")
    args = ap.parse_args()

    arch = "rwkv4-169m" if args.full_169m else None
    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_rwkv4_ckpt")

    def run_training(start_hint=None):
        if arch:
            return train(arch, smoke=False, steps=args.steps,
                         global_batch=args.batch, seq_len=args.seq,
                         ckpt_dir=ckpt_dir, ckpt_every=50)
        # custom config path: reuse the launcher internals via get_model
        from repro.launch import train as T
        import repro.models.registry as REG
        model = REG.get_model(CFG_100M)
        # patch-through: call the launcher with the model's config registered
        return T.train_model(model, steps=args.steps,
                             global_batch=args.batch, seq_len=args.seq,
                             ckpt_dir=ckpt_dir, ckpt_every=50)

    if not args.drill:
        out = run_training()
        print(f"\nloss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
              f"over {args.steps} steps ({out['wall_s']:.0f}s)")
        assert out["losses"][-1] < out["losses"][0], "loss must go down"
        return

    # --- fault-tolerance drill: fail at 60% of the run, restore, finish
    fail_at = int(args.steps * 0.6)
    injector = FailureInjector({fail_at: [3]})
    progress = {"step": 0}

    def step_fn(step):
        injector.check(step)
        progress["step"] = step

    def restore_fn(hosts):
        last = latest_step(ckpt_dir) or 0
        print(f"  hosts {hosts} lost; restoring checkpoint step {last}")
        return last

    sup = TrainingSupervisor(step_fn, restore_fn)
    # the drill wraps the *control flow*; the real training below proves the
    # checkpoint/restore path end-to-end
    sup.run(args.steps)
    print(f"drill complete: {sup.restarts} restart(s); log: {sup.log}")
    out = run_training()
    print(f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
