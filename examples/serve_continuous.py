"""Continuous-batching serving demo: many requests, one state pool.

    PYTHONPATH=src python examples/serve_continuous.py --smoke

Submits several concurrent requests with different prompt lengths and
budgets, streams their tokens as the engine interleaves chunked prefill
with fused batched decode, then verifies every request's output is
bit-identical to decoding it alone with a sequential batch-1 loop (the
engine's correctness contract — see docs/serving.md).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.launch.serve import sequential_decode
from repro.models.registry import get_model
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv4-169m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=3,
                    help="pool slots (< requests exercises queueing)")
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--plane-policy", default=None,
                    choices=["w8", "w4", "vq", "proxy"],
                    help="per-tensor weight-plane preset (implies "
                         "--quantized); default keeps all-W8 packing")
    args = ap.parse_args()

    plane_policy = None
    if args.plane_policy is not None:
        from repro.core.quant import (PLANE_PROXY, PLANE_VQ, PLANE_W4,
                                      PLANE_W8)
        plane_policy = {"w8": PLANE_W8, "w4": PLANE_W4, "vq": PLANE_VQ,
                        "proxy": PLANE_PROXY}[args.plane_policy]
        args.quantized = True

    model = get_model(args.arch, smoke=args.smoke)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params=params, max_batch=args.max_batch,
                           prefill_chunk=8, quantized=args.quantized,
                           plane_policy=plane_policy)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab,
                            size=int(rng.integers(3, 20))).tolist()
               for _ in range(args.requests)]
    handles = [engine.submit(p, max_new_tokens=args.tokens)
               for p in prompts]
    quant_label = engine.plan.cache_variant().quant
    print(f"{args.requests} requests -> {args.max_batch}-slot pool "
          f"({quant_label} weights)\n")

    # stream: drive the engine and print tokens as each request emits them
    streamed: dict[int, list[int]] = {h.rid: [] for h in handles}
    more = True
    while more:
        more = engine.step()
        for h in handles:
            for tok in h.drain():
                streamed[h.rid].append(tok)
                print(f"  req{h.rid} +{tok}", end="")
        print()
    print()

    snap = engine.counters.snapshot()
    print(f"{snap['decode_tokens']} tokens in {snap['ticks']} ticks "
          f"({snap['decode_tokens_per_s']:,.0f} tok/s, "
          f"TTFT {snap['mean_ttft_s']*1e3:.0f} ms)")

    if args.quantized:
        print("(skipping bit-identity check: the sequential reference "
              "below is fp — rerun without --quantized)")
        return
    ok = True
    for h, p in zip(handles, prompts):
        ref = sequential_decode(model, params, p, args.tokens)
        match = streamed[h.rid] == ref == h.tokens
        ok &= match
        print(f"req{h.rid}: engine == sequential decode: {match}")
    if not ok:
        raise SystemExit("outputs diverged from sequential decode")
    print("all outputs bit-identical to sequential decode ✓")


if __name__ == "__main__":
    main()
