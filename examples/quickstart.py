"""Quickstart: the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Covers: picking an architecture, materializing parameters, a forward pass,
the paper's Δ-PoT quantization of the weights, and one decode step with the
quantized model.

The decode loop below is the single-request form.  For serving many
concurrent requests — slotted state pool, chunked prefill interleaved with
fused batched decode, token streaming — use `repro.serving.ServingEngine`:
see docs/serving.md and examples/serve_continuous.py.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import list_configs
from repro.models.registry import get_model
from repro.core.quant.policy import QuantPolicy, fake_quantize_tree

def main():
    print("registered architectures:")
    for name in list_configs():
        print("  -", name)

    # any arch id works; smoke=True gives a CPU-sized same-family config
    model = get_model("rwkv6-7b", smoke=True)
    cfg = model.cfg
    print(f"\nusing {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"({model.param_count():,} params)")

    params = model.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits, _ = model.forward(params, {"tokens": tokens})
    print("forward:", tokens.shape, "->", logits.shape)

    # the paper's mixed-precision quantization (Δ-PoT matrices, W9 additive)
    qparams = fake_quantize_tree(params, QuantPolicy())
    qlogits, _ = model.forward(qparams, {"tokens": tokens})
    drift = float(jnp.mean(jnp.abs(
        qlogits.astype(jnp.float32) - logits.astype(jnp.float32))))
    print(f"quantized forward drift: {drift:.4f} (mean |Δlogit|)")

    # O(1)-state decode (the paper's serving mode)
    state = model.init_decode_state(batch=2, max_len=8)
    tok = tokens[:, :1]
    for t in range(4):
        out, state = model.decode_step(qparams, state, tok, jnp.int32(t))
        tok = jnp.argmax(out[:, -1], -1)[:, None].astype(jnp.int32)
    print("decoded 4 tokens with the quantized model:", tok[:, 0].tolist())


if __name__ == "__main__":
    main()
