"""Serve a small RWKV model with batched requests under the paper's
quantization + hardware numerics (deliverable b, serving flavour).

    PYTHONPATH=src python examples/serve_rwkv_quantized.py

Compares three serving configurations on the same weights:
  1. fp          — float weights, exact exp/sigmoid/div
  2. quantized   — Δ-PoT W9 weights + W9 additive (paper §3)
  3. hw          — quantized + the accelerator's LUT-exp / PWL-sigmoid /
                   LUT-div + A9 activations (paper §4, full hardware model)
and reports throughput + agreement of the generated tokens.
"""
import time

import jax
import jax.numpy as jnp

from repro.core.quant.policy import QuantPolicy, fake_quantize_tree
from repro.launch.serve import greedy_decode
from repro.models import rwkv4 as R4
from repro.models.registry import get_model

BATCH, TOKENS = 4, 24


class HwModel:
    """RWKV-4 with the paper's full accelerator numerics."""

    def __init__(self, model):
        self._m = model
        self.cfg = model.cfg

    def decode_step(self, p, s, t, pos):
        return R4.decode_step(self._m.cast_params(p), s, t, pos, self.cfg,
                              hw=True)


def decode_run(model, params, label):
    state = model.cfg and None
    m = model if not isinstance(model, HwModel) else model
    base = model._m if isinstance(model, HwModel) else model
    state = base.init_decode_state(BATCH, TOKENS + 4)
    first = jnp.ones((BATCH, 1), jnp.int32)
    t0 = time.time()
    toks, _ = greedy_decode(m, params, state, first, TOKENS)
    dt = time.time() - t0
    print(f"{label:10s}: {BATCH * TOKENS / dt:8,.0f} tok/s "
          f"(first seq: {toks[0, :10].tolist()} ...)")
    return toks


def main():
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    qparams = fake_quantize_tree(params, QuantPolicy())

    t_fp = decode_run(model, params, "fp")
    t_q = decode_run(model, qparams, "quantized")
    t_hw = decode_run(HwModel(model), qparams, "hw")

    agree_q = float(jnp.mean((t_fp == t_q).astype(jnp.float32)))
    agree_hw = float(jnp.mean((t_fp == t_hw).astype(jnp.float32)))
    print(f"\ntoken agreement vs fp: quantized {agree_q:.0%}, "
          f"hw-numerics {agree_hw:.0%}")
    print("(random-init weights make argmax near-tied, so agreement here is"
          " a weak lower bound; the paper's Table-1 accuracy claim is"
          " verified on trained weights via logit-KL in"
          " benchmarks/bench_quant_ablation.py and"
          " tests/test_models.py::test_rwkv4_hw_numerics_close_to_std)")


if __name__ == "__main__":
    main()
