"""ExecutionPlan tests: path descriptors, one-pass param preparation,
the (path, batch bucket, dtype) program cache, shared masking semantics,
and — under XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI
multi-device leg) — the data-parallel serving proof: an 8-device engine
streams BIT-IDENTICAL tokens to the 1-device engine for rwkv4 + rwkv6,
fp + Δ-PoT packed, fused and per-op paths, with the slot pool actually
sharded across all devices."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.quant.serving import (
    PreparedParams, is_packed_leaf, pack_params, predecode_packed_leaves)
from repro.models.registry import get_model
from repro.serving import ServingEngine, build_plan
from repro.serving.plan import masked_state_commit, maybe_unpack

MULTI = len(jax.devices()) >= 8


@pytest.fixture(scope="module")
def rwkv4():
    model = get_model("rwkv4-169m", smoke=True)
    return model, model.init_params(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Descriptors + one-pass preparation
# ---------------------------------------------------------------------------


class TestDescriptors:
    def test_decode_paths_match_module_entries(self):
        model = get_model("rwkv4-169m", smoke=True)
        paths = model.decode_paths()
        assert set(paths) == {"per_op", "block", "model"}
        assert paths["per_op"].fused is False
        assert paths["model"].prepare == "prepare_fused_model_params"
        assert set(model.prefill_paths()) == {"per_op", "chunked"}

    def test_has_flags_are_descriptor_views(self, monkeypatch):
        from repro.models import rwkv4 as R4
        monkeypatch.delattr(R4, "decode_step_fused_model")
        model = get_model("rwkv4-169m", smoke=True)
        assert "model" not in model.decode_paths()
        assert not model.has_fused_model_decode
        assert model.has_fused_decode and model.has_decode

    def test_plain_transformer_has_only_per_op(self):
        model = get_model("smollm-135m", smoke=True)
        assert set(model.decode_paths()) == {"per_op"}
        assert set(model.prefill_paths()) == {"per_op"}

    def test_build_plan_rejects_unknown_decode_path(self, rwkv4):
        model, params = rwkv4
        with pytest.raises(ValueError, match="fused_decode"):
            build_plan(model, params, fused_decode="layerwise")

    def test_build_plan_rejects_missing_entry(self, monkeypatch, rwkv4):
        from repro.models import rwkv4 as R4
        monkeypatch.delattr(R4, "prefill_chunk")
        model = get_model("rwkv4-169m", smoke=True)
        with pytest.raises(ValueError, match="prefill_chunk"):
            build_plan(model, fused_prefill=True)


class TestPreparedParams:
    def test_per_op_paths_alias_raw(self, rwkv4):
        model, params = rwkv4
        plan = build_plan(model, params)
        assert isinstance(plan.prepared, PreparedParams)
        assert plan.prepared.decode is plan.prepared.raw
        assert plan.prepared.prefill is plan.prepared.raw
        assert plan.prepared.decode_path == "per_op"

    def test_quantized_packs_once(self, rwkv4):
        model, params = rwkv4
        plan = build_plan(model, params, quantized=True)
        # raw is the packed tree; per-op decode consumes it via in-trace
        # unpack (maybe_unpack), not a second prepared copy
        assert plan.prepared.quantized
        assert is_packed_leaf(
            plan.prepared.raw["blocks"]["att"]["wk"])
        assert plan.prepared.decode is plan.prepared.raw

    def test_rwkv6_prefill_prep_decodes_elementwise_leaves(self):
        model = get_model("rwkv6-7b", smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        plan = build_plan(model, params, quantized=True,
                          fused_prefill=True)
        raw_att = plan.prepared.raw["blocks"]["att"]
        pre_att = plan.prepared.prefill["blocks"]["att"]
        assert is_packed_leaf(raw_att["time_maa"])
        assert not is_packed_leaf(pre_att["time_maa"])   # pre-decoded
        assert is_packed_leaf(pre_att["wk"])             # still packed

    def test_megakernel_prep_builds_layer_stack(self, rwkv4):
        from repro.core.quant.serving import FusedLayerStack
        model, params = rwkv4
        plan = build_plan(model, params, fused_decode="model")
        assert isinstance(plan.prepared.decode["blocks"], FusedLayerStack)
        assert plan.prepared.prefill is plan.prepared.raw

    def test_predecode_packed_leaves_targets_only_named_paths(self, rng):
        w = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
        tree = pack_params({"a": {"x": w, "y": w}, "b": w})
        out = predecode_packed_leaves(tree, [("a", "x"), ("b",)])
        assert not is_packed_leaf(out["a"]["x"])
        assert not is_packed_leaf(out["b"])
        assert is_packed_leaf(out["a"]["y"])
        # plain leaves at a named path pass through untouched
        plain = {"a": {"x": w}}
        assert predecode_packed_leaves(plain, [("a", "x")])["a"]["x"] is w


# ---------------------------------------------------------------------------
# Program cache + shared masking semantics
# ---------------------------------------------------------------------------


class TestProgramCache:
    def test_cache_hit_same_bucket(self, rwkv4):
        model, params = rwkv4
        plan = build_plan(model, params)
        fn1 = plan.decode_fn(4)
        fn2 = plan.decode_fn(4)
        assert fn1 is fn2                     # cache hit, not a rebuild
        assert plan.prefill_fn(4) is plan.prefill_fn(4)

    def test_keys_include_path_bucket_dtype(self, rwkv4):
        model, params = rwkv4
        plan = build_plan(model, params)
        plan.decode_fn(4)
        plan.decode_fn(8)                     # new bucket -> new entry
        keys = set(plan._programs)
        assert ("decode", "per_op", 4, "bfloat16") in keys
        assert ("decode", "per_op", 8, "bfloat16") in keys

    def test_one_trace_across_ticks(self, rwkv4):
        """The no-recompile guarantee through the plan: churny serving
        still traces each program exactly once (as test_scheduler asserts
        through the engine)."""
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=3,
                               prefill_chunk=4)
        rng = np.random.default_rng(0)
        for _ in range(2):
            hs = [engine.submit(
                rng.integers(0, model.cfg.vocab,
                             size=int(rng.integers(1, 9))).tolist(),
                max_new_tokens=3) for _ in range(4)]
            engine.run()
            assert all(h.done for h in hs)
        assert engine.trace_counts == {"decode": 1, "prefill": 1}
        assert engine.plan.trace_counts is engine.trace_counts


class TestMaskedCommit:
    def test_masked_state_commit_semantics(self):
        old = {"a": jnp.zeros((2, 3, 4)), "b": jnp.zeros((3, 5))}
        new = {"a": jnp.ones((2, 3, 4)), "b": jnp.ones((3, 5))}
        mask = jnp.asarray([True, False, True])
        out = masked_state_commit(new, old, mask, axes=[1, 0])
        np.testing.assert_array_equal(
            np.asarray(out["a"][:, :, 0]), [[1, 0, 1]] * 2)
        np.testing.assert_array_equal(
            np.asarray(out["b"][:, 0]), [1, 0, 1])

    def test_broadcasts_batch1_template(self):
        """The prefill fresh-lane reset relies on a batch-1 `new` tree
        broadcasting into the masked lanes."""
        old = {"a": jnp.arange(6, dtype=jnp.float32).reshape(3, 2)}
        fresh = {"a": jnp.full((1, 2), 9.0)}
        out = masked_state_commit(old, fresh, ~jnp.asarray([True, False,
                                                            True]),
                                  axes=[0])
        np.testing.assert_array_equal(np.asarray(out["a"])[:, 0],
                                      [9.0, 2.0, 9.0])

    def test_maybe_unpack(self, rng):
        w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
        packed = pack_params({"w": w})
        assert maybe_unpack(packed, False) is packed
        assert maybe_unpack(packed, True)["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Mesh placement (1-device mesh runs everywhere; 8-device under the CI leg)
# ---------------------------------------------------------------------------


def _serving_mesh(n):
    from repro.launch.mesh import make_serving_mesh
    return make_serving_mesh(n)


def _tokens(model, params, prompts, *, mesh, quantized, fd, fp,
            max_batch=8):
    eng = ServingEngine(model, params=params, max_batch=max_batch,
                        prefill_chunk=4, quantized=quantized,
                        fused_decode=fd, fused_prefill=fp, mesh=mesh)
    hs = [eng.submit(p, max_new_tokens=5) for p in prompts]
    eng.run()
    assert eng.trace_counts == {"decode": 1, "prefill": 1}
    return [h.tokens for h in hs], eng


class TestMeshServing:
    def test_one_device_mesh_matches_plain(self, rwkv4):
        """A 1-device mesh is placement-only: same tokens as no mesh."""
        model, params = rwkv4
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, model.cfg.vocab, size=n).tolist()
                   for n in (3, 9, 1)]
        t_plain, _ = _tokens(model, params, prompts, mesh=None,
                             quantized=False, fd=False, fp=False,
                             max_batch=2)
        t_mesh, eng = _tokens(model, params, prompts,
                              mesh=_serving_mesh(1), quantized=False,
                              fd=False, fp=False, max_batch=2)
        assert t_plain == t_mesh
        assert eng.plan.mesh is not None

    @pytest.mark.skipif(not MULTI, reason="needs >= 8 devices "
                        "(XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=8)")
    @pytest.mark.parametrize("arch,quantized,fd,fp", [
        ("rwkv4-169m", False, False, False),    # per-op, fp
        ("rwkv4-169m", True, False, False),     # per-op, packed
        ("rwkv4-169m", True, "model", True),    # megakernel + chunked
        ("rwkv6-7b", False, "block", True),     # block kernel + chunked
        ("rwkv6-7b", True, False, False),       # per-op, packed
    ])
    def test_8dev_bit_identical_tokens(self, arch, quantized, fd, fp):
        """THE acceptance claim: the 8-device data-parallel engine
        streams bit-identical tokens to the 1-device engine — both archs,
        fp + Δ-PoT packed, fused and per-op paths — and the pool is
        genuinely sharded over all 8 devices."""
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, model.cfg.vocab, size=n).tolist()
                   for n in (3, 9, 17, 5, 1)]
        t1, _ = _tokens(model, params, prompts, mesh=None,
                        quantized=quantized, fd=fd, fp=fp)
        t8, eng = _tokens(model, params, prompts, mesh=_serving_mesh(8),
                          quantized=quantized, fd=fd, fp=fp)
        assert t1 == t8
        for leaf in jax.tree_util.tree_leaves(eng.pool.state):
            assert len(leaf.sharding.device_set) == 8, leaf.sharding

    @pytest.mark.skipif(not MULTI, reason="needs >= 8 devices")
    def test_non_divisible_pool_replicates_and_runs(self, rwkv4):
        """max_batch % devices != 0 falls back to replication (the
        divisibility rule) instead of erroring — and still serves."""
        model, params = rwkv4
        prompts = [[1, 2, 3], [4, 5]]
        t1, _ = _tokens(model, params, prompts, mesh=None,
                        quantized=False, fd=False, fp=False, max_batch=3)
        t8, eng = _tokens(model, params, prompts, mesh=_serving_mesh(8),
                          quantized=False, fd=False, fp=False,
                          max_batch=3)
        assert t1 == t8
        leaf = jax.tree_util.tree_leaves(eng.pool.state)[0]
        assert leaf.sharding.is_fully_replicated

    @pytest.mark.skipif(not MULTI, reason="needs >= 8 devices")
    def test_8dev_weights_replicated_pool_sharded(self, rwkv4):
        """Placement split: every prepared weight leaf is fully
        replicated (placed once at startup), while the per-tick batch and
        pool shard over "data"."""
        model, params = rwkv4
        plan = build_plan(model, params, mesh=_serving_mesh(8),
                          fused_decode="model")
        for leaf in jax.tree_util.tree_leaves(plan.prepared.decode):
            assert leaf.sharding.is_fully_replicated
        shards = jax.tree_util.tree_leaves(plan.state_shardings(8))
        assert shards and all("data" in tuple(s.spec) for s in shards)
