"""Per-architecture smoke tests (assignment requirement): reduced-config
instantiation + one forward/train step on CPU, asserting shapes and no NaNs;
plus decode-vs-forward parity for the recurrent models."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (
    ASSIGNED_ARCHS, RWKV4_ARCHS, SHAPES, get_config, smoke_config,
    supported_shapes)
from repro.models.registry import get_model, loss_fn

ALL_ARCHS = ASSIGNED_ARCHS + ["rwkv4-169m"]


def _batch(model, rng, B=2, S=16):
    cfg = model.cfg
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.enc_frames, cfg.d_model))
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = _batch(model, jax.random.PRNGKey(1))
        logits, aux = model.forward(params, batch)
        B, S = batch["tokens"].shape
        extra = model.cfg.n_patches
        assert logits.shape == (B, S + extra, model.cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(logits)))

    def test_train_step_reduces_loss(self, arch):
        """A few SGD steps on a fixed batch must reduce the loss — catches
        dead gradients anywhere in the block."""
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = _batch(model, jax.random.PRNGKey(1))

        @jax.jit
        def step(p):
            (l, m), g = jax.value_and_grad(
                lambda q: loss_fn(model, q, batch), has_aux=True)(p)
            p = jax.tree_util.tree_map(lambda a, b: a - 0.5 * b, p, g)
            return p, l

        losses = []
        for _ in range(5):
            params, l = step(params)
            losses.append(float(l))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]

    def test_decode_step_shapes(self, arch):
        model = get_model(arch, smoke=True)
        cfg = model.cfg
        params = model.init_params(jax.random.PRNGKey(0))
        B = 2
        state = model.init_decode_state(B, 32)
        tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
        logits, new_state = model.decode_step(params, state, tok,
                                              jnp.int32(0))
        assert logits.shape == (B, 1, cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(logits)))
        # state structure preserved
        assert jax.tree_util.tree_structure(new_state) == \
            jax.tree_util.tree_structure(state)


@pytest.mark.parametrize("arch", ["rwkv4-169m", "rwkv6-7b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the sequence forward pass —
    THE correctness property of the paper's O(1)-state serving mode."""
    model = get_model(arch, smoke=True)
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 1, 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits_seq, _ = model.forward(params, {"tokens": tok})
    state = model.init_decode_state(B, S)
    outs = []
    for t in range(S):
        lg, state = model.decode_step(params, state, tok[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_seq, np.float32),
        np.asarray(logits_dec, np.float32), rtol=0.06, atol=0.06)


def test_transformer_decode_matches_forward():
    """KV-cache decode parity for the attention family."""
    model = get_model("smollm-135m", smoke=True)
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 1, 8
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits_seq, _ = model.forward(params, {"tokens": tok})
    state = model.init_decode_state(B, S)
    outs = []
    for t in range(S):
        lg, state = model.decode_step(params, state, tok[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_seq, np.float32),
        np.asarray(logits_dec, np.float32), rtol=0.06, atol=0.06)


def test_rwkv4_hw_numerics_close_to_std():
    """The paper's accelerator numerics (LUT exp / PWL sigmoid / LUT div +
    A9 activations) must stay close to the fp forward — the Table-1 claim."""
    from repro.models import rwkv4 as R4
    model = get_model("rwkv4-169m", smoke=True)
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    cp = model.cast_params(params)
    l_std, _ = R4.forward(cp, {"tokens": tok}, cfg, hw=False)
    l_hw, _ = R4.forward(cp, {"tokens": tok}, cfg, hw=True)
    # logits within a few percent of each other in KL-relevant terms
    p = jax.nn.softmax(l_std.astype(jnp.float32), -1)
    q = jax.nn.log_softmax(l_hw.astype(jnp.float32), -1)
    kl = float(jnp.mean(jnp.sum(p * (jnp.log(p + 1e-9) - q), -1)))
    assert np.isfinite(kl) and kl < 0.05


def test_full_configs_match_assignment():
    """The exact published dims from the assignment table."""
    c = get_config("smollm-135m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (30, 576, 9, 3, 1536, 49152)
    c = get_config("llama4-maverick-400b-a17b")
    assert (c.d_model, c.n_experts, c.top_k, c.vocab) == \
        (5120, 128, 1, 202048)
    c = get_config("rwkv6-7b")
    assert (c.n_layers, c.d_model, c.vocab) == (32, 4096, 65536)
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = get_config("minicpm3-4b")
    assert c.use_mla and (c.n_layers, c.d_model) == (62, 2560)
    c = get_config("whisper-medium")
    assert (c.enc_layers, c.n_layers, c.d_model) == (24, 24, 1024)
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_experts, c.top_k, c.vocab) == (64, 6, 163840)
    c = get_config("minitron-4b")
    assert (c.d_model, c.vocab) == (3072, 256000)
    c = get_config("phi3-mini-3.8b")
    assert (c.n_heads, c.n_kv_heads) == (32, 32)
    c = get_config("internvl2-2b")
    assert (c.d_model, c.n_kv_heads, c.vocab) == (2048, 8, 92553)


def test_shape_skips_documented():
    """long_500k is runnable exactly for the sub-quadratic families."""
    for arch in ALL_ARCHS:
        cfg = get_config(arch) if arch != "rwkv4-169m" else \
            get_config("rwkv4-169m")
        sup = supported_shapes(cfg)["long_500k"]
        if cfg.family in ("ssm", "hybrid", "rwkv"):
            assert sup == "ok"
        else:
            assert sup.startswith("skip")
