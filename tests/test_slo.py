"""SLO-layer tests (repro.serving.slo): priority/aging/cache-aware
admission, deadlines under a fake clock, the per-tick prefill budget
(bit parity + traced-once), typed Overloaded backpressure, load
shedding, overload x cancellation interplay, the run() hang watchdog,
and the percentile telemetry in ServingCounters.

Scheduler-level tests drive a FakePool + stub decode/prefill functions
(no device work, so admission order and tick counts are exact);
engine-level tests share one real rwkv4 ExecutionPlan."""
import jax
import numpy as np
import pytest

from repro.models.registry import get_model
from repro.runtime.monitor import ServingCounters, percentile
from repro.serving import (AdmissionPolicy, Overloaded, PrefixCache,
                           PrefixCacheConfig, Request, Scheduler,
                           SchedulerHang, ServingEngine, ServingSLO,
                           build_plan)
from repro.serving.prefix_cache import CacheVariant
from repro.serving.scheduler import DECODE


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


class FakePool:
    """Slot bookkeeping without device state — the scheduler only needs
    acquire/release/write/read/sync, so SLO tests can skip tracing."""

    state = None

    def __init__(self, n: int):
        self.max_slots = n
        self._free = list(range(n - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self):
        return self._free.pop() if self._free else None

    def release(self, slot: int):
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        self._free.append(slot)

    def write_slot(self, slot, state):
        pass

    def read_slot(self, slot):
        return np.zeros(1)

    def sync(self):
        pass


def _stub_fns(n_slots: int, vocab: int = 5):
    def prefill_fn(state, toks, valid, fresh):
        return state, np.zeros((n_slots, 1, vocab), np.float32)

    def decode_fn(state, toks, mask):
        return np.zeros((n_slots, 1, vocab), np.float32), state

    return decode_fn, prefill_fn


def _sched(n_slots=1, *, chunk=4, slo=None, counters=None,
           prefix_cache=None, cache_variant=None, finishes=None):
    pool = FakePool(n_slots)
    decode_fn, prefill_fn = _stub_fns(n_slots)
    on_finish = None
    if finishes is not None:
        on_finish = lambda req, outcome: finishes.append((req.rid, outcome))
    return Scheduler(pool, decode_fn, prefill_fn, prefill_chunk=chunk,
                     counters=counters, on_finish=on_finish,
                     prefix_cache=prefix_cache, cache_variant=cache_variant,
                     slo=slo)


def _req(rid, *, prompt=None, pri=0, mnt=1, deadline=None):
    return Request(rid=rid, prompt=prompt if prompt is not None else [1],
                   max_new_tokens=mnt, priority=pri, deadline_s=deadline)


@pytest.fixture(scope="module")
def rwkv4():
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def plan4(rwkv4):
    model, params = rwkv4
    return build_plan(model, params, prefill_chunk=4)


class TestConfigValidation:
    def test_admission_policy_rejects_bad_values(self):
        with pytest.raises(ValueError):
            AdmissionPolicy(overload="drop")
        with pytest.raises(ValueError):
            AdmissionPolicy(max_queue=-1)
        with pytest.raises(ValueError):
            AdmissionPolicy(aging_ticks=-1)

    def test_slo_rejects_bad_values(self):
        with pytest.raises(ValueError):
            ServingSLO(prefill_budget=-1)
        with pytest.raises(ValueError):
            ServingSLO(default_deadline_s=0.0)
        with pytest.raises(ValueError):
            ServingSLO(max_idle_ticks=-1)


class TestAdmissionOrder:
    def test_default_slo_is_fifo(self):
        """Equal priorities, no cache: the historical admission order."""
        fin = []
        sched = _sched(1, finishes=fin)
        for rid in (0, 1, 2):
            sched.enqueue(_req(rid))
        sched.run()
        assert fin == [(0, "finished"), (1, "finished"), (2, "finished")]

    def test_priority_classes_order_admission(self):
        """One slot, one admission per tick: highest class goes first,
        ties FIFO."""
        fin = []
        sched = _sched(1, finishes=fin)
        sched.enqueue(_req(0, pri=0))
        sched.enqueue(_req(1, pri=2))
        sched.enqueue(_req(2, pri=1))
        sched.run()
        assert fin == [(1, "finished"), (2, "finished"), (0, "finished")]

    def test_aging_beats_a_sustained_high_priority_stream(self):
        """A background request under a constant stream of higher-priority
        arrivals is admitted once its aging bonus levels the classes."""
        fin = []
        slo = ServingSLO(admission=AdmissionPolicy(aging_ticks=3))
        sched = _sched(1, slo=slo, finishes=fin)
        sched.enqueue(_req(0, pri=0))
        for t in range(1, 9):
            sched.enqueue(_req(100 + t, pri=1))
            sched.tick()
            if (0, "finished") in fin:
                break
        assert (0, "finished") in fin and t <= 4

    def test_no_aging_means_starvation(self):
        """The control: aging_ticks=0 disables the bonus and the same
        stream starves the background request indefinitely."""
        fin = []
        slo = ServingSLO(admission=AdmissionPolicy(aging_ticks=0))
        sched = _sched(1, slo=slo, finishes=fin)
        sched.enqueue(_req(0, pri=0))
        for t in range(1, 9):
            sched.enqueue(_req(100 + t, pri=1))
            sched.tick()
        assert (0, "finished") not in fin
        assert any(r.rid == 0 for r in sched.queue)

    def test_cache_hit_breaks_priority_ties(self):
        """Same class, one cached prefix: the cache-hit request is
        admitted first even though it was enqueued second."""
        C = 4
        cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=4,
                                                        host_slots=4))
        var = CacheVariant(arch="stub", quant="fp", numerics="exact",
                           prefill="per_op")
        hitp = [1, 2, 3, 4, 9]
        cache.insert(var, hitp, 4, np.zeros(2), cache.digests(hitp))
        fin = []
        c = ServingCounters()
        sched = _sched(1, chunk=C, prefix_cache=cache, cache_variant=var,
                       counters=c, finishes=fin)
        sched.enqueue(_req(0, prompt=[7, 8, 9]))
        sched.enqueue(_req(1, prompt=list(hitp)))
        sched.tick()
        assert fin[0] == (1, "finished")
        assert c.cache_hits == 1 and c.cached_tokens == 4
        assert [r.rid for r in sched.queue] == [0]

    def test_hit_length_peek_is_side_effect_free(self):
        """Admission peeks must not move LRU order or count as probes."""
        C = 4
        cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=4,
                                                        host_slots=4))
        var = CacheVariant(arch="stub", quant="fp", numerics="exact",
                           prefill="per_op")
        hitp = [1, 2, 3, 4, 9]
        cache.insert(var, hitp, 4, np.zeros(2), cache.digests(hitp))
        before = (list(cache._device), cache.snapshot())
        assert cache.hit_length(var, hitp) == 4
        assert cache.hit_length(var, [1, 2, 3, 4]) == 0   # proper prefixes
        assert cache.hit_length(var, [5, 6, 7, 8, 9]) == 0
        assert (list(cache._device), cache.snapshot()) == before


class TestOverload:
    def test_backpressure_is_typed_with_hints(self):
        c = ServingCounters()
        slo = ServingSLO(admission=AdmissionPolicy(max_queue=2))
        sched = _sched(1, slo=slo, counters=c)
        sched.enqueue(_req(0))
        sched.enqueue(_req(1))
        with pytest.raises(Overloaded) as ei:
            sched.enqueue(_req(2))
        e = ei.value
        assert e.queue_depth == 2 and e.max_queue == 2
        assert e.retry_after_s == 0.0     # no completion: no estimate
        assert c.backpressured == 1
        assert len(sched.queue) == 2      # the refused request left no trace

    def test_retry_after_scales_with_queue_and_service_time(self):
        clk = FakeClock()
        c = ServingCounters(clock=clk)
        slo = ServingSLO(admission=AdmissionPolicy(max_queue=1))
        sched = _sched(1, slo=slo, counters=c)
        sched.enqueue(_req(0))
        clk.t = 2.0
        sched.tick()                      # rid 0 completes: latency 2.0s
        sched.enqueue(_req(1, mnt=50))
        sched.tick()                      # rid 1 in flight, queue empty
        sched.enqueue(_req(2))            # queue full again
        with pytest.raises(Overloaded) as ei:
            sched.enqueue(_req(3))
        # mean latency (2.0) x (queue_depth+1) / max_slots = 4.0
        assert ei.value.retry_after_s == pytest.approx(4.0)

    def test_shed_drops_strictly_less_urgent_only(self):
        fin = []
        c = ServingCounters()
        slo = ServingSLO(admission=AdmissionPolicy(max_queue=2,
                                                   overload="shed"))
        sched = _sched(1, slo=slo, counters=c, finishes=fin)
        sched.enqueue(_req(0, pri=0))
        sched.enqueue(_req(1, pri=1))
        sched.enqueue(_req(2, pri=1))     # sheds rid 0 (eff 0 < 1)
        assert fin == [(0, "shed")]
        assert [r.rid for r in sched.queue] == [1, 2]
        with pytest.raises(Overloaded):   # equal classes stay FIFO-fair
            sched.enqueue(_req(3, pri=1))
        assert c.shed == 1 and c.backpressured == 1


class TestDeadlines:
    def test_queued_deadline_expires(self):
        clk = FakeClock()
        c = ServingCounters(clock=clk)
        fin = []
        sched = _sched(1, counters=c, finishes=fin)
        sched.enqueue(_req(0, mnt=50))    # hogs the only slot
        sched.tick()
        sched.enqueue(_req(1, deadline=5.0))
        clk.t += 10.0
        sched.tick()
        assert (1, "deadline") in fin
        assert c.deadline_evicted == 1
        assert not sched.queue and 1 not in sched._queued
        sched.evict(0)

    def test_inflight_deadline_frees_the_slot(self):
        clk = FakeClock()
        c = ServingCounters(clock=clk)
        fin = []
        sched = _sched(1, counters=c, finishes=fin)
        sched.enqueue(_req(0, prompt=[1] * 8, mnt=50, deadline=5.0))
        sched.tick()                      # admitted, mid-prefill
        clk.t += 10.0
        sched.tick()
        assert fin == [(0, "deadline")]
        assert sched.pool.n_free == 1 and not sched.slots

    def test_default_deadline_applies_when_request_sets_none(self):
        clk = FakeClock()
        c = ServingCounters(clock=clk)
        fin = []
        sched = _sched(1, slo=ServingSLO(default_deadline_s=5.0),
                       counters=c, finishes=fin)
        sched.enqueue(_req(0, prompt=[1] * 8, mnt=50))
        sched.tick()
        clk.t += 10.0
        sched.tick()
        assert fin == [(0, "deadline")] and c.deadline_evicted == 1


class TestPrefillBudget:
    def test_quota_derived_from_budget(self):
        sched = _sched(4, slo=ServingSLO(prefill_budget=4))
        assert sched._prefill_quota == 1
        assert _sched(4, slo=ServingSLO(prefill_budget=11))._prefill_quota \
            == 2
        # floor of one lane: a tiny budget can never wedge prefill
        assert _sched(4, slo=ServingSLO(prefill_budget=1))._prefill_quota \
            == 1
        assert _sched(4)._prefill_quota is None

    def test_budget_binds_only_while_decoding(self):
        c = ServingCounters()
        sched = _sched(4, slo=ServingSLO(prefill_budget=4), counters=c)
        for rid in (0, 1, 2):
            sched.enqueue(_req(rid, prompt=[1] * 8, mnt=1))
        sched.tick()                      # no decode lane: unthrottled
        assert c.budget_deferred_tokens == 0
        assert all(m.n_prefilled == 4 for m in sched.slots.values())
        sched.run()

    def test_budget_defers_lowest_priority_lanes(self):
        c = ServingCounters()
        sched = _sched(4, slo=ServingSLO(prefill_budget=4), counters=c)
        sched.enqueue(_req(0, mnt=50))    # prompt [1]: decoding from tick 1
        sched.tick()
        assert any(m.phase == DECODE for m in sched.slots.values())
        sched.enqueue(_req(1, prompt=[1] * 8))
        sched.enqueue(_req(2, prompt=[1] * 8))
        sched.enqueue(_req(3, prompt=[1] * 8, pri=1))
        sched.tick()
        by_rid = {m.req.rid: m for m in sched.slots.values()}
        # one lane per tick, highest priority first; the rest deferred
        assert by_rid[3].n_prefilled == 4
        assert by_rid[1].n_prefilled == by_rid[2].n_prefilled == 0
        assert c.budget_deferred_tokens == 8
        sched.tick()
        assert by_rid[3].n_prefilled == 8   # same lane finishes first
        sched.evict(0)
        sched.run()
        assert sched.pool.n_free == 4

    def test_plan_prefill_quota_is_bucket_aware(self, plan4):
        # chunk=4: whole chunks per lane, clamped to the batch bucket,
        # floor of one lane; 0 = unlimited (the whole bucket)
        assert plan4.prefill_quota(0, 8) == 8
        assert plan4.prefill_quota(4, 3) == 1
        assert plan4.prefill_quota(11, 3) == 2
        assert plan4.prefill_quota(100, 3) == 3
        assert plan4.prefill_quota(1, 3) == 1

    def test_budget_bit_parity_and_traced_once(self, rwkv4):
        """The budget changes WHEN lanes prefill, never what they compute:
        token streams are bit-identical to the unlimited engine and the
        program cache still holds exactly two traces."""
        model, params = rwkv4
        V = model.cfg.vocab
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, V, size=n).tolist()
                   for n in (9, 17, 4, 12, 6)]

        def run(slo):
            eng = ServingEngine(model, params=params, max_batch=3,
                                prefill_chunk=4, slo=slo)
            hs = [eng.submit(p, max_new_tokens=5, temperature=0.7, seed=i)
                  for i, p in enumerate(prompts)]
            eng.run()
            assert eng.trace_counts == {"decode": 1, "prefill": 1}
            return [h.tokens for h in hs], eng

        base, _ = run(ServingSLO())
        budgeted, eng = run(ServingSLO(prefill_budget=4))
        assert budgeted == base
        assert eng.scheduler._prefill_quota == 1
        assert eng.counters.budget_deferred_tokens > 0


class TestHangGuard:
    def test_leaked_slot_raises_diagnosable_hang(self):
        sched = _sched(1)
        sched.pool.acquire()              # leak the only slot
        sched.enqueue(_req(0))
        with pytest.raises(SchedulerHang) as ei:
            sched.run(max_idle_ticks=5)
        e = ei.value
        assert (e.idle_ticks, e.queued, e.active, e.n_free) == (5, 1, 0, 0)
        assert e.phases == {} and "no progress" in str(e)

    def test_slo_default_limit_is_used(self):
        sched = _sched(1, slo=ServingSLO(max_idle_ticks=3))
        sched.pool.acquire()
        sched.enqueue(_req(0))
        with pytest.raises(SchedulerHang) as ei:
            sched.run()
        assert ei.value.idle_ticks == 3

    def test_any_progress_resets_the_watchdog(self):
        """A healthy run never trips even the tightest limit — every
        tick with work makes progress."""
        fin = []
        sched = _sched(2, finishes=fin)
        for rid in range(4):
            sched.enqueue(_req(rid, prompt=[1] * 8, mnt=2))
        sched.run(max_idle_ticks=1)
        assert len(fin) == 4 and sched.pool.n_free == 2


class TestEngineOverloadInterplay:
    def test_backpressured_submit_leaves_no_handle(self, rwkv4, plan4):
        model, _ = rwkv4
        slo = ServingSLO(admission=AdmissionPolicy(max_queue=1))
        eng = ServingEngine(model, plan=plan4, max_batch=2, slo=slo)
        h1 = eng.submit([1, 2, 3], max_new_tokens=2)
        with pytest.raises(Overloaded) as ei:
            eng.submit([4, 5], max_new_tokens=2)
        assert ei.value.queue_depth == 1 and ei.value.max_queue == 1
        assert set(eng._handles) == {h1.rid}
        eng.run()
        assert h1.outcome == "finished" and len(h1.tokens) == 2
        assert eng.counters.snapshot()["backpressured"] == 1

    def test_shed_is_observable_and_cancel_after_shed_is_graceful(
            self, rwkv4, plan4):
        model, _ = rwkv4
        slo = ServingSLO(admission=AdmissionPolicy(max_queue=1,
                                                   overload="shed"))
        eng = ServingEngine(model, plan=plan4, max_batch=2, slo=slo)
        h1 = eng.submit([1, 2, 3], max_new_tokens=2)
        h2 = eng.submit([4, 5, 6], max_new_tokens=2, priority=1)
        assert h1.done and h1.outcome == "shed" and h1.tokens == []
        assert eng.cancel(h1) is False    # already gone, no crash
        eng.run()
        assert h2.outcome == "finished" and len(h2.tokens) == 2
        assert eng.counters.snapshot()["shed"] == 1

    def test_cancel_while_queued(self, rwkv4, plan4):
        model, _ = rwkv4
        eng = ServingEngine(model, plan=plan4, max_batch=2)
        h1 = eng.submit([1, 2, 3], max_new_tokens=3)
        h2 = eng.submit([4, 5], max_new_tokens=3)
        h3 = eng.submit([6, 7, 8], max_new_tokens=3)
        eng.step()                        # h1/h2 in flight, h3 queued
        assert eng.cancel(h3) is True
        assert h3.done and h3.outcome == "cancelled" and h3.tokens == []
        eng.run()
        assert h1.outcome == h2.outcome == "finished"
        assert eng.pool.n_free == 2

    def test_deadline_evicts_a_cache_resumed_lane(self, rwkv4, plan4):
        """A lane resumed from a prefix-cache hit that then exceeds its
        deadline must release slot AND cache cleanly: no leaked lease,
        cache invariants intact, no pending-insert pollution."""
        model, _ = rwkv4
        clk = FakeClock()
        cache = PrefixCache(4, config=PrefixCacheConfig(device_slots=8,
                                                        host_slots=8))
        eng = ServingEngine(model, plan=plan4, max_batch=2,
                            prefix_cache=cache,
                            counters=ServingCounters(clock=clk))
        base = [1, 2, 3, 4, 5, 6, 7, 8]
        h0 = eng.submit(base, max_new_tokens=3)
        eng.run()
        assert h0.outcome == "finished" and cache.n_device > 0
        n_inserts = eng.counters.cache_inserts
        h = eng.submit(base + [9, 10], max_new_tokens=30, deadline_s=5.0)
        eng.step()                        # admitted via cache-hit restore
        assert eng.counters.cache_hits == 1
        clk.t += 10.0
        eng.step()
        assert h.done and h.outcome == "deadline"
        eng.run()
        assert eng.pool.n_free == 2
        cache.check_state()
        assert all(e.refcount == 0 for e in
                   list(cache._device.values()) + list(cache._host.values()))
        snap = eng.counters.snapshot()
        assert snap["deadline_evicted"] == 1
        # an evicted lane publishes nothing
        assert eng.counters.cache_inserts == n_inserts


class TestTelemetry:
    def test_percentile_nearest_rank(self):
        xs = list(range(1, 101))
        assert percentile(xs, 0.50) == 50
        assert percentile(xs, 0.90) == 90
        assert percentile(xs, 0.99) == 99
        assert percentile(xs, 1.00) == 100
        assert percentile([], 0.99) == 0.0
        assert percentile([7.0], 0.5) == 7.0
        assert percentile([3, 1, 2], 0.5) == 2   # sorts, rank 2 of 3

    def test_ttft_itl_percentiles_under_fake_clock(self):
        clk = FakeClock()
        c = ServingCounters(clock=clk)
        c.on_enqueue(0)
        clk.t = 1.0
        c.on_token(0, first=True)         # TTFT 1.0s
        clk.t = 2.0
        c.on_token(0)                     # ITL 1.0s
        clk.t = 4.0
        c.on_token(0)                     # ITL 2.0s
        c.on_finish(0)
        snap = c.snapshot()
        assert snap["ttft_p99_s"] == 1.0
        assert snap["itl_p50_s"] == 1.0 and snap["itl_p99_s"] == 2.0
        assert snap["mean_itl_s"] == pytest.approx(1.5)
        assert snap["latency_p99_s"] == 4.0
        assert not c._last_token_t        # finish cleans per-rid state

    def test_outcome_counters_surface_in_snapshot(self):
        c = ServingCounters()
        c.on_enqueue(3)
        c.on_shed(3)
        c.on_deadline_evict(2)
        c.on_backpressure()
        c.on_cache_error()
        c.on_budget_defer(8)
        snap = c.snapshot()
        assert snap["shed"] == 1
        assert snap["deadline_evicted"] == 1
        assert snap["backpressured"] == 1
        assert snap["cache_errors"] == 1
        assert snap["budget_deferred_tokens"] == 8
        # shed dropped rid 3's tracking: no stale latency state
        assert 3 not in c._enqueue_t and 3 not in c._last_token_t

    def test_occupancy_means(self):
        c = ServingCounters()
        c.on_tick(active=2, queued=4)
        c.on_tick(active=4, queued=0)
        snap = c.snapshot()
        assert snap["mean_active_slots"] == 3.0
        assert snap["mean_queue_depth"] == 2.0
        assert snap["peak_active_slots"] == 4
        assert snap["peak_queue_depth"] == 4
