"""Self-speculative decoding: the provably-lossless acceptance oracle.

The contract under test (docs/serving.md §speculative decode): with
`ServingEngine(speculative=K)` each decode tick becomes draft -> verify
-> accept — a truncated-stack drafter proposes K-1 tokens per lane, ONE
chunk-shaped verify call (the PR 4 prefill machinery with an
all-position head) scores the pending token plus every draft, and the
scheduler accepts the longest verifier-agreed prefix, rolling rejected
lanes back through `masked_state_commit`.  Every emitted token is
sampled from VERIFIER logits and both sides compile under `exact_jit`,
so the token stream is BIT-IDENTICAL to the non-speculative engine no
matter what the drafter proposes — greedy acceptance is lossless by
construction, and the drafter only moves the acceptance rate.

The suite proves that claim in layers, mirroring tests/test_prefill.py:

  * VERIFIER ORACLE — `prefill_chunk_logits` (all-position head) row j
    bit-equals the logits a masked scan of `decode_step` produces after
    consuming tokens[:, :j+1]: fp + packed Δ-PoT x rwkv4/rwkv6, plus the
    paper's hw-LUT numerics (the engine itself always runs exact
    numerics — the LUT leg pins the kernel composition).
  * ROLLBACK ORACLE — post-rollback state bit-equals the pre-verify
    snapshot, and re-advancing by the accepted prefix bit-equals a lane
    that never speculated.
  * ACCEPTANCE RULE — `greedy_accept` examples + a hypothesis property
    (optional dep, conftest stubs): the accepted draft prefix IS the
    verifier argmax prefix.
  * ENGINE STREAMS — bitwise token-stream equivalence vs the plain
    engine across archs x quantization x K in {1, 2, 4}, per-op and
    chunked verify, forced all-accept / all-reject / ragged-per-lane
    acceptance (deterministic stub drafters driven by the baseline
    stream), seeded temperature sampling (per-slot RNG streams advance
    by ACCEPTED tokens only), and resume from a prefix-cache hit.
  * LIFECYCLE — mid-speculation eviction (own lane and another lane's
    callback) never leaks a snapshot or a draft, and a 300-step
    submit/cancel churn holds the scheduler + prefix-cache invariants
    with speculative lanes every step.
"""
import functools

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: property tests importorskip at run time
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from test_prefill import _assert_bitwise, _prefix_valid, _random_state

from repro.core.quant.serving import pack_params, unpack_params
from repro.kernels.common import exact_jit
from repro.models.registry import get_model
from repro.serving import ServingEngine
from repro.serving.plan import build_plan
from repro.serving.scheduler import DECODE, Scheduler, greedy_accept

ARCHS = ["rwkv4-169m", "rwkv6-7b"]
B, K = 4, 4
# per-lane window prefixes: full window, partial, empty (free lane), base-only
WINDOW_LENS = (K, 2, 0, 1)
PROMPT_LENS = (1, 5, 9)
MAX_NEW = 10


# ---------------------------------------------------------------------------
# The verifier oracle: all-position logits == stepwise decode prefixes
# ---------------------------------------------------------------------------


def oracle_verify(model, params, state, tokens, valid, *,
                  quantized=False, hw=False):
    """The verify program's per-op semantics: scan `decode_step` over the
    window, committing state only where `valid`, collecting EVERY
    position's logits row (zeros where invalid) — through the SAME
    `masked_state_commit` / `maybe_unpack` the plan programs use.  Row j
    is, by construction, exactly what the plain decode tick would emit
    after consuming tokens[:, :j+1] — the losslessness anchor."""
    from repro.serving.plan import masked_state_commit, maybe_unpack
    axes = model.decode_state_batch_axes()
    p = maybe_unpack(params, quantized)
    if hw:
        step = lambda pp, s, t: model.module.decode_step(
            pp, s, t, jnp.int32(0), model.cfg, hw=True)
    else:
        step = lambda pp, s, t: model.decode_step(pp, s, t, jnp.int32(0))

    def body(st, xs):
        tok, ok = xs
        logits, stepped = step(p, st, tok[:, None])
        st = masked_state_commit(stepped, st, ok, axes)
        row = jnp.where(ok[:, None], logits[:, 0], jnp.zeros_like(logits[:, 0]))
        return st, row

    st, rows = jax.lax.scan(body, state, (tokens.T, valid.T))
    return st, jnp.swapaxes(rows, 0, 1)            # (B, K, V)


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("arch", ARCHS)
def test_verify_all_position_parity(arch, quantized, rng):
    """THE verifier claim: the chunked all-position head
    (`prefill_chunk_logits`) bit-equals the masked scan of decode_step at
    EVERY window position — states and all K logits rows — over full,
    partial, empty and base-only validity prefixes."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    if quantized:
        params = pack_params(params)
    state = _random_state(model, rng)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, K)), jnp.int32)
    valid = _prefix_valid(WINDOW_LENS, cols=K)
    s1, r1 = exact_jit(lambda p, s: oracle_verify(
        model, p, s, tokens, valid, quantized=quantized))(params, state)
    prep = model.prepare_prefill_params(params) if quantized else params
    s2, r2 = exact_jit(lambda p, s: model.prefill_chunk_logits(
        p, s, tokens, valid))(prep, state)
    _assert_bitwise(s1, s2)
    _assert_bitwise(r1, r2)


def test_verify_hw_numerics_parity(rng):
    """The paper's LUT/PWL numerics compose with the all-position verify
    head: same bits as scanning decode_step(hw=True).  (The serving
    engine always runs exact numerics — this pins the kernel
    composition for callers driving the hw variant directly.)"""
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    state = _random_state(model, rng)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, K)), jnp.int32)
    valid = _prefix_valid(WINDOW_LENS, cols=K)
    s1, r1 = exact_jit(lambda p, s: oracle_verify(
        model, p, s, tokens, valid, hw=True))(params, state)
    s2, r2 = exact_jit(lambda p, s: rwkv4.prefill_chunk(
        p, s, tokens, valid, jnp.int32(0), model.cfg, hw=True,
        all_logits=True))(params, state)
    _assert_bitwise(s1, s2)
    _assert_bitwise(r1, r2)


# ---------------------------------------------------------------------------
# Truncated-stack drafter: params / state slicing semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_truncate_state_slices_layer_axis(arch, rng):
    """`truncate_state` takes the first `depth` layer slices of every
    decode-state leaf (layer l's transition depends only on layers below,
    so the slice IS the truncated model's state)."""
    model = get_model(arch, smoke=True)
    state = _random_state(model, rng)
    axes = model.decode_state_layer_axes()
    tstate = model.truncate_state(state, 1)
    full = jax.tree_util.tree_leaves(state)
    cut = jax.tree_util.tree_leaves(tstate)
    assert len(full) == len(cut) == len(axes)
    for f, c, ax in zip(full, cut, axes):
        np.testing.assert_array_equal(
            np.asarray(np.take(np.asarray(f, np.float32), [0], axis=ax)),
            np.asarray(c, np.float32))
    # the truncated model accepts the sliced state (shape contract)
    assert jax.tree_util.tree_structure(tstate) == \
        jax.tree_util.tree_structure(model.truncated(1).init_decode_state(
            B, 0, jnp.bfloat16))


@pytest.mark.parametrize("arch", ARCHS)
def test_truncate_params_aliases_and_packed_commutes(arch):
    """Drafter weights share every non-block leaf with the full model (no
    copies), and truncation COMMUTES with Δ-PoT unpack — the scale planes
    carry a broadcast layer axis, so slicing packed trees is exact."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    tp = model.truncate_params(params, 1)
    assert all(tp[k] is params[k] for k in params if k != "blocks")
    packed = pack_params(params)
    _assert_bitwise(unpack_params(model.truncate_params(packed, 1)),
                    model.truncate_params(unpack_params(packed), 1))


def test_truncated_depth_validation():
    model = get_model("rwkv4-169m", smoke=True)
    for bad in (0, model.cfg.n_layers + 1, -1):
        with pytest.raises(ValueError, match="depth"):
            model.truncated(bad)


def test_draft_paths_capability():
    for arch in ARCHS:
        assert "truncated" in get_model(arch, smoke=True).draft_paths()


# ---------------------------------------------------------------------------
# Plan programs: draft chain oracle + rollback/readvance bit-parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_draft_fn_matches_truncated_greedy_chain(arch, rng):
    """The plan's one-call drafter (a lax.scan with greedy feedback over
    the truncated stack, state sliced in-trace) proposes exactly the
    tokens a stepwise truncated-model argmax chain would."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    plan = build_plan(model, params, speculative=K, draft_depth=1)
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, 1)), jnp.int32)
    got = np.asarray(plan.draft_fn(B)(state, toks))
    assert got.shape == (B, K - 1) and got.dtype == np.int32
    # stepwise oracle: same per-op step under the same exact_jit semantics
    dmodel = model.truncated(1)
    dparams = model.truncate_params(params, 1)
    dstate = model.truncate_state(state, 1)
    step = exact_jit(dmodel.decode_step)
    tok, want = toks, []
    for _ in range(K - 1):
        logits, dstate = step(dparams, dstate, tok, jnp.int32(0))
        nxt = np.argmax(np.asarray(logits[:, 0], np.float32), axis=-1)
        tok = jnp.asarray(nxt[:, None].astype(np.int32))
        want.append(nxt)
    np.testing.assert_array_equal(got, np.stack(want, axis=1))
    # deterministic: same inputs, same drafts
    np.testing.assert_array_equal(got, np.asarray(plan.draft_fn(B)(state, toks)))


@pytest.mark.parametrize("arch", ARCHS)
def test_rollback_restores_snapshot_then_readvance_is_unspeculated(arch, rng):
    """The rollback theorem, at the plan level: after a full-window verify
    commit, `rollback_fn` returns the pre-verify snapshot BIT-EXACTLY for
    rejected lanes, and re-advancing by each lane's accepted prefix
    through the verify program bit-equals a lane that NEVER speculated
    (the masked scan oracle over just that prefix)."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    plan = build_plan(model, params, speculative=K, fused_prefill=True)
    vfn, rfn = plan.verify_fn(B), plan.rollback_fn(B)
    snapshot = _random_state(model, rng)
    window = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, K)), jnp.int32)
    _, committed = vfn(snapshot, window, np.ones((B, K), bool))
    rolled = rfn(committed, snapshot, np.ones((B,), bool))   # donates committed
    _assert_bitwise(rolled, snapshot)
    # ragged accepted prefixes (incl. 0 = lane untouched by readvance)
    prefix = _prefix_valid(WINDOW_LENS, cols=K)
    _, readvanced = vfn(rolled, window, prefix)
    want, _ = exact_jit(lambda p, s: oracle_verify(
        model, p, s, window, prefix))(params, snapshot)
    _assert_bitwise(readvanced, want)


# ---------------------------------------------------------------------------
# The acceptance rule
# ---------------------------------------------------------------------------


def test_greedy_accept_examples():
    # all-accept: every verifier choice confirms the next draft
    assert greedy_accept([5, 7, 9], [7, 9, 2]) == ([7, 9, 2], 3)
    # all-reject: the first choice already disagrees
    assert greedy_accept([5, 7, 9], [1, 9, 2]) == ([1], 1)
    # partial: one draft confirmed, then divergence
    assert greedy_accept([5, 7, 9], [7, 4, 2]) == ([7, 4], 2)
    # K=1: the degenerate verify-only window always consumes its base
    assert greedy_accept([5], [3]) == ([3], 1)


@settings(max_examples=200, deadline=None)
@given(st.data())
def test_greedy_accept_prefix_property(data):
    """PROPERTY (satellite 2): the accepted draft prefix IS the verifier
    argmax prefix — emitted == argmax_rows[:consumed], the confirmed
    drafts window[1:consumed] == argmax_rows[:consumed-1], and the walk
    stops exactly at the first disagreement (or the window end)."""
    pytest.importorskip("hypothesis")
    k = data.draw(st.integers(min_value=1, max_value=6))
    window = data.draw(st.lists(st.integers(0, 9), min_size=k, max_size=k))
    argmax = data.draw(st.lists(st.integers(0, 9), min_size=k, max_size=k))
    emitted, consumed = greedy_accept(window, argmax)
    assert 1 <= consumed <= k
    assert emitted == argmax[:consumed]
    assert window[1:consumed] == argmax[:consumed - 1]
    if consumed < k:
        assert argmax[consumed - 1] != window[consumed]


# ---------------------------------------------------------------------------
# Engine-level stream equivalence
# ---------------------------------------------------------------------------


def _prompts(model, seed=7, lens=PROMPT_LENS):
    r = np.random.default_rng(seed)
    return [r.integers(0, model.cfg.vocab, size=n).tolist() for n in lens]


def _serve(model, params, prompts, *, max_new=MAX_NEW, temperature=0.0,
           speculative=None, draft_depth=None, fused_prefill=True,
           quantized=False, max_batch=3, prefix_cache=None):
    eng = ServingEngine(model, params=params, max_batch=max_batch,
                        prefill_chunk=4, fused_prefill=fused_prefill,
                        quantized=quantized, speculative=speculative,
                        draft_depth=draft_depth, prefix_cache=prefix_cache)
    handles = [eng.submit(p, max_new_tokens=max_new,
                          temperature=temperature, seed=11 + i)
               for i, p in enumerate(prompts)]
    snap = eng.run()
    return eng, [h.tokens for h in handles], snap


@functools.lru_cache(maxsize=None)
def _baseline(arch, quantized):
    """Plain-engine greedy streams per (arch, quant) — computed once for
    the whole equivalence matrix."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    eng, toks, _ = _serve(model, params, _prompts(model), quantized=quantized)
    assert eng.trace_counts == {"decode": 1, "prefill": 1}   # shape guard
    return toks


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("arch", ARCHS)
def test_spec_stream_bit_equivalence(arch, quantized, k):
    """THE tentpole claim, end to end: the speculative engine streams the
    EXACT token sequences of the plain engine — rwkv4 + rwkv6, fp +
    packed Δ-PoT, K in {1 (verify-only), 2, 4} — with the real
    truncated-stack drafter, and the speculative tick never executes the
    plain decode program."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    eng, toks, snap = _serve(model, params, _prompts(model),
                             speculative=k, quantized=quantized)
    assert toks == _baseline(arch, quantized)
    want = {"decode": 0, "prefill": 1, "verify": 1,
            "rollback": 1 if k > 1 else 0}
    if k > 1:
        want["draft"] = 1
        assert snap["drafted_tokens"] > 0
    assert eng.trace_counts == want
    assert snap["drafted_tokens"] == \
        snap["accepted_tokens"] + snap["rejected_tokens"]


def test_spec_per_op_verify_equivalence():
    """The verify program's per-op fallback (fused_prefill=False: a masked
    scan of decode_step) streams the same bits as the chunked verifier."""
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    _, toks, _ = _serve(model, params, _prompts(model), speculative=2,
                        fused_prefill=False)
    assert toks == _baseline("rwkv4-169m", False)


# ---------------------------------------------------------------------------
# Deterministic stub drafters (driven by the baseline stream)
# ---------------------------------------------------------------------------


def _install_stub_draft(eng, streams, kind):
    """Replace the engine's drafter with a deterministic stub that knows
    each lane's true continuation (the baseline stream, keyed by rid ==
    submission order):

      "accept" — drafts exactly the continuation -> the verifier confirms
                 every draft (in-vocab by construction; no out-of-range
                 tokens, whose embeds gather NaN under jnp's OOB fill)
      "reject" — drafts (next_true_token + 1) % vocab -> the verifier's
                 first choice always disagrees
      "ragged" — even slots accept, odd slots reject, in the SAME tick
    """
    S, km1 = eng.pool.max_slots, eng.speculative - 1
    V = eng.model.cfg.vocab

    def draft(state, toks):
        out = np.zeros((S, km1), np.int32)
        for slot, meta in eng.scheduler.slots.items():
            if meta.phase != DECODE:
                continue
            s, g = streams[meta.req.rid], len(meta.generated)
            accept = kind == "accept" or (kind == "ragged" and slot % 2 == 0)
            if accept:
                out[slot] = [s[min(g + i, len(s) - 1)] for i in range(km1)]
            else:
                out[slot] = (s[min(g, len(s) - 1)] + 1) % V
        return out

    eng.scheduler.draft_fn = draft
    return eng


def _serve_stubbed(model, params, prompts, kind, streams, *, k=3,
                   max_new, temperature=0.0):
    eng = ServingEngine(model, params=params, max_batch=3, prefill_chunk=4,
                        fused_prefill=True, speculative=k)
    _install_stub_draft(eng, streams, kind)
    handles = [eng.submit(p, max_new_tokens=max_new,
                          temperature=temperature, seed=11 + i)
               for i, p in enumerate(prompts)]
    snap = eng.run()
    return eng, [h.tokens for h in handles], snap


@pytest.fixture(scope="module")
def rwkv4():
    model = get_model("rwkv4-169m", smoke=True)
    return model, model.init_params(jax.random.PRNGKey(0))


def test_spec_all_accept_stub(rwkv4):
    """Forced all-accept: a drafter that proposes the true continuation is
    confirmed in full — acceptance_rate == 1.0, zero rollbacks (the
    rollback program is never even traced), and the stream is still the
    baseline's bits.  max_new = 1 + 2K so every window fills exactly."""
    model, params = rwkv4
    k, max_new = 3, 1 + 2 * 3
    prompts = _prompts(model, lens=(5, 5, 5))
    _, base, _ = _serve(model, params, prompts, max_new=max_new)
    eng, toks, snap = _serve_stubbed(model, params, prompts, "accept", base,
                                     k=k, max_new=max_new)
    assert toks == base
    assert snap["acceptance_rate"] == 1.0
    assert snap["rejected_tokens"] == 0
    assert eng.trace_counts["rollback"] == 0


def test_spec_all_reject_stub(rwkv4):
    """Forced all-reject: the engine degrades to one token per lane per
    tick — acceptance_rate == 0.0, every tick rolls back — and the stream
    is STILL the baseline's bits (losslessness does not depend on the
    drafter)."""
    model, params = rwkv4
    prompts = _prompts(model, lens=(5, 5, 5))
    _, base, _ = _serve(model, params, prompts)
    eng, toks, snap = _serve_stubbed(model, params, prompts, "reject", base,
                                     max_new=MAX_NEW)
    assert toks == base
    assert snap["acceptance_rate"] == 0.0
    assert snap["accepted_tokens"] == 0 and snap["drafted_tokens"] > 0
    assert eng.trace_counts["rollback"] == 1


def test_spec_ragged_acceptance_one_batch(rwkv4):
    """Ragged per-lane acceptance INSIDE one tick: even slots accept whole
    windows while odd slots reject everything, so a single verify commit
    serves both and the rollback mask is genuinely mixed.  Streams stay
    bit-identical; the aggregate acceptance rate is strictly between the
    extremes."""
    model, params = rwkv4
    k, max_new = 3, 1 + 2 * 3
    prompts = _prompts(model, lens=(5, 5, 5))
    _, base, _ = _serve(model, params, prompts, max_new=max_new)
    _, toks, snap = _serve_stubbed(model, params, prompts, "ragged", base,
                                   k=k, max_new=max_new)
    assert toks == base
    assert 0.0 < snap["acceptance_rate"] < 1.0


def test_spec_rng_streams_advance_by_accepted_only(rwkv4):
    """SATELLITE FIX regression: with temperature sampling, each slot's
    RNG stream draws exactly one Gumbel vector per EMITTED token — never
    per drafted token — so a reject-heavy speculative run is bit-equal to
    the plain engine's sampled stream, and a ragged-acceptance run too."""
    model, params = rwkv4
    prompts = _prompts(model, lens=(5, 5, 5))
    _, base, _ = _serve(model, params, prompts, temperature=0.9)
    for kind in ("reject", "ragged"):
        _, toks, _ = _serve_stubbed(model, params, prompts, kind, base,
                                    max_new=MAX_NEW, temperature=0.9)
        assert toks == base, kind
    # and with the real drafter
    _, toks, _ = _serve(model, params, prompts, temperature=0.9,
                        speculative=3)
    assert toks == base


def test_spec_real_drafter_aligned_weights_all_accept(rwkv4):
    """The real truncated drafter hits acceptance_rate == 1.0 when the
    deep layers are no-ops: zeroing att.wo / ffn.wv for layers >= depth
    makes every deep block's residual contribution zero, so the depth-1
    drafter's argmax IS the full model's argmax.  (This is also the
    bench's calibrated-acceptance configuration.)"""
    model, params = rwkv4
    k, max_new = 4, 1 + 2 * 4

    def zero_tail(leaf):
        z = np.asarray(leaf, np.float32).copy()
        z[1:] = 0.0
        return jnp.asarray(z, leaf.dtype)

    blocks = dict(params["blocks"])
    blocks["att"] = {**blocks["att"], "wo": zero_tail(blocks["att"]["wo"])}
    blocks["ffn"] = {**blocks["ffn"], "wv": zero_tail(blocks["ffn"]["wv"])}
    aligned = {**params, "blocks": blocks}
    prompts = _prompts(model, lens=(5, 5, 5))
    _, base, _ = _serve(model, aligned, prompts, max_new=max_new)
    _, toks, snap = _serve(model, aligned, prompts, max_new=max_new,
                           speculative=k, draft_depth=1)
    assert toks == base
    assert snap["acceptance_rate"] == 1.0


def test_spec_resume_from_prefix_cache_hit(rwkv4):
    """Speculative decode composes with the recurrent-state prefix cache:
    a second request resuming a cached ancestor prefix streams the same
    bits speculative or not, cache on or off — and the hit actually
    happened."""
    model, params = rwkv4
    r = np.random.default_rng(23)
    prefix = r.integers(0, model.cfg.vocab, size=8).tolist()   # 2 chunks
    prompts = [prefix + [3], prefix + [5, 9]]

    def run(spec, cache):
        eng = ServingEngine(model, params=params, max_batch=2,
                            prefill_chunk=4, fused_prefill=True,
                            speculative=spec, prefix_cache=cache)
        out = []
        for p in prompts:                      # sequential: 2nd resumes 1st
            h = eng.submit(p, max_new_tokens=6)
            eng.run()
            out.append(h.tokens)
        return out, eng.counters.snapshot()

    base, _ = run(None, False)
    spec_cold, _ = run(2, False)
    spec_warm, snap = run(2, True)
    assert base == spec_cold == spec_warm
    assert snap["cache_hits"] >= 1


# ---------------------------------------------------------------------------
# Mid-speculation eviction + churn invariants
# ---------------------------------------------------------------------------


def _assert_spec_quiescent(eng):
    sched = eng.scheduler
    assert sched._spec_snapshot is None
    assert sched._spec_inflight == {}
    assert all(m.drafted == [] for m in sched.slots.values())


@pytest.mark.parametrize("victim", ["other", "self"])
def test_evict_mid_speculation_tick(rwkv4, victim):
    """SATELLITE FIX regression: an `on_token` callback evicting a lane in
    the MIDDLE of a speculative tick — its own lane or another lane whose
    window walk hasn't run yet — discards that lane's drafts, never emits
    them, and leaks neither a snapshot nor an in-flight marker; the
    surviving lanes' streams keep the baseline's bits."""
    model, params = rwkv4
    prompts = _prompts(model, lens=(5, 5, 5))
    _, base, _ = _serve(model, params, prompts)
    eng = ServingEngine(model, params=params, max_batch=3, prefill_chunk=4,
                        fused_prefill=True, speculative=3)
    handles = [eng.submit(p, max_new_tokens=MAX_NEW, seed=11 + i)
               for i, p in enumerate(prompts)]
    orig = eng.scheduler.on_token
    fired = []

    def on_token(req, tok):
        orig(req, tok)
        target = handles[0 if victim == "self" else 1]
        if (req.rid == 0 and len(handles[0].tokens) == 3 and not fired):
            fired.append(True)
            assert eng.cancel(target)

    eng.scheduler.on_token = on_token
    eng.run()
    assert fired
    evicted = handles[0 if victim == "self" else 1]
    assert evicted.done and len(evicted.tokens) < MAX_NEW
    # the evicted lane emitted a (possibly shorter) PREFIX of its true
    # stream — a drafted token never leaked out as engine output
    assert evicted.tokens == base[evicted.rid][:len(evicted.tokens)]
    for h in handles:
        if h is not evicted:
            assert h.tokens == base[h.rid]
    _assert_spec_quiescent(eng)
    assert eng.pool.n_free == 3 and eng.scheduler.slots == {}


def test_spec_churn_300_steps_invariants(rwkv4):
    """The 300-step submit/cancel churn, extended to speculative lanes
    (satellite 4): every single step the scheduler is speculation-
    quiescent (no snapshot, no in-flight drafts), slot accounting closes,
    and the prefix cache's structural invariants hold.  Random prompt
    reuse drives real cache hits through the speculative path."""
    model, params = rwkv4
    eng = ServingEngine(model, params=params, max_batch=3, prefill_chunk=4,
                        fused_prefill=True, speculative=2, prefix_cache=True)
    r = np.random.default_rng(0)
    pool = [r.integers(0, model.cfg.vocab, size=n).tolist()
            for n in (3, 6, 6, 9, 13)]
    live = []
    for step in range(300):
        if r.random() < 0.5 and len(live) < 6:
            p = pool[r.integers(len(pool))]
            live.append(eng.submit(p, max_new_tokens=int(r.integers(2, 9))))
        if live and r.random() < 0.15:
            h = live.pop(r.integers(len(live)))
            if not h.done:
                eng.cancel(h)
        eng.step()
        _assert_spec_quiescent(eng)
        assert len(eng.scheduler.slots) + eng.pool.n_free == 3
        eng.prefix_cache.check_state()
    eng.run()
    _assert_spec_quiescent(eng)
    assert eng.pool.n_free == 3
    snap = eng.counters.snapshot()
    assert snap["cache_hits"] > 0 and snap["drafted_tokens"] > 0


# ---------------------------------------------------------------------------
# Validation + telemetry guards
# ---------------------------------------------------------------------------


def test_build_plan_speculative_validation(rwkv4):
    model, params = rwkv4
    with pytest.raises(ValueError, match="K >= 1"):
        build_plan(model, params, speculative=0)
    with pytest.raises(ValueError, match="depth"):
        build_plan(model, params, speculative=2, draft_depth=99)
    with pytest.raises(ValueError, match="draft_depth"):
        build_plan(model, params, draft_depth=1)


def test_build_plan_rejects_model_without_drafter(rwkv4, monkeypatch):
    from repro.models import registry
    model, params = rwkv4
    monkeypatch.setattr(registry.Model, "draft_paths", lambda self: {})
    with pytest.raises(ValueError, match="truncated-stack drafter"):
        build_plan(model, params, speculative=2)


def test_scheduler_requires_speculative_programs():
    dummy = lambda *a: None
    with pytest.raises(ValueError, match="verify_fn"):
        Scheduler(None, dummy, dummy, prefill_chunk=4, speculative=2)
    with pytest.raises(ValueError, match="draft_fn"):
        Scheduler(None, dummy, dummy, prefill_chunk=4, speculative=2,
                  verify_fn=dummy, rollback_fn=dummy)
    # K=1 is the drafterless verify-only window
    Scheduler(None, dummy, dummy, prefill_chunk=4, speculative=1,
              verify_fn=dummy, rollback_fn=dummy)


def test_nonspec_plan_trace_shape_unchanged(rwkv4):
    """Guard: plans without speculation keep the exact historical
    {"decode", "prefill"} trace-counter shape (and the default drafter
    depth is half the stack when speculation IS on)."""
    model, params = rwkv4
    assert set(build_plan(model, params).trace_counts) == \
        {"decode", "prefill"}
    plan = build_plan(model, params, speculative=2)
    assert plan.speculative.draft_depth == max(1, model.cfg.n_layers // 2)
