"""Loop-aware HLO analyzer: unit tests on handwritten HLO plus an
end-to-end cross-check against a jit-compiled module."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_text, parse_module
from repro.launch.roofline import collective_bytes

HLO = """\
HloModule test

%body.1 (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %gte0 = s32[] get-tuple-element(%p), index=0
  %gte1 = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[128,128]{1,0} dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%dot.1), replica_groups={}
  %c1 = s32[] constant(1)
  %add.1 = s32[] add(%gte0, %c1)
  ROOT %tup = (s32[], f32[128,128]) tuple(%add.1, %ar)
}

%cond.1 (p2: (s32[], f32[128,128])) -> pred[] {
  %p2 = (s32[], f32[128,128]) parameter(0)
  %g = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%g, %n), direction=LT
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(%c0, %a)
  %wh = (s32[], f32[128,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%wh), index=1
}
"""


class TestAnalyzer:
    def test_parse_finds_computations(self):
        comps = parse_module(HLO)
        assert "__entry__" in comps and "body.1" in comps

    def test_loop_multiplier_applied(self):
        m = analyze_text(HLO)
        # one 128x128x128 dot per iteration, 10 iterations
        assert m.flops == 10 * 2 * 128 * 128 * 128
        # all-reduce result bytes x 10
        assert m.coll_bytes == 10 * 128 * 128 * 4

    def test_free_ops_not_counted(self):
        m = analyze_text(HLO)
        # hbm: dot (3 x 64KiB) + all-reduce op (2 x 64KiB) per iter
        # + while carry once; no gte/tuple/parameter contributions
        per_iter = 3 * 128 * 128 * 4 + 2 * 128 * 128 * 4
        assert abs(m.hbm_bytes - (10 * per_iter + (4 + 128 * 128 * 4))) \
            < 1024

    def test_collective_regex_path(self):
        # the simple (loop-unaware) parser still sees the op once
        assert collective_bytes(HLO)["all-reduce"] == 128 * 128 * 4


class TestEndToEnd:
    def test_matches_known_matmul(self):
        """A jit'd matmul chain: analyzer flops == analytic flops."""
        def f(x, w1, w2):
            return (x @ w1) @ w2

        x = jnp.zeros((64, 256))
        w1 = jnp.zeros((256, 512))
        w2 = jnp.zeros((512, 128))
        text = jax.jit(f).lower(x, w1, w2).compile().as_text()
        m = analyze_text(text)
        want = 2 * 64 * 256 * 512 + 2 * 64 * 512 * 128
        assert m.flops == want

    def test_scan_multiplies(self):
        """lax.scan body flops multiplied by the trip count."""
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y

        x = jnp.zeros((32, 64))
        ws = jnp.zeros((7, 64, 64))
        text = jax.jit(f).lower(x, ws).compile().as_text()
        m = analyze_text(text)
        assert m.flops == 7 * 2 * 32 * 64 * 64
        assert any(trips == 7 for _, trips, _ in m.loops)
