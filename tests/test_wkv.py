"""Recurrence-operator invariants: scan == composition of steps,
chunked == scan, state continuity across sequence splits."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: property tests importorskip at run time
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.core.wkv.wkv4 import (
    wkv4_scan, wkv4_step, wkv4_init_state, WKV4State)
from repro.core.wkv.wkv6 import (
    wkv6_scan, wkv6_step, wkv6_chunked, wkv6_init_state)
from repro.core.wkv.ssd import ssd_scan, ssd_step, ssd_chunked


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


class TestWkv4:
    def test_scan_equals_steps(self, rng):
        B, T, C = 2, 16, 8
        k, v = _rand(rng, B, T, C), _rand(rng, B, T, C)
        w = jnp.asarray(np.abs(rng.normal(size=(C,))) + 0.05, jnp.float32)
        u = _rand(rng, C)
        y_scan, final = wkv4_scan(k, v, w, u)
        st = wkv4_init_state((B,), C)
        outs = []
        for t in range(T):
            st, o = wkv4_step(st, k[:, t], v[:, t], w, u)
            outs.append(o)
        y_steps = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_scan),
                                   np.asarray(y_steps), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(final.a), np.asarray(st.a),
                                   rtol=1e-5)

    def test_state_continuity(self, rng):
        """scan(full) == scan(second half, state=scan(first half))."""
        B, T, C = 1, 32, 4
        k, v = _rand(rng, B, T, C), _rand(rng, B, T, C)
        w = jnp.asarray(np.abs(rng.normal(size=(C,))) + 0.05, jnp.float32)
        u = _rand(rng, C)
        y_full, _ = wkv4_scan(k, v, w, u)
        y1, mid = wkv4_scan(k[:, :16], v[:, :16], w, u)
        y2, _ = wkv4_scan(k[:, 16:], v[:, 16:], w, u, state=mid)
        np.testing.assert_allclose(
            np.asarray(y_full), np.asarray(jnp.concatenate([y1, y2], 1)),
            rtol=1e-5, atol=1e-6)

    @given(st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_wkv_is_convex_average(self, seed):
        """Property (paper Eq. 2): wkv_t is a weighted average of the v's
        seen so far => min v <= wkv <= max v."""
        rng = np.random.default_rng(seed)
        B, T, C = 1, 12, 4
        k = _rand(rng, B, T, C)
        v = _rand(rng, B, T, C)
        w = jnp.asarray(np.abs(rng.normal(size=(C,))) + 0.01, jnp.float32)
        u = _rand(rng, C)
        y, _ = wkv4_scan(k, v, w, u)
        y = np.asarray(y)
        vmax = np.maximum.accumulate(np.asarray(v), axis=1)
        vmin = np.minimum.accumulate(np.asarray(v), axis=1)
        assert np.all(y <= vmax + 1e-4)
        assert np.all(y >= vmin - 1e-4)

    def test_no_overflow_large_k(self, rng):
        """The running-max form must survive k ~ +100 (e^100 overflows f32)."""
        B, T, C = 1, 8, 4
        k = _rand(rng, B, T, C) + 100.0
        v = _rand(rng, B, T, C)
        w = jnp.asarray(np.full((C,), 0.5), jnp.float32)
        u = _rand(rng, C)
        y, _ = wkv4_scan(k, v, w, u)
        assert np.all(np.isfinite(np.asarray(y)))


class TestWkv6:
    @pytest.mark.parametrize("T,chunk,sub", [(64, 16, 8), (128, 32, 16)])
    def test_chunked_equals_scan(self, rng, T, chunk, sub):
        B, H, N = 2, 2, 8
        r, k, v = (_rand(rng, B, T, H, N) for _ in range(3))
        w = jnp.asarray(rng.uniform(0.2, 0.999, (B, T, H, N)), jnp.float32)
        u = _rand(rng, H, N)
        y1, s1 = wkv6_scan(r, k, v, w, u)
        y2, s2 = wkv6_chunked(r, k, v, w, u, chunk=chunk, subchunk=sub)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=2e-4, atol=2e-4)

    def test_strong_decay_stable(self, rng):
        """w near 0 (aggressive forgetting) must not produce inf/nan in the
        chunked form (the stability property documented in wkv6.py)."""
        B, T, H, N = 1, 64, 1, 4
        r, k, v = (_rand(rng, B, T, H, N) for _ in range(3))
        w = jnp.full((B, T, H, N), 1e-6, jnp.float32)
        u = _rand(rng, H, N)
        y, s = wkv6_chunked(r, k, v, w, u, chunk=16)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_scan_equals_steps(self, rng):
        B, T, H, N = 1, 8, 2, 4
        r, k, v = (_rand(rng, B, T, H, N) for _ in range(3))
        w = jnp.asarray(rng.uniform(0.5, 0.99, (B, T, H, N)), jnp.float32)
        u = _rand(rng, H, N)
        y_scan, _ = wkv6_scan(r, k, v, w, u)
        S = wkv6_init_state(B, H, N)
        outs = []
        for t in range(T):
            S, y = wkv6_step(S, r[:, t], k[:, t], v[:, t], w[:, t], u)
            outs.append(y)
        np.testing.assert_allclose(np.asarray(y_scan),
                                   np.asarray(jnp.stack(outs, 1)),
                                   rtol=1e-4, atol=1e-5)


class TestSSD:
    @pytest.mark.parametrize("T,chunk", [(64, 16), (32, 32)])
    def test_chunked_equals_scan(self, rng, T, chunk):
        B, H, N, P = 2, 3, 4, 8
        x = _rand(rng, B, T, H, P)
        a = jnp.asarray(rng.uniform(0.3, 0.999, (B, T, H)), jnp.float32)
        Bc, Cc = _rand(rng, B, T, H, N), _rand(rng, B, T, H, N)
        y1, s1 = ssd_scan(x, a, Bc, Cc)
        y2, s2 = ssd_chunked(x, a, Bc, Cc, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=2e-4, atol=2e-4)

    def test_scan_equals_steps(self, rng):
        B, T, H, N, P = 1, 6, 2, 4, 4
        x = _rand(rng, B, T, H, P)
        a = jnp.asarray(rng.uniform(0.5, 0.99, (B, T, H)), jnp.float32)
        Bc, Cc = _rand(rng, B, T, H, N), _rand(rng, B, T, H, N)
        y_scan, _ = ssd_scan(x, a, Bc, Cc)
        h = jnp.zeros((B, H, N, P))
        outs = []
        for t in range(T):
            h, y = ssd_step(h, x[:, t], a[:, t], Bc[:, t], Cc[:, t])
            outs.append(y)
        np.testing.assert_allclose(np.asarray(y_scan),
                                   np.asarray(jnp.stack(outs, 1)),
                                   rtol=1e-4, atol=1e-5)
