"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.quant.delta_pot import (
    FORMAT_W8, dpot_quantize, dpot_pack_int8)
from repro.kernels import (
    dpot_matmul, fused_layernorm, wkv4_pallas, wkv6_pallas,
    exp_kernel, sigmoid_kernel)
from repro.kernels import ref as R


class TestDpotMatmul:
    @pytest.mark.parametrize("M,K,N,bm,bn,bk", [
        (8, 128, 128, 8, 128, 128),
        (16, 256, 256, 8, 128, 128),
        (4, 512, 128, 4, 64, 256),
        (128, 128, 384, 64, 128, 128),
    ])
    def test_shapes(self, rng, M, K, N, bm, bn, bk):
        x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        q = dpot_quantize(w, FORMAT_W8, axis=1)
        packed, scale = dpot_pack_int8(q), q.scale[0]
        got = dpot_matmul(x, packed, scale, bm=bm, bn=bn, bk=bk)
        want = R.dpot_matmul_ref(x, packed, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, rng, dtype):
        x = jnp.asarray(rng.normal(size=(8, 128)), dtype)
        w = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        q = dpot_quantize(w, FORMAT_W8, axis=1)
        got = dpot_matmul(x, dpot_pack_int8(q), q.scale[0])
        assert got.dtype == dtype
        want = R.dpot_matmul_ref(x, dpot_pack_int8(q), q.scale[0])
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2)

    def test_quantized_matmul_close_to_fp(self, rng):
        """End-to-end: Δ-PoT W8 matmul ~ the fp matmul (the paper's
        accuracy-preservation claim at the kernel level)."""
        x = jnp.asarray(rng.normal(size=(16, 256)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(256, 128)) * 0.05, jnp.float32)
        q = dpot_quantize(w, FORMAT_W8, axis=1)
        got = dpot_matmul(x, dpot_pack_int8(q), q.scale[0])
        fp = x @ w
        rel = np.linalg.norm(np.asarray(got - fp)) / \
            np.linalg.norm(np.asarray(fp))
        # ~5.9% relative weight error is intrinsic to a 2-term PoT grid on
        # Gaussian weights (cf. Table 1: proposed ~ FP16 on accuracy, not
        # bit-exact); the matmul must not amplify it
        assert rel < 0.09


class TestFusedLayernorm:
    @pytest.mark.parametrize("shape", [(4, 128), (2, 16, 256), (1, 512)])
    def test_shapes(self, rng, shape):
        x = jnp.asarray(rng.normal(size=shape) * 3 + 1, jnp.float32)
        g = jnp.asarray(rng.normal(size=shape[-1:]), jnp.float32)
        b = jnp.asarray(rng.normal(size=shape[-1:]), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(fused_layernorm(x, g, b)),
            np.asarray(R.fused_layernorm_ref(x, g, b)),
            rtol=1e-5, atol=1e-5)

    def test_bf16(self, rng):
        x = jnp.asarray(rng.normal(size=(8, 128)), jnp.bfloat16)
        g = jnp.ones((128,), jnp.float32)
        b = jnp.zeros((128,), jnp.float32)
        got = fused_layernorm(x, g, b)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(R.fused_layernorm_ref(x, g, b), np.float32),
            rtol=2e-2, atol=2e-2)


class TestWkv4Kernel:
    @pytest.mark.parametrize("B,T,C,bc", [
        (1, 16, 64, 64), (2, 32, 128, 64), (2, 64, 64, 32),
    ])
    def test_vs_ref(self, rng, B, T, C, bc):
        k = jnp.asarray(rng.normal(size=(B, T, C)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, T, C)), jnp.float32)
        w = jnp.asarray(np.abs(rng.normal(size=(C,))) + 0.05, jnp.float32)
        u = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
        y, (a, b, o) = wkv4_pallas(k, v, w, u, bc=bc)
        yr, (ar, br, orr) = R.wkv4_ref(k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(ar),
                                   rtol=1e-5, atol=1e-5)

    def test_state_chaining(self, rng):
        """Kernel(half2, state=Kernel(half1)) == Kernel(full)."""
        B, T, C = 1, 32, 64
        k = jnp.asarray(rng.normal(size=(B, T, C)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, T, C)), jnp.float32)
        w = jnp.asarray(np.abs(rng.normal(size=(C,))) + 0.05, jnp.float32)
        u = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
        y_full, _ = wkv4_pallas(k, v, w, u)
        y1, (a, b, o) = wkv4_pallas(k[:, :16], v[:, :16], w, u)
        y2, _ = wkv4_pallas(k[:, 16:], v[:, 16:], w, u, a, b, o)
        np.testing.assert_allclose(
            np.asarray(y_full),
            np.asarray(jnp.concatenate([y1, y2], 1)), rtol=1e-5, atol=1e-5)


class TestWkv6Kernel:
    @pytest.mark.parametrize("B,T,H,N,chunk", [
        (1, 32, 2, 16, 16), (2, 64, 2, 32, 32), (1, 128, 1, 64, 64),
    ])
    def test_vs_ref(self, rng, B, T, H, N, chunk):
        r = jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.2, 0.99, (B, T, H, N)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)
        y, s = wkv6_pallas(r, k, v, w, u, chunk=chunk)
        yr, sr = R.wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                                   rtol=2e-4, atol=2e-4)


class TestExpSigKernels:
    @pytest.mark.parametrize("n", [100, 4096, 5000])
    def test_exp(self, rng, n):
        x = jnp.asarray(rng.normal(size=(n,)) * 4, jnp.float32)
        np.testing.assert_allclose(np.asarray(exp_kernel(x)),
                                   np.asarray(R.exp_ref(x)),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("n", [100, 4096])
    def test_sigmoid(self, rng, n):
        x = jnp.asarray(rng.normal(size=(n,)) * 4, jnp.float32)
        np.testing.assert_allclose(np.asarray(sigmoid_kernel(x)),
                                   np.asarray(R.sigmoid_ref(x)),
                                   rtol=1e-6, atol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,H,KVH,d,causal,bq,bkv", [
        (2, 64, 4, 4, 32, True, 32, 32),
        (1, 128, 4, 2, 64, True, 64, 32),
        (2, 32, 2, 2, 16, False, 32, 32),
        (1, 256, 8, 1, 64, True, 128, 64),
    ])
    def test_vs_ref(self, rng, B, Sq, H, KVH, d, causal, bq, bkv):
        from repro.kernels import flash_attention
        from repro.kernels.ref import flash_attention_ref
        q = jnp.asarray(rng.normal(size=(B, Sq, H, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Sq, KVH, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Sq, KVH, d)), jnp.float32)
        got = flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv)
        want = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self, rng):
        from repro.kernels import flash_attention
        from repro.kernels.ref import flash_attention_ref
        q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        got = flash_attention(q, k, v, bq=32, bkv=32)
        want = flash_attention_ref(q, k, v)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2)

    def test_gradients_vs_ref(self, rng):
        """Custom-VJP backward kernels (dq / dkv) match autodiff of the
        oracle — through the GQA repeat."""
        from repro.kernels import flash_attention
        from repro.kernels.ref import flash_attention_ref
        q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)

        def l_kernel(q, k, v):
            return jnp.sum(jnp.sin(flash_attention(q, k, v, bq=64, bkv=32)))

        def l_ref(q, k, v):
            return jnp.sum(jnp.sin(flash_attention_ref(q, k, v)))

        g1 = jax.grad(l_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(l_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)


class TestFusedCrossEntropy:
    @pytest.mark.parametrize("N,V,bn,bv", [
        (64, 1000, 32, 250), (32, 4096, 32, 1024), (16, 512, 16, 512),
    ])
    def test_vs_ref(self, rng, N, V, bn, bv):
        from repro.kernels import fused_cross_entropy
        from repro.kernels.ref import fused_cross_entropy_ref
        x = jnp.asarray(rng.normal(size=(N, V)) * 3, jnp.float32)
        lbl = jnp.asarray(rng.integers(0, V, N), jnp.int32)
        got = fused_cross_entropy(x, lbl, bn=bn, bv=bv)
        want = fused_cross_entropy_ref(x, lbl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_gradient_vs_ref(self, rng):
        from repro.kernels import fused_cross_entropy
        from repro.kernels.ref import fused_cross_entropy_ref
        x = jnp.asarray(rng.normal(size=(32, 512)) * 2, jnp.float32)
        lbl = jnp.asarray(rng.integers(0, 512, 32), jnp.int32)
        g1 = jax.grad(lambda a: jnp.sum(
            jnp.sin(fused_cross_entropy(a, lbl, bn=16, bv=128))))(x)
        g2 = jax.grad(lambda a: jnp.sum(
            jnp.sin(fused_cross_entropy_ref(a, lbl))))(x)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-5)

    def test_batched_bf16(self, rng):
        from repro.kernels import fused_cross_entropy
        from repro.kernels.ref import fused_cross_entropy_ref
        x = jnp.asarray(rng.normal(size=(2, 16, 512)), jnp.bfloat16)
        lbl = jnp.asarray(rng.integers(0, 512, (2, 16)), jnp.int32)
        got = fused_cross_entropy(x, lbl)
        want = fused_cross_entropy_ref(x, lbl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-2, atol=1e-2)
