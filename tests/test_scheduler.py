"""Continuous-batching engine tests: slot pool reuse, interleaved
prefill+decode equivalence vs the sequential loop (bit-identical), and
fixed-shape no-recompile behavior (jit trace counts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import sequential_decode
from repro.models.registry import get_model
from repro.serving import SamplingParams, ServingEngine, SlotStatePool


@pytest.fixture(scope="module")
def rwkv4():
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


class TestSlotStatePool:
    def test_free_list_admission_eviction_reuse(self, rwkv4):
        model, _ = rwkv4
        pool = SlotStatePool(model, 3)
        assert (pool.n_free, pool.n_active) == (3, 0)
        a, b, c = pool.acquire(), pool.acquire(), pool.acquire()
        assert (a, b, c) == (0, 1, 2)       # lowest-numbered first
        assert pool.acquire() is None       # full
        pool.release(b)
        assert pool.n_free == 1
        assert pool.acquire() == b          # freed slot is reused
        with pytest.raises(ValueError):
            pool.release(99)
        pool.release(a)
        with pytest.raises(ValueError):     # double-free
            pool.release(a)

    def _fill(self, pool, tag: float):
        """A batch-1 lane tree holding `tag` in every element."""
        return jax.tree_util.tree_map(
            lambda a: jnp.full_like(a, tag).astype(a.dtype), pool._fresh)

    def _assert_lane_is(self, pool, slot: int, tag: float):
        for leaf in jax.tree_util.tree_leaves(pool.read_slot(slot)):
            assert np.all(np.asarray(leaf, np.float32) == tag), \
                f"slot {slot} lost its state (expected {tag})"

    def _interleave(self, pool, steps: int, seed: int = 0):
        """Deterministic interleaved admit/evict/cancel churn: every live
        slot carries a unique tag written at admission; after every
        release-or-admit step the free list must stay duplicate-free and
        consistent with the live set, and NO live slot's state may change
        — i.e. slot reuse never aliases live state, no matter how
        fragmented the free list gets."""
        rng = np.random.default_rng(seed)
        live: dict[int, float] = {}
        next_tag = 1.0
        for _ in range(steps):
            evict = live and (pool.n_free == 0 or rng.random() < 0.45)
            if evict:
                slot = int(rng.choice(sorted(live)))   # cancel mid-life
                del live[slot]
                pool.release(slot)
            else:
                slot = pool.acquire()
                assert slot is not None and slot not in live
                pool.write_slot(slot, self._fill(pool, next_tag))
                live[slot] = next_tag
                next_tag += 1.0
            assert len(set(pool._free)) == len(pool._free)
            assert pool.n_active == len(live)
            assert set(pool._free).isdisjoint(live)
        for slot, tag in live.items():
            self._assert_lane_is(pool, slot, tag)

    def test_fragmentation_interleaved_churn_never_aliases(self, rwkv4):
        model, _ = rwkv4
        pool = SlotStatePool(model, 4)
        self._interleave(pool, steps=80)

    def test_fragmentation_under_sharded_pool(self, rwkv4):
        """Same churn on a pool whose slot axis is sharded over a serving
        mesh (1 device here; all 8 under the CI multi-device leg):
        per-lane dynamic-slice addressing must keep working across shard
        boundaries, and `decode_state_batch_axes` must stay consistent
        with the placed leaves — the slot axis is still where the axes
        tree says it is, and only that axis may be sharded."""
        from repro.launch.mesh import make_serving_mesh
        from repro.parallel.sharding import pool_shardings
        model, _ = rwkv4
        n_dev = len(jax.devices())
        n_slots = max(4, n_dev)
        mesh = make_serving_mesh(n_dev)
        state_ab = jax.eval_shape(
            lambda: model.init_slot_state(n_slots, 0, jnp.bfloat16))
        sh = pool_shardings(model.decode_state_axes(), state_ab, mesh)
        pool = SlotStatePool(model, n_slots, shardings=sh)
        axes = model.decode_state_batch_axes()
        leaves = jax.tree_util.tree_leaves(pool.state)
        assert len(axes) == len(leaves)
        for leaf, ax in zip(leaves, axes):
            assert leaf.shape[ax] == n_slots
            spec = tuple(leaf.sharding.spec) + (None,) * leaf.ndim
            assert all(s is None for i, s in enumerate(spec[:leaf.ndim])
                       if i != ax), "non-slot axis got sharded"
        self._interleave(pool, steps=60, seed=3)

    @pytest.mark.parametrize("arch", ["rwkv4-169m", "rwkv6-7b",
                                      "zamba2-7b"])
    def test_slot_read_write_roundtrip(self, arch):
        """Slot addressing is derived from decode_state_axes naming, so it
        must work across wkv4 (L,B,D), wkv6 (L,B,H,N,N) and the hybrid's
        nested ssd/conv/kv layouts."""
        model = get_model(arch, smoke=True)
        pool = SlotStatePool(model, 3, max_len=8)
        lane = jax.tree_util.tree_map(
            lambda a: jnp.full_like(a, 7).astype(a.dtype), pool._fresh)
        pool.write_slot(1, lane)
        got = pool.read_slot(1)
        for g, w in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(lane)):
            np.testing.assert_array_equal(np.asarray(g, np.float32),
                                          np.asarray(w, np.float32))
        # neighbours untouched
        for other in (0, 2):
            for leaf in jax.tree_util.tree_leaves(pool.read_slot(other)):
                assert not np.all(np.asarray(leaf, np.float32) == 7.0)
        pool.reset_slot(1)
        for g, f in zip(jax.tree_util.tree_leaves(pool.read_slot(1)),
                        jax.tree_util.tree_leaves(pool._fresh)):
            np.testing.assert_array_equal(np.asarray(g, np.float32),
                                          np.asarray(f, np.float32))


class TestEngineEquivalence:
    def test_interleaved_matches_sequential(self, rwkv4):
        """More requests than slots, ragged prompt lengths spanning chunk
        boundaries: every request's greedy output must be bit-identical to
        decoding it alone in the sequential loop."""
        model, params = rwkv4
        V = model.cfg.vocab
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, V, size=n).tolist()
                   for n in (3, 9, 17, 5, 1)]
        engine = ServingEngine(model, params=params, max_batch=3,
                               prefill_chunk=4)
        handles = [engine.submit(p, max_new_tokens=6) for p in prompts]
        engine.run()
        for h, p in zip(handles, prompts):
            assert h.done
            assert h.tokens == sequential_decode(model, params, p, 6)

    def test_stream_yields_all_tokens(self, rwkv4):
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=2,
                               prefill_chunk=4)
        h1 = engine.submit([1, 2, 3], max_new_tokens=5)
        h2 = engine.submit([4, 5], max_new_tokens=5)
        got = list(engine.stream(h1))
        assert got == h1.tokens and len(got) == 5
        engine.run()
        assert h2.done and len(h2.tokens) == 5

    def test_temperature_sampling_and_eos(self, rwkv4):
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=2,
                               prefill_chunk=4)
        h = engine.submit([1, 2, 3], SamplingParams(
            max_new_tokens=8, temperature=0.9, seed=13))
        engine.run()
        assert len(h.tokens) == 8
        # eos cuts generation short (use the first sampled token as eos)
        engine2 = ServingEngine(model, params=params, max_batch=2,
                                prefill_chunk=4)
        h2 = engine2.submit([1, 2, 3], max_new_tokens=8,
                            eos_token=h.tokens[0], temperature=0.9,
                            seed=13)
        engine2.run()
        assert h2.tokens == [h.tokens[0]]

    def test_cancel_frees_slot(self, rwkv4):
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=1,
                               prefill_chunk=4)
        h1 = engine.submit([1, 2, 3], max_new_tokens=50)
        h2 = engine.submit([4, 5, 6], max_new_tokens=3)
        engine.step()
        assert engine.pool.n_free == 0
        assert engine.cancel(h1)
        snap = engine.run()
        assert h1.done and h2.done and len(h2.tokens) == 3
        # cancellation is not a completion: no bogus latency sample
        assert snap["cancelled"] == 1 and snap["finished"] == 1
        assert len(engine.counters.latency_s) == 1

    def test_rejects_zero_token_budget(self, rwkv4):
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=1,
                               prefill_chunk=4)
        with pytest.raises(ValueError):
            engine.submit([1, 2], max_new_tokens=0)


class TestNoRecompile:
    def test_two_traces_total(self, rwkv4):
        """Admission, retirement, ragged prompts, queue churn — the engine
        must keep exactly one trace per device program (fixed shapes)."""
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=3,
                               prefill_chunk=4)
        rng = np.random.default_rng(1)
        V = model.cfg.vocab
        for wave in range(3):
            hs = [engine.submit(
                rng.integers(0, V, size=int(rng.integers(1, 11))).tolist(),
                max_new_tokens=int(rng.integers(1, 5)))
                for _ in range(4)]
            engine.run()
            assert all(h.done for h in hs)
        assert engine.trace_counts == {"decode": 1, "prefill": 1}

    def test_quantized_runs_and_no_recompile(self, rwkv4):
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=2,
                               prefill_chunk=4, quantized=True)
        hs = [engine.submit([1, 2, 3, 4, 5], max_new_tokens=4)
              for _ in range(3)]
        engine.run()
        assert all(h.done and len(h.tokens) == 4 for h in hs)
        assert engine.trace_counts == {"decode": 1, "prefill": 1}

    def test_counters_snapshot(self, rwkv4):
        model, params = rwkv4
        engine = ServingEngine(model, params=params, max_batch=2,
                               prefill_chunk=4)
        engine.submit([1, 2, 3], max_new_tokens=3)
        engine.submit([4, 5], max_new_tokens=2)
        snap = engine.run()
        assert snap["admitted"] == snap["finished"] == 2
        assert snap["decode_tokens"] == 5
        assert snap["prefill_tokens"] == 5
        assert snap["peak_active_slots"] <= 2
        assert len(engine.counters.ttft_s) == 2
        assert len(engine.counters.latency_s) == 2
