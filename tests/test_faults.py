"""Serving fault-injection drills (ServingFaultInjector): cache-probe
failures degrade to misses, forced evictions — mid-prefill and from
inside a token callback, i.e. mid-speculation — forced deadline expiry,
and the crash-safety kinds: typed in-process crashes (`crash_at_tick`),
torn snapshot writes that restore must refuse, and state-leaf corruption
that the NaN/Inf sentinels quarantine and requeue losslessly.  Every
drill asserts the robustness invariants: pool free list restored, no
cache lease leaked (`check_state` + refcounts), tick-local speculation
state empty between ticks, and a seeded surviving request's token
stream bit-identical to a fault-free run (RNG-stream isolation)."""
import jax
import pytest

from repro.models.registry import get_model
from repro.runtime.monitor import ServingFaultInjector
from repro.serving import (PrefixCache, PrefixCacheConfig, ServingEngine,
                           build_plan)


@pytest.fixture(scope="module")
def rwkv4():
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def plan4(rwkv4):
    model, params = rwkv4
    return build_plan(model, params, prefill_chunk=4)


@pytest.fixture(scope="module")
def spec_plan(rwkv4):
    model, params = rwkv4
    return build_plan(model, params, prefill_chunk=4, speculative=2)


def _refcounts(cache):
    return [e.refcount for e in
            list(cache._device.values()) + list(cache._host.values())]


def _fresh_cache():
    return PrefixCache(4, config=PrefixCacheConfig(device_slots=6,
                                                   host_slots=6))


def test_injector_validates_kinds_and_respects_enabled():
    inj = ServingFaultInjector(schedule={1: [("explode", None)]})
    with pytest.raises(ValueError):
        inj.pop(1)
    off = ServingFaultInjector(schedule={1: [("evict", 0)]}, enabled=False)
    assert off.pop(1) == [] and off.fired == []


def test_cache_probe_error_degrades_to_miss(rwkv4, plan4):
    """An injected probe failure must not crash serving, leak a lease, or
    poison the cache — the request prefills from scratch, still publishes
    its boundary state, and a resubmit hits."""
    model, _ = rwkv4
    inj = ServingFaultInjector(schedule={1: [("cache_probe_error", None)]})
    cache = _fresh_cache()
    eng = ServingEngine(model, plan=plan4, max_batch=2, prefix_cache=cache,
                        fault_injector=inj)
    h = eng.submit([1, 2, 3, 4, 5, 6], max_new_tokens=3)
    eng.run()
    assert h.outcome == "finished" and len(h.tokens) == 3
    assert eng.counters.cache_errors == 1
    assert inj.fired == [(1, "cache_probe_error", None)]
    cache.check_state()
    assert all(r == 0 for r in _refcounts(cache))
    h2 = eng.submit([1, 2, 3, 4, 9], max_new_tokens=2)
    eng.run()
    assert h2.outcome == "finished" and eng.counters.cache_hits == 1


def test_forced_evict_mid_prefill_frees_the_lane(rwkv4, plan4):
    model, _ = rwkv4
    inj = ServingFaultInjector()
    eng = ServingEngine(model, plan=plan4, max_batch=2, fault_injector=inj)
    victim = eng.submit(list(range(10, 22)), max_new_tokens=5)
    other = eng.submit([1, 2, 3], max_new_tokens=4)
    inj.schedule[2] = [("evict", victim.rid)]   # 12-token prompt: tick 2
    eng.run()                                   # is mid-prefill
    assert victim.outcome == "cancelled" and victim.tokens == []
    assert other.outcome == "finished" and len(other.tokens) == 4
    assert eng.pool.n_free == 2
    snap = eng.counters.snapshot()
    assert snap["cancelled"] == 1 and snap["finished"] == 1


def test_forced_deadline_evicts_without_a_deadline_set(rwkv4, plan4):
    model, _ = rwkv4
    inj = ServingFaultInjector()
    eng = ServingEngine(model, plan=plan4, max_batch=2, fault_injector=inj)
    victim = eng.submit([1, 2, 3, 4], max_new_tokens=20)   # no deadline_s
    inj.schedule[2] = [("deadline", victim.rid)]
    eng.run()
    assert victim.outcome == "deadline"
    assert eng.counters.deadline_evicted == 1
    assert eng.pool.n_free == 2


def test_evict_on_token_mid_speculation(rwkv4, spec_plan):
    """Eviction from inside a token callback during a speculative tick:
    the victim's drafts die with it, the tick completes, the snapshot
    never outlives the tick, and a seeded co-resident request's stream
    is bit-identical to a fault-free run."""
    model, _ = rwkv4

    def run(faulted):
        inj = ServingFaultInjector() if faulted else None
        eng = ServingEngine(model, plan=spec_plan, max_batch=2,
                            fault_injector=inj)
        victim = eng.submit([1, 2, 3, 4], max_new_tokens=10)
        survivor = eng.submit([5, 6, 7], max_new_tokens=6,
                              temperature=0.9, seed=7)
        if faulted:
            # tick 1 finishes both prefills; tick 2 is the first
            # speculative tick — evict the victim from inside its own
            # token emission there
            inj.schedule[2] = [("evict_on_token", victim.rid)]
        eng.run()
        return eng, inj, victim, survivor

    _, _, _, base_survivor = run(faulted=False)
    eng, inj, victim, survivor = run(faulted=True)
    assert inj.fired == [(2, "evict_on_token", victim.rid)]
    assert victim.outcome == "cancelled"
    assert 1 <= len(victim.tokens) < 10
    assert survivor.outcome == "finished"
    assert survivor.tokens == base_survivor.tokens
    sched = eng.scheduler
    assert sched._spec_snapshot is None and sched._spec_inflight == {}
    assert sched._evict_on_token == set()
    assert eng.pool.n_free == 2


def test_churn_every_fault_kind_holds_invariants(rwkv4, plan4):
    """All four fault kinds in one serving run against a prefix-cached
    engine: the seeded survivor's stream must be bit-identical to the
    fault-free run, and pool/cache/scheduler state must come out clean."""
    model, _ = rwkv4
    surv_p = [1, 2, 3, 4, 5, 6, 7]
    v1_p, v2_p, v3_p = (list(range(10, 22)), list(range(30, 38)),
                        list(range(40, 46)))

    def run(faulted):
        cache = _fresh_cache()
        inj = ServingFaultInjector() if faulted else None
        eng = ServingEngine(model, plan=plan4, max_batch=2,
                            prefix_cache=cache, fault_injector=inj)
        surv = eng.submit(surv_p, max_new_tokens=6, temperature=0.8,
                          seed=11)
        v1 = eng.submit(v1_p, max_new_tokens=6)
        v2 = eng.submit(v2_p, max_new_tokens=6)
        v3 = eng.submit(v3_p, max_new_tokens=6)
        if faulted:
            inj.schedule.update({
                1: [("cache_probe_error", None)],   # hits surv's probe
                2: [("evict", v1.rid)],             # v1 mid-prefill
                3: [("evict_on_token", v2.rid)],    # v2's first token
                4: [("deadline", v3.rid)],          # v3 still queued
            })
        eng.run()
        return eng, cache, inj, surv, (v1, v2, v3)

    _, _, _, base_surv, _ = run(faulted=False)
    eng, cache, inj, surv, (v1, v2, v3) = run(faulted=True)
    assert len(inj.fired) == 4 and not inj.schedule
    assert surv.outcome == "finished"
    assert surv.tokens == base_surv.tokens      # RNG-stream isolation
    assert (v1.outcome, v2.outcome, v3.outcome) == \
        ("cancelled", "cancelled", "deadline")
    # pool free list fully restored, nothing queued or resident
    assert eng.pool.n_free == 2
    assert not eng.scheduler.slots and not eng.scheduler.queue
    assert not eng.scheduler._queued and not eng._handles
    # cache invariants + no leaked lease; only the finished request
    # published its boundary state
    cache.check_state()
    assert all(r == 0 for r in _refcounts(cache))
    assert cache.n_device == 1
    snap = eng.counters.snapshot()
    assert snap["finished"] == 1 and snap["cancelled"] == 2
    assert snap["deadline_evicted"] == 1 and snap["cache_errors"] == 1
    assert eng.trace_counts == {"decode": 1, "prefill": 1}


def test_crash_fault_raises_typed_engine_crash(rwkv4):
    """`crash_at_tick` fires at the TOP of the tick, before any work:
    the raised EngineCrash carries the tick, and every snapshot already
    committed is consistent with respect to the crash point."""
    from repro.runtime.monitor import EngineCrash
    model, params = rwkv4
    inj = ServingFaultInjector(schedule={3: [("crash_at_tick", "raise")]})
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=2, fault_injector=inj)
    eng.submit([1, 2, 3, 4], max_new_tokens=8)
    with pytest.raises(EngineCrash) as ei:
        eng.run()
    assert ei.value.tick == 3
    assert inj.fired == [(3, "crash_at_tick", "raise")]


def test_restore_refuses_torn_only_directory(rwkv4, tmp_path):
    """`torn_snapshot_write` with the automatic cadence off leaves a
    directory holding ONLY a torn staging dir — exactly what a host
    crash during the very first save leaves.  Restore must refuse it
    (nothing committed), not half-restore the partial write."""
    from repro.serving import SnapshotConfig
    model, params = rwkv4
    inj = ServingFaultInjector(
        schedule={2: [("torn_snapshot_write", None)]})
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=2, fault_injector=inj,
                        snapshot=SnapshotConfig(directory=str(tmp_path),
                                                every=0))
    h = eng.submit([1, 2, 3, 4], max_new_tokens=4)
    eng.run()
    assert h.outcome == "finished"
    names = sorted(n for n in tmp_path.iterdir())
    assert [n.name.startswith(".tmp-step_") for n in names] == [True]
    with pytest.raises(FileNotFoundError):
        ServingEngine.restore(str(tmp_path), params=params)


def test_corrupt_state_leaf_quarantine_leaks_nothing(rwkv4, plan4):
    """`corrupt_state_leaf` + sentinels: the poisoned lane is
    quarantined and requeued, the replayed stream and a seeded
    co-resident survivor are bit-identical to a fault-free run, and
    nothing leaks — pool free list restored, no cache lease held, no
    stale queue/handle entries."""
    model, _ = rwkv4

    def run(faulted):
        cache = _fresh_cache()
        inj = ServingFaultInjector() if faulted else None
        eng = ServingEngine(model, plan=plan4, max_batch=2,
                            prefix_cache=cache, fault_injector=inj,
                            sentinel_every=1)
        victim = eng.submit([1, 2, 3, 4, 5, 6], max_new_tokens=6)
        surv = eng.submit([7, 8, 9], max_new_tokens=6,
                          temperature=0.8, seed=11)
        if faulted:
            inj.schedule[3] = [("corrupt_state_leaf", victim.rid)]
        eng.run()
        return eng, cache, victim, surv

    _, _, base_victim, base_surv = run(faulted=False)
    eng, cache, victim, surv = run(faulted=True)
    assert eng.counters.quarantined_lanes == 1
    assert victim.outcome == "finished"
    assert victim.tokens == base_victim.tokens   # lossless replay
    assert victim.resumed == []
    assert surv.outcome == "finished"
    assert surv.tokens == base_surv.tokens       # RNG-stream isolation
    assert eng.pool.n_free == 2
    assert not eng.scheduler.slots and not eng.scheduler.queue
    assert not eng.scheduler._queued and not eng._handles
    cache.check_state()
    assert all(r == 0 for r in _refcounts(cache))
