"""Fused decode kernels vs the per-op oracle.

The contract under test (docs/kernels.md §fully-on-chip datapath): BOTH
fused granularities — the per-block Pallas kernel (`decode_step_fused`,
one launch per layer) and the whole-model megakernel
(`decode_step_fused_model`, ONE launch per decode step with the grid
iterating over layers) — are BIT-IDENTICAL to the per-op decode path
(`decode_step`) — for fp and Δ-PoT-packed weights, for rwkv4 and rwkv6,
from random recurrent states — and the serving engine produces identical
greedy tokens with `fused_decode="block"` / `"model"`.  The megakernel's
launch count is pinned by jaxpr traversal: exactly ONE `pallas_call` per
model decode step (vs L for the per-block path).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.quant.serving import pack_params, unpack_params
from repro.models.registry import get_model

ARCHS = ["rwkv4-169m", "rwkv6-7b"]
BATCH = 4


# ---------------------------------------------------------------------------
# Launch counting: how many pallas_call EXECUTIONS does one step issue?
# ---------------------------------------------------------------------------


def _sub_jaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, jax.core.Jaxpr):
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for e in v for j in _sub_jaxprs(e)]
    return []


def count_pallas_launches(jaxpr, mult: int = 1) -> int:
    """Number of pallas_call executions one evaluation of `jaxpr` issues:
    a pallas_call inside a scan body counts once per scan iteration (the
    per-block fused path is a scan of L launches), so this measures
    LAUNCHES, not trace sites."""
    n = 0
    for eqn in jaxpr.eqns:
        m = mult
        if eqn.primitive.name == "scan":
            m = mult * eqn.params["length"]
        if eqn.primitive.name == "pallas_call":
            n += mult
        for v in eqn.params.values():
            for j in _sub_jaxprs(v):
                n += count_pallas_launches(j, m)
    return n


def _random_state(model, rng, batch=BATCH, dtype=jnp.bfloat16):
    """A decode state with random (but per-leaf plausible) contents: the
    fresh state is all-zeros/-inf, which would mask bugs that only show
    once the recurrence has history."""
    state = model.init_decode_state(batch, 0, dtype)

    def fill(leaf):
        vals = rng.normal(size=leaf.shape).astype(np.float32)
        if np.all(np.asarray(leaf, np.float32) < -1e30):   # wkv_o running max
            vals = vals - 1.0   # plausible max-exponent values
        return jnp.asarray(vals, leaf.dtype)

    return jax.tree_util.tree_map(fill, state)


def _assert_bitwise(tree_a, tree_b):
    la = jax.tree_util.tree_leaves(tree_a)
    lb = jax.tree_util.tree_leaves(tree_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def _fused_step(model, mode: str):
    """The fused decode entry under test: per-block or whole-model."""
    return (model.decode_step_fused_model if mode == "model"
            else model.decode_step_fused)


@pytest.mark.parametrize("mode", ["block", "model"])
@pytest.mark.parametrize("arch", ARCHS)
class TestBitParity:
    def test_fp(self, arch, mode, rng):
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        state = _random_state(model, rng)
        toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                           jnp.int32)
        l1, s1 = jax.jit(model.decode_step)(params, state, toks,
                                            jnp.int32(0))
        l2, s2 = jax.jit(_fused_step(model, mode))(params, state, toks,
                                                   jnp.int32(0))
        _assert_bitwise(l1, l2)
        _assert_bitwise(s1, s2)

    def test_dpot_packed(self, arch, mode, rng):
        """Packed Δ-PoT weights: per-op path unpacks the whole tree inside
        the jit (the engine's quantized oracle); the fused paths hand uint8
        codes to the kernel and decode in-launch — the megakernel
        additionally streams the code planes per layer while the shared
        scales stay resident.  Same bits out."""
        model = get_model(arch, smoke=True)
        packed = pack_params(model.init_params(jax.random.PRNGKey(0)))
        state = _random_state(model, rng)
        toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                           jnp.int32)
        oracle = jax.jit(lambda p, s, t: model.decode_step(
            unpack_params(p), s, t, jnp.int32(0)))
        fused = jax.jit(lambda p, s, t: _fused_step(model, mode)(
            p, s, t, jnp.int32(0)))
        l1, s1 = oracle(packed, state, toks)
        l2, s2 = fused(packed, state, toks)
        _assert_bitwise(l1, l2)
        _assert_bitwise(s1, s2)

    def test_multi_step_trajectory(self, arch, mode, rng):
        """Parity holds when the fused path consumes its OWN state: run
        several steps per path independently and compare at the end."""
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(1))
        s1 = model.init_decode_state(BATCH, 0, jnp.bfloat16)
        s2 = jax.tree_util.tree_map(lambda x: x, s1)
        step = jax.jit(model.decode_step)
        fstep = jax.jit(_fused_step(model, mode))
        for i in range(4):
            toks = jnp.asarray(
                rng.integers(0, model.cfg.vocab, (BATCH, 1)), jnp.int32)
            l1, s1 = step(params, s1, toks, jnp.int32(0))
            l2, s2 = fstep(params, s2, toks, jnp.int32(0))
        _assert_bitwise(l1, l2)
        _assert_bitwise(s1, s2)


@pytest.mark.parametrize("mode", ["block", "model"])
def test_rwkv4_hw_numerics_parity(mode, rng):
    """Both fused kernels compose with the paper's LUT/PWL numerics mode
    (the tables travel as explicit VMEM operands)."""
    from repro.models import rwkv4
    fused_fn = (rwkv4.decode_step_fused_model if mode == "model"
                else rwkv4.decode_step_fused)
    model = get_model("rwkv4-169m", smoke=True)
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                       jnp.int32)
    l1, s1 = jax.jit(lambda p, s, t: rwkv4.decode_step(
        p, s, t, jnp.int32(0), model.cfg, hw=True))(params, state, toks)
    l2, s2 = jax.jit(lambda p, s, t: fused_fn(
        p, s, t, jnp.int32(0), model.cfg, hw=True))(params, state, toks)
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)


@pytest.mark.parametrize("bb", [1, 2])   # bb=1 and bb=B//2, both < B
def test_batch_tiling_matches_full_batch(bb, rng):
    """Grid over batch tiles (bb < B, B % bb == 0) produces the same bits
    as one program covering the whole batch — the grid path the default
    whole-batch launch skips entirely."""
    from repro.kernels.fused_decode import fused_block_decode
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    cfg = model.cfg
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    lp = jax.tree_util.tree_map(lambda p: p[0], params["blocks"])
    st = jax.tree_util.tree_map(
        lambda p: p[0], _random_state(model, rng))
    x = jnp.asarray(rng.normal(size=(BATCH, cfg.d_model)), jnp.bfloat16)
    block = lambda l, s, xx: rwkv4.block_decode(l, s, xx, cfg)
    x_full, st_full = jax.jit(
        lambda xx, l, s: fused_block_decode(block, xx, l, s))(x, lp, st)
    x_tile, st_tile = jax.jit(
        lambda xx, l, s: fused_block_decode(block, xx, l, s, bb=bb))(
            x, lp, st)
    _assert_bitwise(x_full, x_tile)
    _assert_bitwise(st_full, st_tile)


def test_batch_tiling_rejects_ragged():
    """B % bb != 0 is a caller error, not a silent truncation."""
    from repro.kernels.fused_decode import fused_block_decode
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    lp = jax.tree_util.tree_map(lambda p: p[0], params["blocks"])
    st = jax.tree_util.tree_map(
        lambda p: p[0], model.init_decode_state(BATCH, 0, jnp.bfloat16))
    x = jnp.zeros((BATCH, model.cfg.d_model), jnp.bfloat16)
    block = lambda l, s, xx: rwkv4.block_decode(l, s, xx, model.cfg)
    with pytest.raises(ValueError, match="not divisible"):
        fused_block_decode(block, x, lp, st, bb=3)


@pytest.mark.parametrize("bb", [1, 2])
def test_model_kernel_batch_tiling(bb, rng):
    """Megakernel batch tiling: the (B // bb, L) grid re-initializes the
    residual scratch at l == 0 of every batch tile, so tiled and
    whole-batch launches agree bit-for-bit."""
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(0))
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, 1)), jnp.int32)
    l1, s1 = jax.jit(lambda p, s, t: rwkv4.decode_step_fused_model(
        p, s, t, jnp.int32(0), cfg))(params, state, toks)
    l2, s2 = jax.jit(lambda p, s, t: rwkv4.decode_step_fused_model(
        p, s, t, jnp.int32(0), cfg, bb=bb))(params, state, toks)
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("arch", ARCHS)
def test_model_kernel_prepared_params(arch, quantized, rng):
    """The serving form — `prepare_fused_model_params` chunks the stacked
    weights into per-dtype contiguous slabs ONCE outside the step — is
    bit-identical to feeding the raw tree (fused per call), for fp and
    packed Δ-PoT weights."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    if quantized:
        params = pack_params(params)
    prep = model.prepare_fused_model_params(params)
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                       jnp.int32)
    step = jax.jit(model.decode_step_fused_model)
    l1, s1 = step(params, state, toks, jnp.int32(0))
    l2, s2 = step(prep, state, toks, jnp.int32(0))
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("arch", ARCHS)
def test_model_kernel_stream_binding(arch, quantized, rng):
    """The "stream" execution structure — the TPU default: grid over
    (batch tile, layer), layer-indexed BlockSpecs, VMEM-scratch residual
    carry — produces the same bits as the oracle and the off-TPU-default
    "resident" structure, exercised here through interpret mode."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    if quantized:
        params = pack_params(params)
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                       jnp.int32)
    oracle = jax.jit(lambda p, s, t: model.decode_step(
        unpack_params(p) if quantized else p, s, t, jnp.int32(0)))
    stream = jax.jit(lambda p, s, t: model.module.decode_step_fused_model(
        p, s, t, jnp.int32(0), model.cfg, weights="stream"))
    l1, s1 = oracle(params, state, toks)
    l2, s2 = stream(params, state, toks)
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)


def test_model_kernel_stream_binding_hw_and_tiling(rng):
    """Stream binding composed with (a) the hw LUT operands at full batch
    and (b) fp bb < B batch tiling (scratch re-initializes per tile).
    hw + bb < B is deliberately NOT pinned: the A9 activation fake-quant
    scales over the whole batch, so tiling changes the quantization grain
    — an intrinsic property of the hw numerics, not a kernel bug (the
    per-block kernel has the same behavior)."""
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    cfg = model.cfg
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, 1)), jnp.int32)
    l1, s1 = jax.jit(lambda p, s, t: rwkv4.decode_step(
        p, s, t, jnp.int32(0), cfg, hw=True))(params, state, toks)
    l2, s2 = jax.jit(lambda p, s, t: rwkv4.decode_step_fused_model(
        p, s, t, jnp.int32(0), cfg, hw=True, weights="stream"))(
            params, state, toks)
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)
    l3, s3 = jax.jit(lambda p, s, t: rwkv4.decode_step_fused_model(
        p, s, t, jnp.int32(0), cfg, weights="stream", bb=2))(
            params, state, toks)
    l4, s4 = jax.jit(lambda p, s, t: rwkv4.decode_step(
        p, s, t, jnp.int32(0), cfg))(params, state, toks)
    _assert_bitwise(l4, l3)
    _assert_bitwise(s4, s3)


def test_prepared_hw_mismatch_rejected():
    """rwkv4: decoding with hw= opposite to how the params were prepared
    is an error, not silently-wrong numerics (the LUT operands travel
    with the prepared stack)."""
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(0))
    state = model.init_decode_state(BATCH, 0, jnp.bfloat16)
    toks = jnp.zeros((BATCH, 1), jnp.int32)
    prep_fp = model.prepare_fused_model_params(params)
    prep_hw = model.prepare_fused_model_params(params, hw=True)
    with pytest.raises(ValueError, match="hw="):
        rwkv4.decode_step_fused_model(prep_fp, state, toks, jnp.int32(0),
                                      cfg, hw=True)
    with pytest.raises(ValueError, match="hw="):
        rwkv4.decode_step_fused_model(prep_hw, state, toks, jnp.int32(0),
                                      cfg, hw=False)
    # matched prepare/decode works and equals the oracle
    l1, _ = jax.jit(lambda p, s, t: rwkv4.decode_step(
        model.cast_params(p), s, t, jnp.int32(0), cfg, hw=True))(
            params, state, toks)
    l2, _ = jax.jit(lambda p, s, t: rwkv4.decode_step_fused_model(
        p, s, t, jnp.int32(0), cfg, hw=True))(prep_hw, state, toks)
    _assert_bitwise(l1, l2)


def test_fuse_layer_stack_roundtrip(rng):
    """fuse_layer_stack -> unfuse_layer is bit-exact per layer and routes
    broadcast leading-1 leaves (shared Δ-PoT scales) to the resident aux
    operands instead of the slabs."""
    from repro.core.quant.serving import (
        fuse_layer_stack, pack_params, unfuse_layer)
    model = get_model("rwkv4-169m", smoke=True)
    blocks = pack_params(model.init_params(jax.random.PRNGKey(0)))["blocks"]
    Lc = model.cfg.n_layers
    stack = fuse_layer_stack(blocks, Lc)
    assert "uint8" in stack.slabs          # Δ-PoT code planes are chunked
    assert len(stack.aux) > 0              # shared scales stay resident
    flat, _ = jax.tree_util.tree_flatten(blocks)
    for l in range(Lc):
        rows = {k: v[l] for k, v in stack.slabs.items()}
        aux = [a[0] for a in stack.aux]
        layer = unfuse_layer(rows, aux, stack.manifest, stack.tdef)
        expect = jax.tree_util.tree_map(
            lambda a: a[l] if a.shape[0] == Lc else a[0], blocks)
        _assert_bitwise(expect, layer)


@pytest.mark.parametrize("arch", ARCHS)
def test_model_kernel_single_launch(arch):
    """THE megakernel claim: one model decode step issues exactly ONE
    pallas_call — vs L for the per-block fused path (a scan of L
    launches), counted by jaxpr traversal with scan trip counts."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    state = model.init_decode_state(BATCH, 0, jnp.bfloat16)
    toks = jnp.zeros((BATCH, 1), jnp.int32)
    jx_model = jax.make_jaxpr(lambda p, s, t: model.decode_step_fused_model(
        p, s, t, jnp.int32(0)))(params, state, toks)
    jx_block = jax.make_jaxpr(lambda p, s, t: model.decode_step_fused(
        p, s, t, jnp.int32(0)))(params, state, toks)
    assert count_pallas_launches(jx_model.jaxpr) == 1
    assert count_pallas_launches(jx_block.jaxpr) == model.cfg.n_layers
    # and the per-op oracle issues none at all
    jx_oracle = jax.make_jaxpr(lambda p, s, t: model.decode_step(
        p, s, t, jnp.int32(0)))(params, state, toks)
    assert count_pallas_launches(jx_oracle.jaxpr) == 0


@pytest.mark.parametrize("fused", ["block", "model"])
@pytest.mark.parametrize("quantized", [False, True])
def test_engine_greedy_equivalence(quantized, fused):
    """ServingEngine(fused_decode="block"/"model") streams the exact token
    sequences of the per-op engine — greedy decode is
    bitwise-deterministic, so this is an end-to-end bit-parity check
    through admission, chunked prefill, masked decode, and retirement."""
    from repro.serving import ServingEngine
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).tolist()
               for n in (3, 9, 17, 5)]

    def run(mode):
        eng = ServingEngine(model, params=params, max_batch=3,
                            prefill_chunk=4, quantized=quantized,
                            fused_decode=mode)
        handles = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run()
        return [h.tokens for h in handles]

    assert run(False) == run(fused)


def test_engine_fused_decode_true_is_block():
    """PR 2 compatibility: fused_decode=True still means the per-block
    kernel, and bogus modes are rejected up front."""
    from repro.serving import ServingEngine
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params=params, fused_decode=True)
    assert eng.fused_decode == "block"
    with pytest.raises(ValueError, match="fused_decode"):
        ServingEngine(model, params=params, fused_decode="layerwise")


def test_fused_capability_flag():
    """has_fused_decode / has_fused_model_decode mark exactly the models
    shipping the kernels; the engine refuses fused_decode for anything
    else."""
    for arch in ARCHS:
        m = get_model(arch, smoke=True)
        assert m.has_fused_decode
        assert m.has_fused_model_decode
    z = get_model("zamba2-7b", smoke=True)
    assert not z.has_fused_decode
    assert not z.has_fused_model_decode


# ---------------------------------------------------------------------------
# Mixed weight planes (W8 / W4-nibble / VQ-codebook per tensor)
# ---------------------------------------------------------------------------

# One tensor family per plane so every decode branch runs: wk streams W4
# nibble pairs, the FFN down-projection gathers a VQ codebook, the head is
# W4, everything else stays scalar W8.
MIXED_PLANES_POLICY = None


def _mixed_policy():
    global MIXED_PLANES_POLICY
    if MIXED_PLANES_POLICY is None:
        from repro.core.quant.policy import PlanePolicy
        MIXED_PLANES_POLICY = PlanePolicy(default="w8", overrides=(
            (r"\['att'\]\['wk'\]", "w4"),
            (r"\['ffn'\]\['wv'\]", "vq"),
            (r"\['head'\]", "w4"),
        ))
    return MIXED_PLANES_POLICY


@pytest.mark.parametrize("mode", ["block", "model"])
@pytest.mark.parametrize("arch", ARCHS)
def test_mixed_plane_bit_parity(arch, mode, rng):
    """A tree packed under a MIXED plane policy runs every fused decode
    granularity bit-identically to the per-op unpack oracle: the uint8
    slab carries W8 codes, W4 nibble pairs (half bytes) and VQ indices
    side by side; scales AND codebooks ride the resident const maps."""
    model = get_model(arch, smoke=True)
    packed = pack_params(model.init_params(jax.random.PRNGKey(0)),
                         _mixed_policy())
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                       jnp.int32)
    oracle = jax.jit(lambda p, s, t: model.decode_step(
        unpack_params(p), s, t, jnp.int32(0)))
    fused = jax.jit(lambda p, s, t: _fused_step(model, mode)(
        p, s, t, jnp.int32(0)))
    l1, s1 = oracle(packed, state, toks)
    l2, s2 = fused(packed, state, toks)
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)


@pytest.mark.parametrize("arch", ARCHS)
def test_mixed_plane_prepared_megakernel(arch, rng):
    """The serving form — `prepare_fused_model_params` over a mixed-plane
    tree (per-dtype slabs + resident codebooks) — matches the per-op
    oracle bitwise, and still launches exactly ONE pallas_call."""
    model = get_model(arch, smoke=True)
    packed = pack_params(model.init_params(jax.random.PRNGKey(0)),
                         _mixed_policy())
    prep = model.prepare_fused_model_params(packed)
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                       jnp.int32)
    oracle = jax.jit(lambda p, s, t: model.decode_step(
        unpack_params(p), s, t, jnp.int32(0)))
    mega = jax.jit(lambda p, s, t: model.decode_step_fused_model(
        p, s, t, jnp.int32(0)))
    l1, s1 = oracle(packed, state, toks)
    l2, s2 = mega(prep, state, toks)
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)
    jx = jax.make_jaxpr(lambda s, t: mega(prep, s, t))(state, toks)
    assert count_pallas_launches(jx.jaxpr) == 1


def test_mixed_plane_engine_greedy_equivalence():
    """The engine serves a mixed-plane plan end to end: fused decode
    produces the same greedy tokens as the per-op path under the SAME
    plane policy."""
    from repro.serving import ServingEngine
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).tolist()
               for n in (3, 5)]

    def run(fused):
        eng = ServingEngine(model, params=params, quantized=True,
                            plane_policy=_mixed_policy(),
                            fused_decode=fused, max_batch=2)
        handles = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run()
        return [h.tokens for h in handles]

    assert run(False) == run("model")
