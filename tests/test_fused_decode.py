"""Fused decode-layer kernel vs the per-op oracle.

The contract under test (docs/kernels.md §fully-on-chip datapath): the
single-launch Pallas block kernel (`decode_step_fused`) is BIT-IDENTICAL to
the per-op decode path (`decode_step`) — for fp and Δ-PoT-packed weights,
for rwkv4 and rwkv6, from random recurrent states — and the serving engine
produces identical greedy tokens with `fused_decode=True`.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.quant.serving import pack_params, unpack_params
from repro.models.registry import get_model

ARCHS = ["rwkv4-169m", "rwkv6-7b"]
BATCH = 4


def _random_state(model, rng, batch=BATCH, dtype=jnp.bfloat16):
    """A decode state with random (but per-leaf plausible) contents: the
    fresh state is all-zeros/-inf, which would mask bugs that only show
    once the recurrence has history."""
    state = model.init_decode_state(batch, 0, dtype)

    def fill(leaf):
        vals = rng.normal(size=leaf.shape).astype(np.float32)
        if np.all(np.asarray(leaf, np.float32) < -1e30):   # wkv_o running max
            vals = vals - 1.0   # plausible max-exponent values
        return jnp.asarray(vals, leaf.dtype)

    return jax.tree_util.tree_map(fill, state)


def _assert_bitwise(tree_a, tree_b):
    la = jax.tree_util.tree_leaves(tree_a)
    lb = jax.tree_util.tree_leaves(tree_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
class TestBitParity:
    def test_fp(self, arch, rng):
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        state = _random_state(model, rng)
        toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                           jnp.int32)
        l1, s1 = jax.jit(model.decode_step)(params, state, toks,
                                            jnp.int32(0))
        l2, s2 = jax.jit(model.decode_step_fused)(params, state, toks,
                                                  jnp.int32(0))
        _assert_bitwise(l1, l2)
        _assert_bitwise(s1, s2)

    def test_dpot_packed(self, arch, rng):
        """Packed Δ-PoT weights: per-op path unpacks the whole tree inside
        the jit (the engine's quantized oracle); the fused path hands uint8
        codes to the kernel and decodes in-launch.  Same bits out."""
        model = get_model(arch, smoke=True)
        packed = pack_params(model.init_params(jax.random.PRNGKey(0)))
        state = _random_state(model, rng)
        toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                           jnp.int32)
        oracle = jax.jit(lambda p, s, t: model.decode_step(
            unpack_params(p), s, t, jnp.int32(0)))
        fused = jax.jit(lambda p, s, t: model.decode_step_fused(
            p, s, t, jnp.int32(0)))
        l1, s1 = oracle(packed, state, toks)
        l2, s2 = fused(packed, state, toks)
        _assert_bitwise(l1, l2)
        _assert_bitwise(s1, s2)

    def test_multi_step_trajectory(self, arch, rng):
        """Parity holds when the fused path consumes its OWN state: run
        several steps per path independently and compare at the end."""
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(1))
        s1 = model.init_decode_state(BATCH, 0, jnp.bfloat16)
        s2 = jax.tree_util.tree_map(lambda x: x, s1)
        step = jax.jit(model.decode_step)
        fstep = jax.jit(model.decode_step_fused)
        for i in range(4):
            toks = jnp.asarray(
                rng.integers(0, model.cfg.vocab, (BATCH, 1)), jnp.int32)
            l1, s1 = step(params, s1, toks, jnp.int32(0))
            l2, s2 = fstep(params, s2, toks, jnp.int32(0))
        _assert_bitwise(l1, l2)
        _assert_bitwise(s1, s2)


def test_rwkv4_hw_numerics_parity(rng):
    """The fused kernel composes with the paper's LUT/PWL numerics mode."""
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    state = _random_state(model, rng)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, 1)),
                       jnp.int32)
    l1, s1 = jax.jit(lambda p, s, t: rwkv4.decode_step(
        p, s, t, jnp.int32(0), model.cfg, hw=True))(params, state, toks)
    l2, s2 = jax.jit(lambda p, s, t: rwkv4.decode_step_fused(
        p, s, t, jnp.int32(0), model.cfg, hw=True))(params, state, toks)
    _assert_bitwise(l1, l2)
    _assert_bitwise(s1, s2)


def test_batch_tiling_matches_full_batch(rng):
    """Grid over batch tiles (bb < B) produces the same bits as one
    program covering the whole batch."""
    from repro.kernels.fused_decode import fused_block_decode
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    cfg = model.cfg
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    lp = jax.tree_util.tree_map(lambda p: p[0], params["blocks"])
    st = jax.tree_util.tree_map(
        lambda p: p[0], _random_state(model, rng))
    x = jnp.asarray(rng.normal(size=(BATCH, cfg.d_model)), jnp.bfloat16)
    block = lambda l, s, xx: rwkv4.block_decode(l, s, xx, cfg)
    x_full, st_full = jax.jit(
        lambda xx, l, s: fused_block_decode(block, xx, l, s))(x, lp, st)
    x_tile, st_tile = jax.jit(
        lambda xx, l, s: fused_block_decode(block, xx, l, s, bb=2))(
            x, lp, st)
    _assert_bitwise(x_full, x_tile)
    _assert_bitwise(st_full, st_tile)


@pytest.mark.parametrize("quantized", [False, True])
def test_engine_greedy_equivalence(quantized):
    """ServingEngine(fused_decode=True) streams the exact token sequences
    of the per-op engine — greedy decode is bitwise-deterministic, so this
    is an end-to-end bit-parity check through admission, chunked prefill,
    masked decode, and retirement."""
    from repro.serving import ServingEngine
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).tolist()
               for n in (3, 9, 17, 5)]

    def run(fused):
        eng = ServingEngine(model, params=params, max_batch=3,
                            prefill_chunk=4, quantized=quantized,
                            fused_decode=fused)
        handles = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run()
        return [h.tokens for h in handles]

    assert run(False) == run(True)


def test_fused_capability_flag():
    """has_fused_decode marks exactly the models shipping the kernel; the
    engine refuses fused_decode for anything else."""
    assert get_model("rwkv4-169m", smoke=True).has_fused_decode
    assert get_model("rwkv6-7b", smoke=True).has_fused_decode
    assert not get_model("zamba2-7b", smoke=True).has_fused_decode
