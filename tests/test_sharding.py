"""Sharding-rule unit tests + an end-to-end sharded train step on the
host mesh (the same code path the production dry-run lowers)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec

from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    build_prefill_step, build_serve_step, build_train_step)
from repro.models.registry import get_model
from repro.parallel.sharding import spec_for_axes


@pytest.fixture(scope="module")
def mesh22():
    # a fake 2x2 mesh over... 1 device won't work; use abstract mesh math
    # only through spec_for_axes (which never touches devices).
    import jax.sharding as shd
    return jax.make_mesh((1, 1), ("data", "model"))


class TestSpecRules:
    def _mesh(self):
        # AbstractMesh lets us test the rules for production shapes without
        # 256 devices.
        return self._abstract_mesh((16, 16), ("data", "model"))

    def _mesh3(self):
        return self._abstract_mesh((2, 16, 16), ("pod", "data", "model"))

    @staticmethod
    def _abstract_mesh(sizes, names):
        from jax.sharding import AbstractMesh
        try:   # jax 0.4.x: one tuple of (name, size) pairs
            return AbstractMesh(tuple(zip(names, sizes)))
        except TypeError:   # jax >= 0.5: (axis_sizes, axis_names)
            return AbstractMesh(sizes, names)

    def test_fsdp_tp(self):
        spec = spec_for_axes(("fsdp", "tp"), (4096, 4096), self._mesh())
        assert spec == PartitionSpec("data", "model")

    def test_batch_spans_pod_and_data(self):
        spec = spec_for_axes(("batch", None), (256, 4096), self._mesh3())
        assert spec == PartitionSpec(("pod", "data"), None)

    def test_non_divisible_replicates(self):
        # 9 heads % 16 != 0 -> replicated, not an error
        spec = spec_for_axes((None, "tp", None), (1, 9, 64), self._mesh())
        assert spec == PartitionSpec(None, None, None)

    def test_batch1_falls_back(self):
        spec = spec_for_axes(("batch", None), (1, 524288), self._mesh3())
        assert spec == PartitionSpec(None, None)

    def test_seq_prefers_model_axis(self):
        spec = spec_for_axes(
            ("layers", "batch", "seq", "tp", None),
            (32, 128, 32768, 8, 128), self._mesh())
        # batch -> data, seq -> model (tp then has nothing left and 8 % 16
        # != 0 anyway)
        assert spec == PartitionSpec(None, "data", "model", None, None)

    def test_no_axis_reuse(self):
        spec = spec_for_axes(("fsdp", "fsdp"), (256, 256), self._mesh())
        assert spec == PartitionSpec("data", None)


class TestShardedSteps:
    """Build + run each step kind on the 1x1 host mesh: proves the
    sharding trees match the pytrees (structure errors fail here fast)."""

    def test_train_step_runs(self):
        model = get_model("smollm-135m", smoke=True)
        mesh = make_host_mesh()
        shape = ShapeConfig("t", 32, 4, "train")
        jitted, args, (p_sh, o_sh, b_sh), (init_opt, _) = \
            build_train_step(model, mesh, shape)
        params = jax.device_put(model.init_params(jax.random.PRNGKey(0)),
                                p_sh)
        opt = jax.device_put(init_opt(jax.device_get(params)), o_sh)
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                 model.cfg.vocab)
        batch = {"tokens": tok, "labels": tok,
                 "mask": jnp.ones((4, 32), jnp.float32)}
        p2, o2, metrics = jitted(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))

    def test_serve_step_runs(self):
        model = get_model("rwkv6-7b", smoke=True)
        mesh = make_host_mesh()
        shape = ShapeConfig("d", 64, 2, "decode")
        jitted, args, _ = build_serve_step(model, mesh, shape)
        params = model.init_params(jax.random.PRNGKey(0))
        state = model.init_decode_state(2, 64)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, new_state = jitted(params, state, tok, jnp.int32(0))
        assert logits.shape == (2, 1, model.cfg.vocab)

    def test_prefill_step_runs(self):
        model = get_model("phi3-mini-3.8b", smoke=True)
        mesh = make_host_mesh()
        shape = ShapeConfig("p", 32, 2, "prefill")
        jitted, args, _ = build_prefill_step(model, mesh, shape)
        params = model.init_params(jax.random.PRNGKey(0))
        tok = jnp.zeros((2, 32), jnp.int32)
        batch = {"tokens": tok, "labels": tok,
                 "mask": jnp.ones((2, 32), jnp.float32)}
        logits = jitted(params, batch)
        assert logits.shape == (2, 32, model.cfg.vocab)
