"""Packed Δ-PoT serving path: correctness of pack/unpack, serve-step
variants, and agreement with the fp decode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig
from repro.core.quant.serving import (
    pack_params, packed_abstract, replicate_fsdp, serving_axes,
    unpack_params)
from repro.core.quant.delta_pot import (
    FORMAT_W8, dpot_quantize, dpot_dequantize)
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_serve_step
from repro.models.registry import get_model


class TestPackUnpack:
    def test_roundtrip_matches_fake_quant(self, rng):
        """unpack(pack(w)) == dequantize(quantize(w)) for matmul leaves."""
        w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        params = {"blocks": {"wk": w}}
        packed = pack_params(params)
        assert packed["blocks"]["wk"]["packed"].dtype == jnp.uint8
        out = unpack_params(packed)["blocks"]["wk"]
        q = dpot_quantize(w, FORMAT_W8, axis=-1)
        want = dpot_dequantize(q)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want),
            rtol=2e-2, atol=2e-2)  # bf16 storage of the dequant

    def test_additive_leaves_passthrough(self, rng):
        params = {"ln0": {"scale": jnp.ones((8,))},
                  "time_decay": jnp.zeros((8,))}
        packed = pack_params(params)
        assert packed["ln0"]["scale"].dtype == jnp.bfloat16

    def test_abstract_matches_real(self, rng):
        model = get_model("rwkv6-7b", smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        packed = pack_params(params)
        ab = packed_abstract(model.spec(), model.abstract_params())
        real_shapes = jax.tree_util.tree_map(
            lambda x: (x.shape, str(x.dtype)), packed)
        ab_shapes = jax.tree_util.tree_map(
            lambda x: (x.shape, str(x.dtype)), ab)
        assert jax.tree_util.tree_structure(real_shapes) == \
            jax.tree_util.tree_structure(ab_shapes)
        flat_r = jax.tree_util.tree_leaves(real_shapes)
        flat_a = jax.tree_util.tree_leaves(ab_shapes)
        # scale shapes differ in broadcast form only; compare packed dtypes
        assert flat_r == flat_a

    def test_replicate_fsdp(self):
        axes = {"w": ("fsdp", "tp"), "b": (None,)}
        out = replicate_fsdp(axes)
        assert out["w"] == (None, "tp")


class TestQuantizedServeStep:
    @pytest.mark.parametrize("variant", ["base", "replicated", "quantized"])
    def test_variants_run(self, variant):
        model = get_model("rwkv6-7b", smoke=True)
        mesh = make_host_mesh()
        shape = ShapeConfig("d", 32, 2, "decode")
        jitted, args, _ = build_serve_step(model, mesh, shape,
                                           variant=variant)
        params = model.init_params(jax.random.PRNGKey(0))
        if variant == "quantized":
            params = pack_params(params)
        state = model.init_decode_state(2, 32)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, _ = jitted(params, state, tok, jnp.int32(0))
        assert logits.shape == (2, 1, model.cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(logits)))

    def test_quantized_close_to_fp(self):
        """Packed serving ~ fp serving (the paper's accuracy contract)."""
        model = get_model("rwkv4-169m", smoke=True)
        mesh = make_host_mesh()
        shape = ShapeConfig("d", 16, 2, "decode")
        j_fp, _, _ = build_serve_step(model, mesh, shape, variant="base")
        j_q, _, _ = build_serve_step(model, mesh, shape,
                                     variant="quantized")
        params = model.init_params(jax.random.PRNGKey(0))
        state = model.init_decode_state(2, 16)
        tok = jnp.ones((2, 1), jnp.int32)
        l_fp, _ = j_fp(params, state, tok, jnp.int32(0))
        l_q, _ = j_q(pack_params(params),
                     model.init_decode_state(2, 16), tok, jnp.int32(0))
        p = jax.nn.softmax(l_fp.astype(jnp.float32), -1)
        lq = jax.nn.log_softmax(l_q.astype(jnp.float32), -1)
        kl = float(jnp.mean(jnp.sum(
            p * (jnp.log(p + 1e-9) - lq), -1)))
        assert np.isfinite(kl) and kl < 0.1
