"""Tier-1 mirror of the CI docs gate (tools/check_docs.py): every module
under src/repro has a docstring and every file docs/*.md or README.md
references exists — so the paper-to-code map (docs/kernels.md) cannot
drift from the tree between CI runs."""
import importlib.util
import pathlib

_SPEC = importlib.util.spec_from_file_location(
    "check_docs",
    pathlib.Path(__file__).parent.parent / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_docs)


def test_every_module_has_docstring():
    assert check_docs.missing_docstrings() == []


def test_every_doc_file_reference_exists():
    assert check_docs.broken_references() == []
