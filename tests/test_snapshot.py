"""Crash-safe serving: tick-boundary snapshots with bit-identical resume.

The contract under test (docs/operations.md): killing the engine at ANY
tick and restoring from its newest committed snapshot yields, for every
request, a concatenated pre-crash + post-restore stream (`handle.resumed
+ handle.tokens`) that is BITWISE equal to a never-crashed oracle run —
greedy and seeded-sampled lanes alike, across fp + packed Δ-PoT, rwkv4 +
rwkv6, per-op and fused paths, speculative decode, prefix-cache lanes
and the 8-virtual-device pool.  Around that oracle: the integrity layer
(param checksums refuse corrupted planes, NaN/Inf sentinels quarantine
and requeue poisoned lanes losslessly), automatic fused→per-op path
fallback (DegradedMode events, streams unchanged), and the store's
refusal of torn/uncommitted snapshot directories.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.store import save_checkpoint
from repro.models.registry import get_model
from repro.runtime.monitor import (EngineCrash, ServingCounters,
                                   ServingFaultInjector)
from repro.serving import (IntegrityError, ServingEngine, SnapshotConfig,
                           load_snapshot)
from repro.serving.snapshot import (EngineSnapshot, make_rng,
                                    param_checksums, rng_state,
                                    tree_checksums, verify_param_checksums)

MULTI = len(jax.devices()) >= 8

N_TOKENS = 8
CRASH_TICK = 5


@pytest.fixture(scope="module")
def rwkv4():
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def rwkv6():
    model = get_model("rwkv6-7b", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, start=3):
    return [[start + i, 7, 11 + i, 2, 9, 5] for i in range(n)]


def _submit_all(engine, prompts, n_tokens=N_TOKENS):
    """Even lanes greedy, odd lanes seeded-sampled: resume parity must
    hold for both token-selection paths (the sampled lanes replay their
    serialized mid-stream RNG state)."""
    return [engine.submit(p, max_new_tokens=n_tokens,
                          temperature=(0.9 if i % 2 else 0.0), seed=11 + i)
            for i, p in enumerate(prompts)]


def _oracle_streams(model, params, prompts, n_tokens=N_TOKENS, **kw):
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=len(prompts), **kw)
    hs = _submit_all(eng, prompts, n_tokens)
    eng.run()
    assert all(h.outcome == "finished" for h in hs)
    return {h.rid: list(h.tokens) for h in hs}


def _crash_and_restore(model, params, prompts, tmp_path, *,
                       crash_tick=CRASH_TICK, every=2, n_tokens=N_TOKENS,
                       **kw):
    """Run with snapshots + a crash fault, restore, drain; returns
    (per-rid resumed+restored streams, restored engine)."""
    inj = ServingFaultInjector(
        schedule={crash_tick: [("crash_at_tick", None)]})
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=len(prompts), fault_injector=inj,
                        snapshot=SnapshotConfig(directory=str(tmp_path),
                                                every=every), **kw)
    _submit_all(eng, prompts, n_tokens)
    with pytest.raises(EngineCrash):
        eng.run()
    eng.snapshot_manager.wait()
    assert inj.fired == [(crash_tick, "crash_at_tick", None)]

    restored = ServingEngine.restore(str(tmp_path), params=params)
    handles = restored.handles              # run() pops finished lanes
    restored.run()
    if restored.snapshot_manager is not None:
        restored.snapshot_manager.wait()
    streams = {rid: h.resumed + h.tokens for rid, h in handles.items()}
    assert restored.counters.restores == 1
    assert restored.counters.resumed_lanes == len(prompts)
    return streams, restored


# ---------------------------------------------------------------------------
# Checksums, RNG streams, counters: the serialization primitives
# ---------------------------------------------------------------------------


def test_tree_checksums_dedupe_scalars_and_sensitivity():
    a = np.arange(6, dtype=np.float32)
    tree = {"w": a, "alias": a, "n": 3, "flag": True}
    cks = tree_checksums(tree)
    assert set(cks) == set(tree_checksums(tree))
    assert cks == tree_checksums(tree)              # deterministic
    # aliased leaves hash once and identically
    alias_keys = [k for k in cks if "alias" in k]
    w_keys = [k for k in cks if "'w'" in k or "w" in k and "alias" not in k]
    assert alias_keys and w_keys
    assert cks[alias_keys[0]] == cks[w_keys[0]]
    # a single flipped element changes exactly that plane's checksum
    b = a.copy()
    b[2] += 1
    cks2 = tree_checksums({"w": b, "alias": a, "n": 3, "flag": True})
    assert cks2[w_keys[0]] != cks[w_keys[0]]
    assert cks2[alias_keys[0]] == cks[alias_keys[0]]


def test_verify_param_checksums_names_planes_and_counts(rwkv4):
    model, params = rwkv4
    eng = ServingEngine(model, params=params, max_batch=1)
    ref = param_checksums(eng.plan.prepared)
    verify_param_checksums(eng.plan.prepared, ref)  # clean: no raise
    bad_ref = dict(ref)
    key = sorted(bad_ref)[0]
    bad_ref[key] ^= 0xFFFF
    counters = ServingCounters()
    with pytest.raises(IntegrityError, match="1 plane"):
        verify_param_checksums(eng.plan.prepared, bad_ref,
                               counters=counters, where="startup")
    assert counters.checksum_failures == 1


def test_rng_stream_serialization_is_bit_exact():
    gen = np.random.default_rng(123)
    gen.random(17)                                  # advance mid-stream
    clone = make_rng(rng_state(gen))
    assert clone is not gen
    assert np.array_equal(gen.random(64), clone.random(64))
    assert rng_state(None) is None and make_rng(None) is None


def test_counters_state_roundtrip():
    c = ServingCounters()
    c.on_tick(active=2, queued=1)
    c.on_snapshot(0.25)
    c.on_quarantine(7)
    c.on_checksum_failure(2)
    fresh = ServingCounters()
    fresh.load_state(c.state_dict())

    def _static(d):             # elapsed_s is a live wall clock
        return {k: v for k, v in d.items() if k != "elapsed_s"}

    assert _static(fresh.state_dict()) == _static(c.state_dict())
    assert fresh.snapshot()["quarantined_lanes"] == 1
    assert fresh.snapshot()["checksum_failures"] == 2


# ---------------------------------------------------------------------------
# Store refusals: torn, uncommitted and foreign directories
# ---------------------------------------------------------------------------


def test_load_snapshot_refuses_empty_and_torn_dirs(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_snapshot(str(tmp_path))
    # a torn write (no COMMIT) is what a crash mid-save leaves: it must
    # be invisible, so an otherwise-empty dir still has no snapshot
    tmp = tmp_path / ".tmp-step_00000004"
    tmp.mkdir()
    np.save(tmp / "leaf.npy", np.zeros(3))
    with pytest.raises(FileNotFoundError):
        load_snapshot(str(tmp_path))


def test_load_snapshot_refuses_foreign_checkpoints(tmp_path):
    # a committed checkpoint that is NOT an engine snapshot (no snapshot
    # meta) must be refused loudly, not half-restored
    save_checkpoint(str(tmp_path), 4, {"w": np.zeros(3)},
                    meta={"unrelated": True})
    with pytest.raises(ValueError):
        load_snapshot(str(tmp_path))


def test_capture_requires_build_plan_provenance(rwkv4):
    from repro.serving import build_plan
    model, params = rwkv4
    plan = build_plan(model, params, prefill_chunk=4)
    plan.build_config = None                        # hand-built plan
    eng = ServingEngine(model, plan=plan, max_batch=1)
    with pytest.raises(RuntimeError, match="build_config"):
        EngineSnapshot.capture(eng, 0)


def test_save_refuses_corrupted_params(rwkv4, tmp_path):
    """verify_interval_s=0.0 re-checksums before EVERY save: corrupt the
    reference (stand-in for a flipped param plane) and the save must
    raise IntegrityError instead of committing a poisoned snapshot."""
    model, params = rwkv4
    eng = ServingEngine(
        model, params=params, max_batch=2, prefill_chunk=4,
        snapshot=SnapshotConfig(directory=str(tmp_path), every=2,
                                verify_interval_s=0.0))
    mgr = eng.snapshot_manager
    key = sorted(mgr.reference_checksums)[0]
    mgr.reference_checksums[key] ^= 0xFFFF
    _submit_all(eng, _prompts(2), 4)
    with pytest.raises(IntegrityError):
        eng.run()
    assert eng.counters.checksum_failures >= 1
    with pytest.raises(FileNotFoundError):          # nothing committed
        load_snapshot(str(tmp_path))


# ---------------------------------------------------------------------------
# The resume oracle: crash at a tick, restore, bitwise stream parity
# ---------------------------------------------------------------------------


def test_crash_resume_rwkv4_per_op(rwkv4, tmp_path):
    model, params = rwkv4
    prompts = _prompts(3)
    oracle = _oracle_streams(model, params, prompts)
    streams, restored = _crash_and_restore(model, params, prompts,
                                           tmp_path)
    assert streams == oracle
    # the restored engine is healthy: it serves new work afterwards
    h = restored.submit(prompts[0], max_new_tokens=3)
    restored.run()
    assert h.outcome == "finished" and len(h.tokens) == 3


def test_crash_resume_quantized_fused(rwkv4, tmp_path):
    model, params = rwkv4
    prompts = _prompts(3)
    kw = dict(quantized=True, fused_decode=True, fused_prefill=True)
    oracle = _oracle_streams(model, params, prompts, **kw)
    streams, _ = _crash_and_restore(model, params, prompts, tmp_path,
                                    **kw)
    assert streams == oracle


def test_crash_resume_rwkv6_chunked(rwkv6, tmp_path):
    model, params = rwkv6
    prompts = _prompts(2)
    kw = dict(fused_prefill=True)
    oracle = _oracle_streams(model, params, prompts, **kw)
    streams, _ = _crash_and_restore(model, params, prompts, tmp_path,
                                    **kw)
    assert streams == oracle


def test_crash_resume_speculative(rwkv4, tmp_path):
    model, params = rwkv4
    prompts = _prompts(3)
    kw = dict(speculative=2)
    oracle = _oracle_streams(model, params, prompts, **kw)
    streams, restored = _crash_and_restore(model, params, prompts,
                                           tmp_path, **kw)
    assert streams == oracle
    assert restored.scheduler._spec_snapshot is None


def test_crash_resume_prefix_cache(rwkv4, tmp_path):
    """Cache lanes: warm the cache, then crash while cached-suffix
    requests are mid-flight — the snapshot carries the cache manifest,
    so the restored engine re-leases the same entries and the streams
    stay bitwise equal to the never-crashed cache run."""
    model, params = rwkv4
    warm = [1, 2, 3, 4, 5, 6, 7, 8]
    prompts = [warm + [20 + i] for i in range(3)]

    def drive(engine):
        w = engine.submit(warm, max_new_tokens=2)
        engine.run()
        assert w.outcome == "finished"
        hs = _submit_all(engine, prompts)
        return hs

    oracle_eng = ServingEngine(model, params=params, prefill_chunk=4,
                               max_batch=3, prefix_cache=True)
    ohs = drive(oracle_eng)
    oracle_eng.run()
    oracle = {h.rid: list(h.tokens) for h in ohs}

    inj = ServingFaultInjector(
        schedule={CRASH_TICK + 3: [("crash_at_tick", None)]})
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=3, prefix_cache=True, fault_injector=inj,
                        snapshot=SnapshotConfig(directory=str(tmp_path),
                                                every=2))
    hs = drive(eng)
    with pytest.raises(EngineCrash):
        eng.run()
    eng.snapshot_manager.wait()

    restored = ServingEngine.restore(str(tmp_path), params=params)
    assert restored.prefix_cache is not None
    handles = restored.handles
    restored.run()
    streams = {rid: h.resumed + h.tokens for rid, h in handles.items()
               if rid in oracle}
    assert streams == {h.rid: oracle[h.rid] for h in hs}
    restored.prefix_cache.check_state()


@pytest.mark.skipif(not MULTI, reason="needs >= 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_crash_resume_multi_device(rwkv4, tmp_path):
    from repro.launch.mesh import make_serving_mesh
    model, params = rwkv4
    prompts = _prompts(8)
    mesh = make_serving_mesh(8)
    oracle = _oracle_streams(model, params, prompts, n_tokens=6,
                             mesh=mesh)
    streams, restored = _crash_and_restore(model, params, prompts,
                                           tmp_path, n_tokens=6,
                                           mesh=make_serving_mesh(8))
    assert streams == oracle
    # restore's mesh="auto" rebuilt the recorded 8-device topology
    assert restored.plan.build_config["mesh_devices"] == 8


try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: property tests importorskip at run time
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()


# upper bound 8: with 6-token prompts and 8 new tokens the last lane
# finishes during tick 8, so tick 8 is the last tick whose top-of-tick
# fault hook still fires — a later "crash" would never trigger
@given(crash_tick=st.integers(min_value=2, max_value=8))
@settings(max_examples=5, deadline=None)
def test_crash_resume_any_tick_property(crash_tick):
    """The tentpole property: the crash tick is adversarial — ANY tick
    with a committed snapshot behind it resumes bit-identically (the
    snapshot cadence guarantees the newest committed step is at most
    `every` ticks stale; the replay from there is deterministic)."""
    import tempfile
    model = get_model("rwkv4-169m", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = _prompts(3)
    oracle = _oracle_streams(model, params, prompts)
    with tempfile.TemporaryDirectory() as d:
        try:
            streams, _ = _crash_and_restore(
                model, params, prompts, d, crash_tick=crash_tick)
        except FileNotFoundError:
            # crash before the first committed snapshot: refusing to
            # restore is the correct outcome — nothing to resume from
            assert crash_tick <= 2
            return
    assert streams == oracle


# ---------------------------------------------------------------------------
# Sentinels: NaN/Inf quarantine replays losslessly
# ---------------------------------------------------------------------------


def test_sentinel_quarantine_replays_bit_identically(rwkv4):
    model, params = rwkv4
    prompts = _prompts(3)
    oracle = _oracle_streams(model, params, prompts)
    inj = ServingFaultInjector(
        schedule={3: [("corrupt_state_leaf", 0)]})   # payload = rid 0
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=3, fault_injector=inj, sentinel_every=1)
    hs = _submit_all(eng, prompts)
    eng.run()
    assert eng.counters.quarantined_lanes == 1
    assert {h.rid: list(h.tokens) for h in hs} == oracle
    assert all(h.resumed == [] for h in hs)          # replay, not resume
    assert eng.pool.n_free == eng.pool.max_slots
    assert not eng.scheduler.slots and not eng.scheduler.queue


def test_sentinel_off_by_default(rwkv4):
    model, params = rwkv4
    eng = ServingEngine(model, params=params, max_batch=1)
    assert eng.scheduler.sentinel_every == 0


# ---------------------------------------------------------------------------
# Degraded mode: fused-path faults demote to the per-op twin
# ---------------------------------------------------------------------------


def _flaky(fn, fail_times):
    calls = {"n": 0}

    def wrapped(*args):
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise RuntimeError(f"injected dispatch failure {calls['n']}")
        return fn(*args)

    return wrapped, calls


def test_path_fallback_demotes_after_limit(rwkv4):
    model, params = rwkv4
    prompts = _prompts(3)
    kw = dict(fused_decode=True, fused_prefill=True)
    oracle = _oracle_streams(model, params, prompts, **kw)
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=3, path_fault_limit=2, **kw)
    eng.scheduler.decode_fn, _ = _flaky(eng.scheduler.decode_fn, 2)
    hs = _submit_all(eng, prompts)
    eng.run()
    assert {h.rid: list(h.tokens) for h in hs} == oracle
    assert eng.scheduler.demoted == frozenset({"decode"})
    assert eng.counters.path_fallbacks == 1
    (ev,) = eng.counters.degraded_events
    assert (ev["kind"], ev["failures"], ev["to_path"]) == \
        ("decode", 2, "per_op")
    assert ev["from_path"] == eng.plan.decode_desc.name
    # demotion is sticky: later work keeps serving on the per-op twin
    h = eng.submit(prompts[0], max_new_tokens=3)
    eng.run()
    assert h.outcome == "finished" and len(h.tokens) == 3


def test_path_fault_below_limit_retries_without_demotion(rwkv4):
    model, params = rwkv4
    prompts = _prompts(2)
    kw = dict(fused_decode=True)
    oracle = _oracle_streams(model, params, prompts, **kw)
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=2, path_fault_limit=2, **kw)
    eng.scheduler.decode_fn, calls = _flaky(eng.scheduler.decode_fn, 1)
    hs = _submit_all(eng, prompts)
    eng.run()
    assert {h.rid: list(h.tokens) for h in hs} == oracle
    assert eng.scheduler.demoted == frozenset()
    assert eng.counters.path_fallbacks == 0
    assert calls["n"] > 1                            # retried the primary


# ---------------------------------------------------------------------------
# Torn writes: restore falls back to the newest committed step
# ---------------------------------------------------------------------------


def test_torn_write_falls_back_to_committed_step(rwkv4, tmp_path):
    model, params = rwkv4
    prompts = _prompts(3)
    oracle = _oracle_streams(model, params, prompts)
    inj = ServingFaultInjector(schedule={
        5: [("torn_snapshot_write", None)],
        6: [("crash_at_tick", None)]})
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=3, fault_injector=inj,
                        snapshot=SnapshotConfig(directory=str(tmp_path),
                                                every=2))
    _submit_all(eng, prompts)
    with pytest.raises(EngineCrash):
        eng.run()
    eng.snapshot_manager.wait()
    assert any(n.startswith(".tmp-step_") for n in os.listdir(tmp_path))
    step, meta = load_snapshot(str(tmp_path))
    assert step == 4 and meta["tick"] == 4           # torn step 5 skipped

    restored = ServingEngine.restore(str(tmp_path), params=params)
    handles = restored.handles
    restored.run()
    assert {rid: h.resumed + h.tokens
            for rid, h in handles.items()} == oracle


def test_restore_refuses_wrong_params(rwkv4, tmp_path):
    model, params = rwkv4
    eng = ServingEngine(model, params=params, prefill_chunk=4,
                        max_batch=2,
                        snapshot=SnapshotConfig(directory=str(tmp_path),
                                                every=2))
    _submit_all(eng, _prompts(2), 4)
    eng.run()
    eng.snapshot_manager.wait()
    other = model.init_params(jax.random.PRNGKey(99))
    with pytest.raises(IntegrityError):
        ServingEngine.restore(str(tmp_path), params=other)
