"""Substrate tests: data determinism/host-sharding, optimizers,
checkpoint atomicity + elastic restore, fault-tolerance runtime."""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import SyntheticLM, make_batch_iterator
from repro.optim import (
    adamw, adafactor, cosine_schedule, clip_by_global_norm)
from repro.optim.compression import (
    init_error_feedback, compress_grads_int8, decompress_grads_int8)
from repro.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step, AsyncCheckpointer)
from repro.runtime import (
    HeartbeatMonitor, StragglerDetector, FailureInjector, TrainingSupervisor)
from repro.runtime.monitor import HostFailure


class TestData:
    def test_deterministic_per_step(self):
        ds = SyntheticLM(vocab=100, seq_len=16, global_batch=4)
        a, b = ds.batch(7), ds.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch(8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_partitions_batch(self):
        full = SyntheticLM(vocab=50, seq_len=8, global_batch=8)
        parts = [SyntheticLM(vocab=50, seq_len=8, global_batch=8,
                             n_hosts=4, host_id=i) for i in range(4)]
        assert sum(p.host_batch for p in parts) == full.global_batch

    def test_labels_shifted(self):
        ds = SyntheticLM(vocab=50, seq_len=8, global_batch=2)
        b = ds.batch(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)

    def test_prefetch_iterator(self):
        ds = SyntheticLM(vocab=50, seq_len=8, global_batch=2)
        it = make_batch_iterator(ds, start_step=3)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], ds.batch(3)["tokens"])

    def test_vocab_bounds(self):
        ds = SyntheticLM(vocab=17, seq_len=64, global_batch=4)
        b = ds.batch(0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 17


def _quad_problem():
    """min ||Wx - y||^2: any sane optimizer drives the loss down."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    target = jnp.asarray(rng.normal(size=(16,)), jnp.float32)

    def loss(params):
        return jnp.mean((params["w"] @ target - W @ target) ** 2)
    return loss, {"w": jnp.zeros((16, 16))}


class TestOptim:
    @pytest.mark.parametrize("make", [
        lambda: adamw(1e-2), lambda: adafactor(1e-1)])
    def test_converges(self, make):
        loss, params = _quad_problem()
        init, update = make()
        st = init(params)
        l0 = float(loss(params))
        for _ in range(60):
            g = jax.grad(loss)(params)
            params, st = update(g, st, params)
        assert float(loss(params)) < 0.2 * l0

    def test_clip_global_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
        assert abs(norm - 1.0) < 1e-5

    def test_adafactor_factored_state_is_small(self):
        init, _ = adafactor(1e-3)
        p = {"big": jnp.zeros((512, 512))}
        st = init(p)
        n_state = sum(x.size for x in jax.tree_util.tree_leaves(st.nu))
        assert n_state < 2 * 512 + 8  # vr + vc, not 512*512

    def test_grad_compression_error_feedback(self):
        """EF accumulates the quantization error so the MEAN compressed
        gradient over steps converges to the true gradient."""
        g = {"w": jnp.asarray(np.linspace(-1, 1, 64), jnp.float32) * 1e-3}
        ef = init_error_feedback(g)
        acc = jnp.zeros_like(g["w"])
        n = 50
        for _ in range(n):
            ef, cg = compress_grads_int8(g, ef)
            acc = acc + decompress_grads_int8(cg)["w"]
        np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                                   atol=2e-6)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"w": jnp.arange(12.0).reshape(3, 4),
                "nested": {"b": jnp.ones((2,))}}
        save_checkpoint(str(tmp_path), 5, tree)
        out = restore_checkpoint(str(tmp_path), 5, tree)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))

    def test_latest_ignores_uncommitted(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(2)})
        os.makedirs(tmp_path / "step_00000009")  # no COMMIT
        assert latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.ones((2,))})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1, {"x": jnp.ones((3,))})

    def test_async_keep_policy(self, tmp_path):
        ac = AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ac.save(s, {"x": jnp.full((4,), float(s))})
        ac.wait()
        assert latest_step(str(tmp_path)) == 4
        kept = sorted(n for n in os.listdir(tmp_path)
                      if n.startswith("step_"))
        assert len(kept) == 2

    def test_elastic_reshard_across_meshes(self, tmp_path):
        """Save under one mesh topology, restore under another — the
        pod-failure recovery path."""
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        save_checkpoint(str(tmp_path), 1, tree)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        axes = {"w": ("fsdp", "tp")}
        out = restore_checkpoint(str(tmp_path), 1, tree, mesh=mesh,
                                 axes=axes)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        assert out["w"].sharding.mesh.shape["data"] == 1


class TestRuntime:
    def test_heartbeat_detects_dead(self):
        t = [0.0]
        hm = HeartbeatMonitor([0, 1], timeout_s=10, clock=lambda: t[0])
        t[0] = 5.0
        hm.beat(0)
        t[0] = 12.0
        assert hm.dead_hosts() == [1]
        assert hm.alive_hosts() == [0]

    def test_straggler_detection(self):
        sd = StragglerDetector([0, 1, 2, 3], warmup_steps=3)
        for _ in range(6):
            for h in (0, 1, 2):
                sd.record(h, 1.0)
            sd.record(3, 2.5)
        assert sd.stragglers() == [3]

    def test_supervisor_restores_and_resumes(self):
        fi = FailureInjector({4: [2]})
        executed = []

        def step(s):
            fi.check(s)
            executed.append(s)

        restores = []

        def restore(hosts):
            restores.append(hosts)
            return 2   # checkpoint was at step 2

        sup = TrainingSupervisor(step, restore)
        end = sup.run(8)
        assert end == 8
        assert restores == [[2]]
        # steps 2,3 re-executed after restore
        assert executed.count(2) == 2 and executed.count(3) == 2

    def test_supervisor_gives_up(self):
        fi = FailureInjector({0: [1], 1: [1], 2: [1], 3: [1]})

        def step(s):
            fi.check(s)

        sup = TrainingSupervisor(step, restore_fn=lambda h: 0,
                                 max_restarts=2)
        with pytest.raises(HostFailure):
            sup.run(10)
