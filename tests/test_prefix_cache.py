"""Prefix cache: the bit-parity resume oracle + cache-structure invariants.

THE tentpole claim (docs/serving.md §prefix cache): resuming a prompt
from a cached chunk-boundary state is BIT-IDENTICAL to prefilling the
whole prompt — states AND last logits — because the cached state is the
state the engine's own tick-chunking produces, the pool's read/write
helpers are dtype-preserving dynamic slices, and the resumed suffix
re-chunks on the same tick boundaries a full prefill uses.  The matrix
here pins it across rwkv4 + rwkv6, fp + packed Δ-PoT weights, per-op +
fused chunked prefill, every resume boundary (including partial-chunk
suffixes), a host-tier spill roundtrip, the paper's hw LUT/PWL numerics,
and multi-turn resume-of-a-resume through the live engine.

The cache structure itself gets the same treatment as the slot pool:
variant/collision aliasing sweeps (a cache entry must NEVER be served
across quant/arch/numerics/path variants, nor on a hash collision),
write-once + refcount-lease semantics, and seeded LRU churn with
`check_state()` invariants asserted every step — including over states
read from a mesh-sharded pool (all 8 virtual devices under the CI
multi-device leg).  ServingCounters' TTFT decomposition (probe/copy time
split out of prefill_s) is pinned with a fake clock.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import exact_jit
from repro.models.registry import get_model
from repro.runtime.monitor import ServingCounters
from repro.serving import (CacheVariant, PrefixCache, PrefixCacheConfig,
                           ServingEngine, SlotStatePool)
from repro.serving.plan import build_plan
from repro.serving.prefix_cache import DEVICE, default_chunk_hash

ARCHS = ["rwkv4-169m", "rwkv6-7b"]
C = 4                                   # prefill chunk for every test


def _assert_bitwise(tree_a, tree_b):
    la = jax.tree_util.tree_leaves(tree_a)
    lb = jax.tree_util.tree_leaves(tree_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def _variant(**kw) -> CacheVariant:
    base = dict(arch="rwkv4-169m-smoke", quant="fp", numerics="exact",
                prefill="per_op", state_dtype="bfloat16")
    base.update(kw)
    return CacheVariant(**base)


def _lane(tag: float, dtype=jnp.bfloat16):
    """A tiny sentinel 'lane state' tree for pure-cache tests."""
    return {"a": jnp.full((2, 3), tag, dtype),
            "b": jnp.full((4,), tag + 0.5, jnp.float32)}


def _chunked(prompt, n0=0):
    """[(lo, hi)] tick chunks the scheduler would run for prompt[n0:]."""
    return [(lo, min(lo + C, len(prompt)))
            for lo in range(n0, len(prompt), C)]


# ---------------------------------------------------------------------------
# THE resume oracle: plan-level bit parity at every boundary
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused_prefill", [False, True],
                         ids=["per_op", "chunked"])
@pytest.mark.parametrize("quantized", [False, True], ids=["fp", "dpot"])
@pytest.mark.parametrize("arch", ARCHS)
def test_resume_bit_parity_matrix(arch, quantized, fused_prefill):
    """For EVERY chunk boundary n of a prompt with a partial final chunk:
    (capture state at n during a full prefill) then (write it into a
    fresh pool lane, prefill only prompt[n:]) ends bit-identical — final
    state and final logits — to the uninterrupted full prefill.  This is
    exactly the cache's hit path: probe -> write_slot -> suffix chunks on
    the same tick boundaries."""
    model = get_model(arch, smoke=True)
    plan = build_plan(model, quantized=quantized,
                      fused_prefill=fused_prefill, prefill_chunk=C)
    prefill = plan.prefill_fn(1)
    rng = np.random.default_rng(len(arch) + 2 * quantized)
    prompt = rng.integers(0, model.cfg.vocab, size=2 * C + 2).tolist()

    def run(pool, chunks, fresh0):
        fresh = fresh0
        boundary_states, last = {}, None
        for lo, hi in chunks:
            toks = np.zeros((1, C), np.int32)
            valid = np.zeros((1, C), bool)
            toks[0, :hi - lo] = prompt[lo:hi]
            valid[0, :hi - lo] = True
            pool.state, last = prefill(pool.state, toks, valid,
                                       np.array([fresh]))
            fresh = False
            if hi % C == 0:
                boundary_states[hi] = pool.read_slot(0)
        return boundary_states, pool.read_slot(0), last

    pool = SlotStatePool(model, 1, dtype=plan.state_dtype)
    cached, s_full, l_full = run(pool, _chunked(prompt), True)
    assert sorted(cached) == [C, 2 * C]       # 10 tokens -> 2 boundaries
    for n, state in cached.items():
        pool2 = SlotStatePool(model, 1, dtype=plan.state_dtype)
        pool2.write_slot(0, state)            # the cache-hit restore
        _, s_res, l_res = run(pool2, _chunked(prompt, n), False)
        _assert_bitwise(s_full, s_res)
        _assert_bitwise(l_full, l_res)


def test_resume_bit_parity_survives_host_spill(rng):
    """The spill tier's device_get -> device roundtrip is bit-exact for
    the bf16 state: resuming from a state that took the host detour ends
    identical to resuming from the device-resident copy."""
    model = get_model("rwkv4-169m", smoke=True)
    plan = build_plan(model, prefill_chunk=C)
    prefill = plan.prefill_fn(1)
    prompt = rng.integers(0, model.cfg.vocab, size=C + 3).tolist()
    pool = SlotStatePool(model, 1, dtype=plan.state_dtype)
    toks = np.asarray([prompt[:C]], np.int32)
    pool.state, _ = prefill(pool.state, toks, np.ones((1, C), bool),
                            np.array([True]))
    state = pool.read_slot(0)
    spilled = jax.tree_util.tree_map(
        jnp.asarray, jax.tree_util.tree_map(jax.device_get, state))
    _assert_bitwise(state, spilled)

    def suffix(lane):
        p = SlotStatePool(model, 1, dtype=plan.state_dtype)
        p.write_slot(0, lane)
        t = np.zeros((1, C), np.int32)
        v = np.zeros((1, C), bool)
        t[0, :3], v[0, :3] = prompt[C:], True
        p.state, last = prefill(p.state, t, v, np.array([False]))
        return p.read_slot(0), last

    _assert_bitwise(suffix(state), suffix(spilled))


def test_resume_bit_parity_hw_lut_numerics(rng):
    """The paper's LUT-exp / PWL-sigmoid / LUT-div numerics resume
    bit-identically too (their states are filed under numerics='hw_lut',
    never aliasing the exact-numerics entries): a masked scan of
    decode_step(hw=True) over the suffix, seeded with the boundary state
    (after a host roundtrip), matches the uninterrupted scan."""
    from repro.models import rwkv4
    from repro.serving.plan import masked_state_commit
    model = get_model("rwkv4-169m", smoke=True)
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    cfg, axes = model.cfg, model.decode_state_batch_axes()
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, size=2 * C + 2),
                         jnp.int32)

    def scan(state, tokens):
        def body(st, tok):
            logits, stepped = rwkv4.decode_step(
                params, st, tok[None, None], jnp.int32(0), cfg, hw=True)
            return masked_state_commit(stepped, st,
                                       jnp.ones((1,), bool), axes), logits
        return jax.lax.scan(body, state, tokens)

    scan = exact_jit(scan)
    fresh = model.init_decode_state(1, 0)
    s_full, l_full = scan(fresh, prompt)
    s_mid, _ = scan(fresh, prompt[:C])
    s_mid = jax.tree_util.tree_map(                 # host-tier roundtrip
        jnp.asarray, jax.tree_util.tree_map(jax.device_get, s_mid))
    s_res, l_res = scan(s_mid, prompt[C:])
    _assert_bitwise(s_full, s_res)
    _assert_bitwise(l_full[-1], l_res[-1])


# ---------------------------------------------------------------------------
# Engine-level: cached serving streams the exact cache-off tokens
# ---------------------------------------------------------------------------


def _run_engine(model, params, prompts, *, cache, n_new=5, **kw):
    eng = ServingEngine(model, params=params, max_batch=2, prefill_chunk=C,
                        prefix_cache=cache, **kw)
    toks = []
    for p in prompts:                  # sequential: later submits can hit
        h = eng.submit(p, max_new_tokens=n_new)
        eng.run()
        toks.append(h.tokens)
    assert eng.trace_counts == {"decode": 1, "prefill": 1}
    return eng, toks


@pytest.mark.parametrize("quantized,fused_prefill",
                         [(False, False), (False, True), (True, True)],
                         ids=["fp-per_op", "fp-chunked", "dpot-chunked"])
@pytest.mark.parametrize("arch", ARCHS)
def test_engine_cached_greedy_equivalence(arch, quantized, fused_prefill):
    """End to end through the live engine: with the cache on, repeated
    and extended prefixes stream the exact greedy tokens of cache-off
    serving, on both prefill paths, fp and packed — and still on exactly
    two device programs (a hit is a per-lane write, not a new trace)."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    base = rng.integers(0, model.cfg.vocab, size=2 * C).tolist()
    prompts = [base + [7], base + [9, 3],          # sibling suffixes
               base[:C] + [5],                     # shorter shared prefix
               base + rng.integers(0, model.cfg.vocab, size=C + 1).tolist()]
    kw = dict(quantized=quantized, fused_prefill=fused_prefill)
    _, want = _run_engine(model, params, prompts, cache=None, **kw)
    eng, got = _run_engine(model, params, prompts,
                           cache=PrefixCacheConfig(device_slots=8,
                                                   host_slots=8), **kw)
    assert got == want
    snap = eng.prefix_cache.snapshot()
    assert snap["hits"] >= 3 and snap["collisions"] == 0
    eng.prefix_cache.check_state()


def test_engine_resume_of_a_resume(rwkv4_fixture):
    """Multi-turn: request B resumes from A's cached boundary and extends
    it; request C resumes from a boundary B captured WHILE ITSELF running
    resumed — tokens stay bit-identical to cache-off serving, and the
    cached-token accounting shows each turn skipped the whole shared
    prefix."""
    model, params = rwkv4_fixture
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, model.cfg.vocab, size=2 * C).tolist()
    p2 = rng.integers(0, model.cfg.vocab, size=C).tolist()
    p3 = rng.integers(0, model.cfg.vocab, size=C + 2).tolist()
    prompts = [p1 + [3], p1 + p2 + [5], p1 + p2 + p3]
    _, want = _run_engine(model, params, prompts, cache=None)
    counters = ServingCounters()
    eng, got = _run_engine(model, params, prompts,
                           cache=PrefixCacheConfig(device_slots=8,
                                                   host_slots=8),
                           counters=counters)
    assert got == want
    # B restored 2C (all of p1), C restored 3C (p1+p2, captured during
    # B's own resumed run)
    assert counters.cached_tokens == 2 * C + 3 * C
    assert counters.cache_hits == 2 and counters.cache_misses == 1


def test_engine_cached_serving_on_mesh(rwkv4_fixture):
    """The cache's per-lane read/write rides the sharded pool: cached
    serving over a ('data',) mesh (all visible devices — 8 under the CI
    multi-device leg) streams the cache-off tokens bit-identically."""
    from repro.launch.mesh import make_serving_mesh
    model, params = rwkv4_fixture
    rng = np.random.default_rng(4)
    base = rng.integers(0, model.cfg.vocab, size=2 * C).tolist()
    prompts = [base + [1], base + [2, 3]]
    _, want = _run_engine(model, params, prompts, cache=None)
    mesh = make_serving_mesh(len(jax.devices()))
    eng, got = _run_engine(model, params, prompts,
                           cache=PrefixCacheConfig(device_slots=4,
                                                   host_slots=4),
                           mesh=mesh)
    assert got == want
    assert eng.prefix_cache.stats["hits"] == 1


def test_engine_rejects_chunk_mismatched_shared_cache(rwkv4_fixture):
    """A shared cache whose chunk granularity differs from the plan's
    prefill_chunk would capture states at non-tick boundaries — the
    engine refuses it outright."""
    model, params = rwkv4_fixture
    shared = PrefixCache(C + 1)
    with pytest.raises(ValueError, match="chunk"):
        ServingEngine(model, params=params, max_batch=2, prefill_chunk=C,
                      prefix_cache=shared)


# ---------------------------------------------------------------------------
# Cache-key aliasing: variants and collisions never cross
# ---------------------------------------------------------------------------


def test_variant_isolation_full_sweep():
    """Every pairwise-distinct CacheVariant over the arch/quant/numerics/
    prefill/state-dtype cross-product gets its own namespace: after
    inserting a distinct sentinel state under each variant FOR THE SAME
    TOKENS, each probe returns exactly its own sentinel."""
    variants = [CacheVariant(arch=a, quant=q, numerics=n, prefill=p,
                             state_dtype=d)
                for a, q, n, p, d in itertools.product(
                    ("rwkv4-169m-smoke", "rwkv6-7b-smoke"),
                    ("fp", "dpot_w8"), ("exact", "hw_lut"),
                    ("per_op", "chunked"), ("bfloat16", "float32"))]
    cache = PrefixCache(C, config=PrefixCacheConfig(
        device_slots=len(variants), host_slots=0))
    prompt = list(range(C + 1))
    for i, v in enumerate(variants):
        assert cache.insert(v, prompt, C, _lane(float(i)))
    for i, v in enumerate(variants):
        lease = cache.probe(v, prompt)
        assert lease is not None
        np.testing.assert_array_equal(
            np.asarray(lease.state["a"], np.float32), float(i))
        lease.release()
    cache.check_state()
    assert cache.stats["collisions"] == 0


def test_hash_collision_rejected_by_token_compare():
    """A hash-equal-but-token-unequal chunk is a lookup-table accident,
    not a hit: with a constant hash function every same-length prompt
    collides, and the full-key token compare must reject all of them
    (counted as collisions), never serving another prompt's state."""
    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=4,
                                                    host_slots=0),
                        hash_fn=lambda prev, toks: b"collide")
    v = _variant()
    a, b = list(range(10, 10 + C + 1)), list(range(50, 50 + C + 1))
    assert cache.insert(v, a, C, _lane(1.0))
    assert cache.probe(v, b) is None
    assert cache.stats["collisions"] == 1 and cache.stats["misses"] == 1
    # the colliding key is occupied by a's state, so b can neither see
    # itself as cached nor insert its own state under that key — a
    # collision degrades to a miss, never to a wrong state
    assert not cache.contains(v, b, C)
    assert not cache.insert(v, b, C, _lane(2.0))
    lease = cache.probe(v, a)
    assert lease is not None
    np.testing.assert_array_equal(np.asarray(lease.state["a"], np.float32),
                                  1.0)
    lease.release()


def test_rolling_digests_ancestor_sharing(rng):
    """Rolling-hash structure: two prompts agree on every boundary digest
    up to their common prefix and disagree on every boundary after the
    first differing token — so any cached ancestor hits and nothing past
    the divergence can."""
    cache = PrefixCache(C)
    p = rng.integers(0, 1000, size=4 * C + 2).tolist()
    q = list(p)
    q[2 * C + 1] += 1                       # diverge inside chunk 3
    dp, dq = cache.digests(p), cache.digests(q)
    assert sorted(dp) == sorted(dq) == [C, 2 * C, 3 * C, 4 * C]
    assert dp[C] == dq[C] and dp[2 * C] == dq[2 * C]
    assert dp[3 * C] != dq[3 * C] and dp[4 * C] != dq[4 * C]
    # process-stability: the digest is a pure function of the tokens
    assert default_chunk_hash(b"", tuple(p[:C])) == dp[C]


def test_probe_serves_only_proper_prefixes():
    """A whole-prompt boundary entry must not be served for the SAME
    prompt (the last token's logits are still needed to sample the first
    generated token) — but it IS the longest hit for any extension."""
    cache = PrefixCache(C)
    v = _variant()
    prompt = list(range(2 * C))
    assert cache.insert(v, prompt, 2 * C, _lane(1.0))
    assert cache.probe(v, prompt) is None          # n == len(prompt)
    lease = cache.probe(v, prompt + [99])
    assert lease is not None and lease.n_tokens == 2 * C
    lease.release()


def test_write_once_first_state_wins():
    cache = PrefixCache(C)
    v = _variant()
    prompt = list(range(C + 1))
    assert cache.insert(v, prompt, C, _lane(1.0))
    assert not cache.insert(v, prompt, C, _lane(2.0))
    assert cache.stats["rejects"] == 1 and cache.stats["inserts"] == 1
    lease = cache.probe(v, prompt)
    np.testing.assert_array_equal(np.asarray(lease.state["a"], np.float32),
                                  1.0)
    lease.release()
    # misaligned / out-of-range boundaries are refused outright
    assert not cache.insert(v, prompt, C - 1, _lane(3.0))
    assert not cache.insert(v, prompt, 0, _lane(3.0))
    assert not cache.insert(v, prompt, 2 * C, _lane(3.0))


# ---------------------------------------------------------------------------
# LRU tiers, refcount leases, churn invariants
# ---------------------------------------------------------------------------


def test_eviction_spills_lru_and_host_hit_promotes():
    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=2,
                                                    host_slots=2))
    v = _variant()
    prompts = [[i * 100 + j for j in range(C + 1)] for i in range(3)]
    for i, p in enumerate(prompts):
        assert cache.insert(v, p, C, _lane(float(i)))
    # 0 was LRU -> spilled to host; 1, 2 device-resident
    assert (cache.n_device, cache.n_host) == (2, 1)
    assert cache.stats["evictions"] == cache.stats["spills"] == 1
    lease = cache.probe(v, prompts[0])             # host hit
    assert cache.stats["host_hits"] == 1
    np.testing.assert_array_equal(np.asarray(lease.state["a"], np.float32),
                                  0.0)
    lease.release()
    # promotion put 0 back on device, displacing the new LRU (1) to host
    key0 = (v, C, cache.digests(prompts[0])[C])
    assert key0 in cache._device and cache._device[key0].tier == DEVICE
    assert (v, C, cache.digests(prompts[1])[C]) in cache._host
    cache.check_state()


def test_leases_pin_entries_against_eviction():
    """A refcount-held entry is never the eviction/spill victim; when
    EVERY device entry is leased, inserts drop instead of tearing down a
    state someone is copying."""
    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=2,
                                                    host_slots=2))
    v = _variant()
    pa, pb, pc, pd = ([i * 10 + j for j in range(C + 1)] for i in range(4))
    cache.insert(v, pa, C, _lane(1.0))
    cache.insert(v, pb, C, _lane(2.0))
    hold_a = cache.probe(v, pa)
    cache.insert(v, pc, C, _lane(3.0))     # victim must be b, not leased a
    assert (v, C, cache.digests(pa)[C]) in cache._device
    assert (v, C, cache.digests(pb)[C]) in cache._host
    hold_c = cache.probe(v, pc)
    assert not cache.insert(v, pd, C, _lane(4.0))  # all device slots leased
    assert cache.stats["insert_dropped"] == 1
    cache.check_state()
    hold_a.release(), hold_c.release()
    hold_a.release()                               # idempotent
    assert cache._device[(v, C, cache.digests(pa)[C])].refcount == 0
    assert cache.insert(v, pd, C, _lane(4.0))      # room again
    cache.check_state()


def test_host_hit_pinned_through_promotion_churn():
    """The host-hit lease is taken BEFORE promotion, so the promotion's
    own room-making (device eviction -> host spill -> host eviction) can
    never victimize the entry being served — the regression that would
    otherwise KeyError with both tiers at capacity."""
    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=1,
                                                    host_slots=1))
    v = _variant()
    pa = [10 + j for j in range(C + 1)]
    pb = [90 + j for j in range(C + 1)]
    cache.insert(v, pa, C, _lane(1.0))
    cache.insert(v, pb, C, _lane(2.0))     # a spills to the 1-slot host
    assert (cache.n_device, cache.n_host) == (1, 1)
    lease = cache.probe(v, pa)             # host hit, both tiers full
    assert lease is not None and lease.n_tokens == C
    np.testing.assert_array_equal(np.asarray(lease.state["a"], np.float32),
                                  1.0)
    cache.check_state()
    lease.release()
    cache.check_state()


def _churn(cache, variant, steps, seed, state_for):
    """Seeded random probe/insert/hold/release schedule; invariants
    checked EVERY step.  `state_for(i)` builds the state inserted for
    prompt family i."""
    rng = np.random.default_rng(seed)
    prompts = [[i * 1000 + j for j in range(rng.integers(1, 4) * C + 1)]
               for i in range(12)]
    held = []
    for _ in range(steps):
        op = rng.random()
        p = prompts[int(rng.integers(len(prompts)))]
        if op < 0.45:
            n = int(rng.integers(1, len(p) // C + 1)) * C
            cache.insert(variant, p, n, state_for(n))
        elif op < 0.8:
            lease = cache.probe(variant, p)
            if lease is not None:
                assert lease.n_tokens < len(p)
                assert lease.tokens == tuple(p[:lease.n_tokens])
                if rng.random() < 0.5 and len(held) < 4:
                    held.append(lease)     # hold across future churn
                else:
                    lease.release()
        elif held:
            held.pop(int(rng.integers(len(held)))).release()
        cache.check_state()
    for lease in held:
        lease.release()
    cache.check_state()
    snap = cache.snapshot()
    assert snap["inserts"] > 0 and snap["hits"] > 0
    assert snap["device_entries"] <= cache.config.device_slots


def test_lru_churn_invariants_every_step():
    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=3,
                                                    host_slots=4))
    _churn(cache, _variant(), steps=300, seed=0,
           state_for=lambda n: _lane(float(n)))
    assert cache.stats["evictions"] > 0 and cache.stats["spills"] > 0


def test_lru_churn_over_sharded_pool_states(rwkv4_fixture):
    """Same churn, but the cached states are REAL lane trees read from a
    pool sharded over a serving mesh (1 device locally, all 8 under the
    CI multi-device leg): read_slot -> insert -> probe -> write_slot back
    must preserve the lane bits across shard boundaries and the host
    spill tier."""
    from repro.launch.mesh import make_serving_mesh
    from repro.parallel.sharding import pool_shardings
    model, _ = rwkv4_fixture
    n_dev = len(jax.devices())
    n_slots = max(4, n_dev)
    mesh = make_serving_mesh(n_dev)
    state_ab = jax.eval_shape(
        lambda: model.init_slot_state(n_slots, 0, jnp.bfloat16))
    sh = pool_shardings(model.decode_state_axes(), state_ab, mesh)
    pool = SlotStatePool(model, n_slots, shardings=sh)

    def tag_lane(tag):
        return jax.tree_util.tree_map(
            lambda a: jnp.full_like(a, tag).astype(a.dtype), pool._fresh)

    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=2,
                                                    host_slots=2))
    _churn(cache, _variant(), steps=120, seed=7,
           state_for=lambda n: tag_lane(float(n)))
    # roundtrip a probed state through a pool lane and back, bit-exact
    v = _variant()
    p = list(range(C + 1))
    cache2 = PrefixCache(C)
    cache2.insert(v, p, C, tag_lane(21.0))
    lease = cache2.probe(v, p)
    pool.write_slot(2, lease.state)
    lease.release()
    _assert_bitwise(pool.read_slot(2), tag_lane(21.0))


def test_host_tier_disabled_drops_instead_of_spilling():
    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=2,
                                                    host_slots=0))
    v = _variant()
    for i in range(4):
        cache.insert(v, [i * 100 + j for j in range(C + 1)], C,
                     _lane(float(i)))
    assert cache.n_host == 0 and cache.stats["spills"] == 0
    assert cache.stats["evictions"] == 2 and cache.stats["drops"] == 2
    cache.check_state()


# ---------------------------------------------------------------------------
# Telemetry: the TTFT decomposition and the token accounting
# ---------------------------------------------------------------------------


def test_counters_prefill_excludes_probe_and_copy_time():
    """The satellite counter fix, pinned with a settable clock: the
    request's prefill_s sample is admit -> first-token MINUS the cache
    probe and state-copy slices — cache time must not masquerade as
    prefill work (and a cancelled request drops its pending overhead)."""
    t = [0.0]
    c = ServingCounters(clock=lambda: t[0])
    c.on_enqueue(1)
    t[0] = 1.0
    c.on_admit(1)
    c.on_cache_probe(1, hit=True, n_cached=8, probe_s=0.25, copy_s=0.75)
    t[0] = 5.0
    c.on_token(1, first=True)
    assert c.ttft_s == [5.0]
    assert c.prefill_s == [3.0]            # 4s wall - 1s cache overhead
    assert c.cached_tokens == 8 and c.cache_hits == 1
    assert c.cache_probe_s == [0.25] and c.state_copy_s == [0.75]
    # miss: probe time still subtracted, no copy sample
    c.on_enqueue(2)
    t[0] = 6.0
    c.on_admit(2)
    c.on_cache_probe(2, hit=False, probe_s=0.5)
    t[0] = 8.0
    c.on_token(2, first=True)
    assert c.prefill_s[-1] == 1.5 and len(c.state_copy_s) == 1
    # cancellation clears the pending overhead (no leak)
    c.on_admit(3)
    c.on_cache_probe(3, hit=True, n_cached=4, probe_s=1.0, copy_s=1.0)
    c.on_cancel(3)
    assert 3 not in c._admit_overhead
    snap = c.snapshot()
    assert snap["cache_hit_rate"] == 2 / 3
    assert snap["mean_cache_probe_s"] == pytest.approx((0.25 + 0.5 + 1) / 3)


def test_engine_cached_vs_prefilled_token_accounting(rwkv4_fixture):
    """Across a cached run, every prompt token is accounted exactly once:
    restored-from-cache or actually prefilled — and the cache-side stats
    agree with the scheduler-side counters."""
    model, params = rwkv4_fixture
    rng = np.random.default_rng(9)
    base = rng.integers(0, model.cfg.vocab, size=2 * C).tolist()
    prompts = [base + [1], base + [2], base[:C] + [3]]
    counters = ServingCounters()
    eng, _ = _run_engine(model, params, prompts,
                         cache=PrefixCacheConfig(device_slots=8,
                                                 host_slots=8),
                         counters=counters)
    total = sum(len(p) for p in prompts)
    assert counters.cached_tokens + counters.prefill_tokens == total
    assert counters.cached_tokens == 2 * C + C      # full base, then half
    snap = eng.prefix_cache.snapshot()
    assert snap["hits"] == counters.cache_hits == 2
    assert snap["misses"] == counters.cache_misses == 1
    assert counters.cache_inserts == snap["inserts"] > 0


@pytest.fixture(scope="module")
def rwkv4_fixture():
    model = get_model("rwkv4-169m", smoke=True)
    return model, model.init_params(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Plane-policy isolation: the guardrail for mixed quantized planes
# ---------------------------------------------------------------------------


def test_plane_policy_variant_isolation():
    """Two plans differing ONLY in plane policy can never share cache
    entries: `cache_variant()` derives `quant` from the prepared tree's
    actual per-tensor planes (`plane_fingerprint`), so a state cached
    under the all-W8 pack is invisible to a W4 plan and vice versa —
    while the all-W8 pack keeps the historical "dpot_w8" string and stays
    compatible with pre-plane cache entries."""
    from repro.core.quant.policy import PLANE_W4, PlanePolicy
    w8 = build_plan("rwkv4-169m", quantized=True, prefill_chunk=C)
    w4 = build_plan("rwkv4-169m", quantized=True, plane_policy=PLANE_W4,
                    prefill_chunk=C)
    mix = build_plan("rwkv4-169m", quantized=True, prefill_chunk=C,
                     plane_policy=PlanePolicy(
                         default="w8", overrides=((r"\['head'\]", "w4"),)))
    v_w8, v_w4, v_mix = (p.cache_variant() for p in (w8, w4, mix))
    assert v_w8.quant == "dpot_w8"
    assert v_w4.quant.startswith("dpot_mix_")
    assert v_mix.quant.startswith("dpot_mix_")
    assert len({v_w8, v_w4, v_mix}) == 3

    cache = PrefixCache(C, config=PrefixCacheConfig(device_slots=4,
                                                    host_slots=0))
    prompt = list(range(C + 1))
    assert cache.insert(v_w8, prompt, C, _lane(1.0))
    # the other policies MISS on the same tokens...
    assert cache.probe(v_w4, prompt) is None
    assert cache.probe(v_mix, prompt) is None
    # ...and each can hold its own state for them side by side
    assert cache.insert(v_w4, prompt, C, _lane(2.0))
    for v, tag in ((v_w8, 1.0), (v_w4, 2.0)):
        lease = cache.probe(v, prompt)
        assert lease is not None
        np.testing.assert_array_equal(
            np.asarray(lease.state["a"], np.float32), tag)
        lease.release()
    cache.check_state()


def test_plane_policy_in_snapshot_build_config():
    """A plan's `build_config` records the plane policy (so snapshot
    restore repacks the SAME per-tensor selection), round-trips through
    `PlanePolicy.from_config`, and pre-plane configs restore as None —
    the historical all-W8 pack."""
    from repro.core.quant.policy import PlanePolicy
    pol = PlanePolicy(default="w8", overrides=((r"\['head'\]", "w4"),))
    plan = build_plan("rwkv4-169m", quantized=True, plane_policy=pol,
                      prefill_chunk=C)
    cfg = plan.build_config["plane_policy"]
    assert PlanePolicy.from_config(cfg) == pol
    rebuilt = build_plan("rwkv4-169m", quantized=True, prefill_chunk=C,
                         plane_policy=PlanePolicy.from_config(cfg))
    assert rebuilt.cache_variant() == plan.cache_variant()
    # pre-plane snapshots: no key -> None -> "dpot_w8"
    legacy = build_plan("rwkv4-169m", quantized=True, prefill_chunk=C,
                        plane_policy=PlanePolicy.from_config(None))
    assert legacy.cache_variant().quant == "dpot_w8"
    assert legacy.build_config["plane_policy"] is None
    with pytest.raises(ValueError, match="plane_policy"):
        build_plan("rwkv4-169m", quantized=False, plane_policy=pol)
