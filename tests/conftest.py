import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def hypothesis_stubs():
    """Stand-ins for (given, settings, strategies) when hypothesis is not
    installed: the module still collects and its example-based tests run,
    while each guarded property test skips via pytest.importorskip at run
    time.  Install the `dev` extra (pyproject.toml) to run them for real.
    """
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    def given(*a, **k):
        def deco(fn):
            def _skipped(*args, **kw):
                pytest.importorskip("hypothesis")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*a, **k):
        return lambda fn: fn

    return given, settings, _AnyStrategy()
